//! Distributed termination detection (paper Sec. 4.2.2: "a multi-threaded
//! variant of the distributed consensus algorithm described in [Misra 83]").
//!
//! We implement the Safra refinement of Misra's token ring: each machine
//! keeps a message counter (sent − received) and a color (black if it
//! received a message since last forwarding the token). The leader
//! circulates a token accumulating counters and colors; a white token
//! returning to a white idle leader with total count zero proves global
//! quiescence. The detector is pure state — the engine moves the token in
//! its messages — so the protocol is unit-testable without threads.

use crate::partition::MachineId;
use crate::wire::Wire;

/// The circulating token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Token {
    /// Sum of per-machine (sent − received) counters accumulated so far.
    pub count: i64,
    /// Black if any visited machine was black.
    pub black: bool,
    /// Detection round (monotone; diagnostic only).
    pub round: u64,
}

/// The token rides the locking engine's frames: 17 bytes on the wire.
impl Wire for Token {
    fn encode(&self, out: &mut Vec<u8>) {
        self.count.encode(out);
        self.black.encode(out);
        self.round.encode(out);
    }
    fn decode(input: &mut &[u8]) -> crate::wire::Result<Self> {
        Ok(Token {
            count: i64::decode(input)?,
            black: bool::decode(input)?,
            round: u64::decode(input)?,
        })
    }
}

/// Per-machine detector state.
#[derive(Debug)]
pub struct Termination {
    me: MachineId,
    machines: usize,
    /// sent − received over *countable* messages (work-carrying ones).
    counter: i64,
    /// Black = received a countable message since last token forward.
    black: bool,
    /// Leader only: whether a token is currently circulating.
    token_out: bool,
    round: u64,
}

/// What to do after handling a token.
#[derive(Debug, PartialEq, Eq)]
pub enum TokenAction {
    /// Forward this token to machine `(me + 1) % machines`.
    Forward(Token),
    /// Global termination detected (leader only): broadcast halt.
    Terminate,
    /// Hold the token; re-offer via `maybe_forward` once idle.
    Hold,
}

impl Termination {
    /// Detector for machine `me` of `machines`.
    pub fn new(me: MachineId, machines: usize) -> Self {
        Termination {
            me,
            machines,
            counter: 0,
            black: false,
            token_out: false,
            round: 0,
        }
    }

    /// Record a countable (work-carrying) message send.
    pub fn on_send(&mut self) {
        self.counter += 1;
    }

    /// Record a countable message receipt.
    pub fn on_recv(&mut self) {
        self.counter -= 1;
        self.black = true;
    }

    /// Leader: start a detection round if none is circulating and the
    /// leader itself is idle. Returns the token to send to machine 1 (or
    /// `Terminate` immediately in a single-machine cluster).
    pub fn leader_try_start(&mut self, idle: bool) -> Option<TokenAction> {
        debug_assert_eq!(self.me, 0);
        if self.token_out || !idle {
            return None;
        }
        self.round += 1;
        if self.machines == 1 {
            // Single machine: idle leader with no peers terminates.
            return Some(TokenAction::Terminate);
        }
        self.token_out = true;
        let token = Token {
            count: self.counter,
            black: self.black,
            round: self.round,
        };
        self.black = false;
        Some(TokenAction::Forward(token))
    }

    /// Handle an incoming token. `idle` = scheduler empty and no
    /// transactions in flight. Non-idle machines hold the token and call
    /// [`Termination::maybe_forward`] later.
    pub fn on_token(&mut self, token: Token, idle: bool) -> TokenAction {
        if self.me == 0 {
            // Token completed the ring.
            self.token_out = false;
            if idle && !token.black && !self.black && token.count == 0 {
                return TokenAction::Terminate;
            }
            // Failed round; leader will restart via leader_try_start.
            return TokenAction::Hold;
        }
        if !idle {
            return TokenAction::Hold;
        }
        self.forward(token)
    }

    /// Re-offer a held token now that the machine is idle.
    pub fn maybe_forward(&mut self, token: Token, idle: bool) -> TokenAction {
        if !idle {
            return TokenAction::Hold;
        }
        if self.me == 0 {
            return self.on_token(token, idle);
        }
        self.forward(token)
    }

    fn forward(&mut self, mut token: Token) -> TokenAction {
        token.count += self.counter;
        token.black |= self.black;
        self.black = false;
        TokenAction::Forward(token)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a full ring round over `dets`, returning the leader's verdict.
    fn run_round(dets: &mut [Termination], idle: &[bool]) -> TokenAction {
        let Some(action) = dets[0].leader_try_start(idle[0]) else {
            return TokenAction::Hold;
        };
        let mut token = match action {
            TokenAction::Forward(t) => t,
            other => return other,
        };
        for m in 1..dets.len() {
            match dets[m].on_token(token, idle[m]) {
                TokenAction::Forward(t) => token = t,
                other => return other,
            }
        }
        dets[0].on_token(token, idle[0])
    }

    #[test]
    fn all_idle_no_messages_terminates() {
        let mut dets: Vec<Termination> = (0..4).map(|m| Termination::new(m, 4)).collect();
        let idle = [true; 4];
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Terminate);
    }

    #[test]
    fn busy_machine_blocks_termination() {
        let mut dets: Vec<Termination> = (0..3).map(|m| Termination::new(m, 3)).collect();
        let idle = [true, false, true];
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Hold);
    }

    #[test]
    fn in_flight_message_blocks_then_clears() {
        let mut dets: Vec<Termination> = (0..3).map(|m| Termination::new(m, 3)).collect();
        // Machine 1 sent a message not yet received: counters unbalanced.
        dets[1].on_send();
        let idle = [true; 3];
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Hold);
        // Message arrives at machine 2 (turns it black): still no terminate
        // this round (black), but the next round is clean.
        dets[2].on_recv();
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Hold);
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Terminate);
    }

    #[test]
    fn single_machine_terminates_when_idle() {
        let mut d = Termination::new(0, 1);
        assert_eq!(d.leader_try_start(false), None);
        assert_eq!(d.leader_try_start(true), Some(TokenAction::Terminate));
    }

    #[test]
    fn no_false_termination_with_hidden_work() {
        // Classic Safra scenario: machine 2 sends work to machine 1 after
        // the token passed machine 1. The receive blackens machine 1, so
        // the *next* round fails too, and only the round after can
        // succeed — by which time the work is visible.
        let mut dets: Vec<Termination> = (0..3).map(|m| Termination::new(m, 3)).collect();
        // Round starts; simulate token passing 1 (idle), then 2 sends to 1.
        let t0 = match dets[0].leader_try_start(true).unwrap() {
            TokenAction::Forward(t) => t,
            _ => panic!(),
        };
        let t1 = match dets[1].on_token(t0, true) {
            TokenAction::Forward(t) => t,
            _ => panic!(),
        };
        dets[2].on_send(); // work sent to machine 1 (in flight)
        let t2 = match dets[2].on_token(t1, true) {
            TokenAction::Forward(t) => t,
            _ => panic!(),
        };
        // Leader must NOT terminate: counter sum is +1.
        assert_eq!(dets[0].on_token(t2, true), TokenAction::Hold);
        // Work arrives; machine 1 processes it and goes idle again.
        dets[1].on_recv();
        let idle = [true; 3];
        // One round fails (machine 1 black), the next terminates.
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Hold);
        assert_eq!(run_round(&mut dets, &idle), TokenAction::Terminate);
    }
}
