//! Distributed reader–writer lock table (paper Sec. 4.2.2).
//!
//! Each machine owns the locks for its own vertices. Requests arrive (from
//! local or remote transactions) and are granted immediately or queued
//! FIFO; releases promote waiters. The table is pure logic — message
//! transport is the engine's job — which makes the protocol directly
//! unit-testable.
//!
//! Deadlock freedom: a transaction acquires the locks of its scope in
//! ascending global vertex order, holding earlier locks while waiting for
//! later ones. Cycles in the wait-for graph would need some transaction to
//! wait on a lower-ordered lock than one it holds — impossible. Pipelining
//! (paper Fig. 8(b)) runs many transactions' chains concurrently.
//!
//! Grant exclusivity: a granted write lock excludes every other grant on
//! that vertex until the holder releases it. The locking engine's executor
//! pool leans on exactly this contract — scope data snapshotted any time
//! between the final grant and the release reads the same values, which is
//! what makes dispatch-time snapshots and commit-time write-back of
//! executor results exact (DESIGN.md, "Execution off the pump thread").

use std::collections::{HashMap, VecDeque};

use crate::graph::VertexId;
use crate::partition::MachineId;
use crate::wire::Wire;

/// Globally unique transaction id: (machine, local sequence).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TxnId {
    /// Requesting machine.
    pub machine: MachineId,
    /// Per-machine sequence number.
    pub seq: u64,
}

/// Transaction ids travel in every lock-protocol frame: machine (as u32 —
/// cluster sizes are small) + sequence.
impl Wire for TxnId {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.machine as u32).encode(out);
        self.seq.encode(out);
    }
    fn decode(input: &mut &[u8]) -> crate::wire::Result<Self> {
        Ok(TxnId {
            machine: u32::decode(input)? as MachineId,
            seq: u64::decode(input)?,
        })
    }
}

/// A lock request.
#[derive(Debug, Clone, Copy)]
pub struct LockReq {
    /// Requesting transaction.
    pub txn: TxnId,
    /// Vertex whose lock is requested (owned by this table's machine).
    pub vertex: VertexId,
    /// Write (exclusive) or read (shared).
    pub write: bool,
}

#[derive(Default)]
struct LockState {
    readers: u32,
    writer: Option<TxnId>,
    /// FIFO wait queue.
    waiting: VecDeque<LockReq>,
}

/// Reader–writer lock table for the vertices owned by one machine.
#[derive(Default)]
pub struct LockTable {
    locks: HashMap<VertexId, LockState>,
    held_reads: HashMap<(VertexId, MachineId, u64), ()>,
}

impl LockTable {
    /// Empty table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit a request. Returns `true` if granted immediately; otherwise
    /// the request is queued and will appear in a later
    /// [`LockTable::release`] result.
    pub fn request(&mut self, req: LockReq) -> bool {
        let st = self.locks.entry(req.vertex).or_default();
        let grantable = if req.write {
            st.readers == 0 && st.writer.is_none() && st.waiting.is_empty()
        } else {
            // Readers must also queue behind waiting writers (no writer
            // starvation — matches a fair RW lock).
            st.writer.is_none() && st.waiting.is_empty()
        };
        if grantable {
            self.grant(req);
            true
        } else {
            st.waiting.push_back(req);
            false
        }
    }

    fn grant(&mut self, req: LockReq) {
        let st = self.locks.get_mut(&req.vertex).unwrap();
        if req.write {
            debug_assert!(st.readers == 0 && st.writer.is_none());
            st.writer = Some(req.txn);
        } else {
            debug_assert!(st.writer.is_none());
            st.readers += 1;
            self.held_reads
                .insert((req.vertex, req.txn.machine, req.txn.seq), ());
        }
    }

    /// Release a previously granted lock; returns the requests that become
    /// granted as a result (to be notified by the engine).
    pub fn release(&mut self, vertex: VertexId, txn: TxnId, write: bool) -> Vec<LockReq> {
        if write {
            let st = self.locks.get_mut(&vertex).expect("release of unknown lock");
            debug_assert_eq!(st.writer, Some(txn), "write release by non-holder");
            st.writer = None;
        } else {
            // Note: the removal must stay outside debug_assert! — a side
            // effect inside it would vanish in release builds.
            let held = self.held_reads.remove(&(vertex, txn.machine, txn.seq));
            debug_assert!(held.is_some(), "read release by non-holder");
            let st = self.locks.get_mut(&vertex).expect("release of unknown lock");
            debug_assert!(st.readers > 0);
            st.readers -= 1;
        }
        // Promote waiters: grant the head writer if the lock is free, or a
        // maximal prefix run of readers.
        let mut granted = Vec::new();
        loop {
            let st = self.locks.get_mut(&vertex).unwrap();
            let Some(head) = st.waiting.front().copied() else {
                break;
            };
            let ok = if head.write {
                st.readers == 0 && st.writer.is_none()
            } else {
                st.writer.is_none()
            };
            if !ok {
                break;
            }
            st.waiting.pop_front();
            self.grant(head);
            granted.push(head);
            if head.write {
                break;
            }
        }
        granted
    }

    /// Number of vertices with any lock state (test/diagnostic).
    pub fn active_locks(&self) -> usize {
        self.locks
            .values()
            .filter(|s| s.readers > 0 || s.writer.is_some() || !s.waiting.is_empty())
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(m: MachineId, seq: u64) -> TxnId {
        TxnId { machine: m, seq }
    }

    fn req(txn: TxnId, v: VertexId, write: bool) -> LockReq {
        LockReq {
            txn,
            vertex: v,
            write,
        }
    }

    #[test]
    fn readers_share_writers_exclude() {
        let mut lt = LockTable::new();
        assert!(lt.request(req(t(0, 1), 5, false)));
        assert!(lt.request(req(t(1, 1), 5, false)));
        assert!(!lt.request(req(t(2, 1), 5, true))); // queued
        assert!(!lt.request(req(t(3, 1), 5, false))); // queued behind writer
        // Release one reader: nothing grantable yet.
        assert!(lt.release(5, t(0, 1), false).is_empty());
        // Release last reader: writer granted.
        let g = lt.release(5, t(1, 1), false);
        assert_eq!(g.len(), 1);
        assert!(g[0].write);
        assert_eq!(g[0].txn, t(2, 1));
        // Writer releases: queued reader granted.
        let g = lt.release(5, t(2, 1), true);
        assert_eq!(g.len(), 1);
        assert!(!g[0].write);
    }

    #[test]
    fn fifo_promotion_grants_reader_runs() {
        let mut lt = LockTable::new();
        assert!(lt.request(req(t(0, 1), 9, true)));
        assert!(!lt.request(req(t(1, 1), 9, false)));
        assert!(!lt.request(req(t(2, 1), 9, false)));
        assert!(!lt.request(req(t(3, 1), 9, true)));
        assert!(!lt.request(req(t(4, 1), 9, false)));
        let g = lt.release(9, t(0, 1), true);
        // Reader run of length 2 granted; writer t3 blocks the rest.
        assert_eq!(g.len(), 2);
        assert!(g.iter().all(|r| !r.write));
        let _ = lt.release(9, t(1, 1), false);
        let g = lt.release(9, t(2, 1), false);
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].txn, t(3, 1));
    }

    #[test]
    fn independent_vertices_dont_interact() {
        let mut lt = LockTable::new();
        assert!(lt.request(req(t(0, 1), 1, true)));
        assert!(lt.request(req(t(0, 2), 2, true)));
        assert_eq!(lt.active_locks(), 2);
        assert!(lt.release(1, t(0, 1), true).is_empty());
        assert_eq!(lt.active_locks(), 1);
    }

    #[test]
    fn ordered_acquisition_cannot_deadlock_two_txns() {
        // Simulated interleaving: txn A and B both need locks {3, 7} in
        // ascending order. Whatever the interleaving, someone finishes.
        let mut lt = LockTable::new();
        let a = t(0, 1);
        let b = t(1, 1);
        assert!(lt.request(req(a, 3, true)));
        assert!(!lt.request(req(b, 3, true))); // b queues on 3
        assert!(lt.request(req(a, 7, true))); // a completes its chain
        // a finishes, releases in any order.
        let g = lt.release(3, a, true);
        assert_eq!(g[0].txn, b); // b now holds 3
        assert!(lt.release(7, a, true).is_empty());
        assert!(lt.request(req(b, 7, true))); // b completes
    }
}
