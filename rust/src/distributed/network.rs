//! The **framing layer**: typed messages over a byte-level
//! [`Transport`], with byte accounting and a self-send fast path.
//!
//! Machines communicate only through [`Endpoint`]s. Every send serializes
//! its message through the [`Wire`] codec into a `[u32 len][payload]`
//! frame; the frame's encoded length is what lands in the per-machine
//! [`NetStats`] (Fig. 6(b) plots these), and the receiver decodes the
//! frame back — the byte counters are measurements of real serialization,
//! not size models. The frames travel over whichever
//! [`Transport`](crate::distributed::transport::Transport) backend the
//! run selected:
//!
//! * **InProc** (default): mpsc channels, FIFO per peer like the paper's
//!   TCP sockets, with [`NetworkModel`] latency applied as a delivery
//!   hold-back. A frame that fails to decode here is a codec bug (both
//!   ends are the same build) and panics.
//! * **Tcp**: real sockets (loopback mesh in one process, or one endpoint
//!   per worker process). Frames from the network are untrusted: a
//!   malformed frame surfaces as a typed [`PeerError`] and a disconnect
//!   of that peer via [`Endpoint::peer_errors`], never a process abort.
//!
//! Self-sends skip the transport entirely (the value is delivered
//! in-memory through a local queue) but still run the encoder, so every
//! message pays the same measurement path; they account zero *network*
//! bytes, as before.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::distributed::transport::{
    tcp_loopback_mesh, FaultPlan, Faulty, FrameError, FramePool, InProcTransport, PeerError,
    TcpBound, TcpConfig, Transport,
};
use crate::partition::MachineId;
use crate::wire::Wire;

pub use crate::distributed::transport::NetworkModel;

/// Eager-flush threshold for autobatched sends: a peer's pending
/// coalesced buffer that reaches this size goes out immediately instead
/// of waiting for the next explicit flush point, bounding both memory
/// and added latency under heavy fan-out.
const BATCH_FLUSH_BYTES: usize = 1 << 20;

/// Per-machine traffic counters (all byte counts are encoded frame
/// lengths, including the 4-byte length prefix).
#[derive(Default)]
pub struct NetStats {
    /// Frame bytes sent by this machine to other machines.
    pub bytes_sent: AtomicU64,
    /// Messages sent by this machine to other machines.
    pub msgs_sent: AtomicU64,
    /// Frame bytes received from other machines.
    pub bytes_recv: AtomicU64,
    /// Messages received from other machines.
    pub msgs_recv: AtomicU64,
}

/// Construction handle: build one, split into per-machine endpoints.
///
/// In a multi-process cluster ([`Network::tcp_cluster`]) the network
/// holds a *single* endpoint — this process's machine — and the stats
/// vector still has one slot per machine, of which only the local slot
/// is ever written.
pub struct Network<M> {
    endpoints: Vec<Endpoint<M>>,
    stats: Arc<Vec<NetStats>>,
}

/// One machine's connection to the cluster: the typed, accounted framing
/// layer over a byte-level transport backend.
pub struct Endpoint<M> {
    me: MachineId,
    machines: usize,
    transport: Box<dyn Transport>,
    /// Self-send fast path: messages to `me` skip the transport (and the
    /// frame copy) and are delivered through this in-memory queue.
    self_tx: mpsc::Sender<M>,
    self_rx: mpsc::Receiver<M>,
    /// Peers disconnected after a framing-layer decode error (their
    /// later frames drop — the stream is producing untrustable bytes).
    dead: Vec<bool>,
    /// Typed errors from untrusted peers, drained by [`Endpoint::peer_errors`].
    errors: Vec<PeerError>,
    stats: Arc<Vec<NetStats>>,
    /// Recycled frame buffers: `send` encodes into a pooled `Vec<u8>`,
    /// `open` returns decoded frames, and the transport (if it buffers
    /// internally, like TCP's writer/reader threads) recycles through
    /// the same pool via [`Transport::install_pool`].
    pool: FramePool,
    /// When set, `send` appends to a per-peer pending buffer instead of
    /// hitting the transport; see [`Endpoint::set_autobatch`].
    autobatch: AtomicBool,
    /// Per-peer pending coalesced frames (autobatch mode). Mutexes, not
    /// `&mut`, because `send` takes `&self` — engines send while holding
    /// shared borrows.
    pending: Vec<Mutex<Pending>>,
}

/// One peer's pending coalesced frames (autobatch mode).
#[derive(Default)]
struct Pending {
    /// Back-to-back `[u32 len][payload]` frames not yet handed to the
    /// transport.
    buf: Vec<u8>,
    /// How many logical frames `buf` holds.
    count: usize,
}

/// Encode `msg` as one `[u32 len][payload]` frame appended to `buf`;
/// returns the frame's total length (payload + 4-byte prefix).
fn encode_frame_into<M: Wire>(msg: &M, buf: &mut Vec<u8>) -> usize {
    let start = buf.len();
    buf.extend_from_slice(&[0u8; 4]);
    msg.encode(buf);
    let payload_len = (buf.len() - start - 4) as u32;
    buf[start..start + 4].copy_from_slice(&payload_len.to_le_bytes());
    buf.len() - start
}

fn new_stats(machines: usize) -> Arc<Vec<NetStats>> {
    Arc::new((0..machines).map(|_| NetStats::default()).collect())
}

impl<M: Send + Wire> Network<M> {
    /// Create a fully-connected **in-process** network of `machines`
    /// endpoints (mpsc channels + injected latency).
    pub fn new(machines: usize, model: NetworkModel) -> Self {
        let stats = new_stats(machines);
        let endpoints = InProcTransport::mesh(machines, model)
            .into_iter()
            .map(|t| Endpoint::from_transport(Box::new(t), stats.clone()))
            .collect();
        Network { endpoints, stats }
    }

    /// Create a fully-connected network of `machines` endpoints over
    /// **real loopback TCP sockets** (ephemeral ports, full mesh, one
    /// listener + writer/reader threads per machine) — same API, actual
    /// kernel sockets under every frame.
    pub fn tcp_loopback(machines: usize) -> anyhow::Result<Self> {
        let stats = new_stats(machines);
        let endpoints = tcp_loopback_mesh(machines, std::any::type_name::<M>())?
            .into_iter()
            .map(|t| Endpoint::from_transport(Box::new(t), stats.clone()))
            .collect();
        Ok(Network { endpoints, stats })
    }

    /// Join a **multi-process** cluster as machine `me` of
    /// `hosts.len()`: bind the listener at `hosts[me]`, handshake with
    /// every peer (machine id + wire version + message type tag), and
    /// return a network holding this machine's single endpoint.
    pub fn tcp_cluster(me: MachineId, hosts: &[String]) -> anyhow::Result<Self> {
        anyhow::ensure!(
            me < hosts.len(),
            "machine id {me} out of range for a {}-machine cluster",
            hosts.len()
        );
        let stats = new_stats(hosts.len());
        let cfg = TcpConfig::new(hosts.len(), std::any::type_name::<M>());
        let transport = TcpBound::bind(me, &hosts[me], cfg)?.connect(hosts)?;
        let endpoints = vec![Endpoint::from_transport(Box::new(transport), stats.clone())];
        Ok(Network { endpoints, stats })
    }

    /// Split into the per-machine endpoints. For the in-process
    /// constructors the index is the machine id; for
    /// [`Network::tcp_cluster`] there is exactly one endpoint (machine
    /// `me`).
    pub fn into_endpoints(self) -> Vec<Endpoint<M>> {
        self.endpoints
    }

    /// Shared stats handle (read by the harness after the run).
    pub fn stats(&self) -> Arc<Vec<NetStats>> {
        self.stats.clone()
    }
}

/// Build the endpoints a distributed engine runs locally, for any
/// backend combination:
///
/// * `cluster = None`, `InProc` — the classic in-process cluster (all
///   `machines` endpoints over channels, with `model` latency);
/// * `cluster = None`, `Tcp` — all `machines` endpoints in this process
///   over a real loopback-socket mesh (`model` is ignored — real wires
///   have real latency);
/// * `cluster = Some(c)` — this process is machine `c.me` of a
///   multi-process cluster; exactly one endpoint comes back.
///
/// The stats vector always has one slot per machine; only locally-run
/// machines ever write theirs.
pub(crate) fn cluster_endpoints<M: Send + Wire>(
    machines: usize,
    model: NetworkModel,
    transport: crate::distributed::transport::TransportKind,
    cluster: Option<&crate::distributed::transport::ClusterConfig>,
    fault: Option<&FaultPlan>,
) -> anyhow::Result<(Vec<Endpoint<M>>, Arc<Vec<NetStats>>)> {
    use crate::distributed::transport::TransportKind;
    // With a fault plan, every backend's transports are wrapped in
    // `Faulty` before the framing layer sees them; a plan that injects
    // nothing takes the plain path.
    if let Some(plan) = fault.filter(|p| !p.is_empty()) {
        let stats = new_stats(machines);
        let endpoints: Vec<Endpoint<M>> = match cluster {
            Some(c) => {
                anyhow::ensure!(
                    c.hosts.len() == machines,
                    "cluster hosts file lists {} machines but the engine runs {machines}",
                    c.hosts.len()
                );
                let cfg = TcpConfig::new(machines, std::any::type_name::<M>());
                let t = TcpBound::bind(c.me, &c.hosts[c.me], cfg)?.connect(&c.hosts)?;
                vec![Endpoint::from_transport(
                    Box::new(Faulty::new(t, plan.clone())),
                    stats.clone(),
                )]
            }
            None => match transport {
                TransportKind::InProc => {
                    Faulty::wrap_mesh(InProcTransport::mesh(machines, model), plan.clone())
                        .into_iter()
                        .map(|t| Endpoint::from_transport(Box::new(t), stats.clone()))
                        .collect()
                }
                TransportKind::Tcp => Faulty::wrap_mesh(
                    tcp_loopback_mesh(machines, std::any::type_name::<M>())?,
                    plan.clone(),
                )
                .into_iter()
                .map(|t| Endpoint::from_transport(Box::new(t), stats.clone()))
                .collect(),
            },
        };
        return Ok((endpoints, stats));
    }
    let net = match cluster {
        Some(c) => {
            anyhow::ensure!(
                c.hosts.len() == machines,
                "cluster hosts file lists {} machines but the engine runs {machines}",
                c.hosts.len()
            );
            Network::tcp_cluster(c.me, &c.hosts)?
        }
        None => match transport {
            TransportKind::InProc => Network::new(machines, model),
            TransportKind::Tcp => Network::tcp_loopback(machines)?,
        },
    };
    let stats = net.stats();
    Ok((net.into_endpoints(), stats))
}

/// Received message with its source.
pub struct Received<M> {
    /// Sender machine.
    pub src: MachineId,
    /// The message.
    pub msg: M,
}

impl<M: Send + Wire> Endpoint<M> {
    /// Wrap a ready byte-level transport in the typed framing layer.
    /// `stats` must have one slot per machine; this endpoint writes only
    /// its own. (Public so tests and tooling can drive hand-built
    /// transports; engine code goes through [`Network`].)
    pub fn from_transport(mut transport: Box<dyn Transport>, stats: Arc<Vec<NetStats>>) -> Self {
        let (self_tx, self_rx) = mpsc::channel();
        let machines = transport.machines();
        assert_eq!(
            stats.len(),
            machines,
            "stats vector must have one slot per machine"
        );
        let pool = FramePool::default();
        transport.install_pool(&pool);
        Endpoint {
            me: transport.me(),
            machines,
            transport,
            self_tx,
            self_rx,
            dead: vec![false; machines],
            errors: Vec::new(),
            stats,
            pool,
            autobatch: AtomicBool::new(false),
            pending: (0..machines).map(|_| Mutex::new(Pending::default())).collect(),
        }
    }

    /// This machine's id.
    pub fn me(&self) -> MachineId {
        self.me
    }

    /// Cluster size.
    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Shared stats handle.
    pub fn stats(&self) -> Arc<Vec<NetStats>> {
        self.stats.clone()
    }

    /// Serialize `msg` into a frame and send it to `dst`. The frame's
    /// encoded length (payload + 4-byte length prefix) is recorded in
    /// [`NetStats`] at encode time, per logical message — so the byte
    /// counters are identical whether frames go out one by one,
    /// coalesced by autobatch, or packed by [`Endpoint::send_batch`].
    ///
    /// Sending to self is allowed (simplifies engine loops); it still
    /// encodes — parity with remote accounting — but skips the frame
    /// copy and counts zero network bytes (nothing crosses the wire).
    pub fn send(&self, dst: MachineId, msg: M) {
        if dst == self.me {
            // Fast path: deliver the value in-memory (receiver may have
            // stopped draining at shutdown; drop silently then). The
            // parity encode goes through a pooled scratch buffer.
            let mut scratch = self.pool.get();
            encode_frame_into(&msg, &mut scratch);
            self.pool.put(scratch);
            let _ = self.self_tx.send(msg);
            return;
        }
        if self.autobatch.load(Ordering::Relaxed) {
            let mut p = self.pending[dst].lock().unwrap_or_else(|e| e.into_inner());
            let n = encode_frame_into(&msg, &mut p.buf);
            p.count += 1;
            let s = &self.stats[self.me];
            s.bytes_sent.fetch_add(n as u64, Ordering::Relaxed);
            s.msgs_sent.fetch_add(1, Ordering::Relaxed);
            if p.buf.len() >= BATCH_FLUSH_BYTES {
                let buf = std::mem::replace(&mut p.buf, self.pool.get());
                let count = std::mem::take(&mut p.count);
                drop(p);
                self.transport.send_frames(dst, buf, count);
            }
            return;
        }
        let mut frame = self.pool.get();
        let n = encode_frame_into(&msg, &mut frame);
        let s = &self.stats[self.me];
        s.bytes_sent.fetch_add(n as u64, Ordering::Relaxed);
        s.msgs_sent.fetch_add(1, Ordering::Relaxed);
        self.transport.send_frame(dst, frame);
    }

    /// Encode `msgs` into one contiguous multi-frame buffer and hand it
    /// to the transport as a single batched send — one writer-queue entry
    /// and (on TCP) one write for the lot. FIFO order with surrounding
    /// [`Endpoint::send`]s is preserved, and per-message accounting is
    /// identical to sending each individually.
    pub fn send_batch(&self, dst: MachineId, msgs: Vec<M>) {
        if msgs.is_empty() {
            return;
        }
        if dst == self.me || self.autobatch.load(Ordering::Relaxed) {
            // Self-sends keep the in-memory fast path; under autobatch
            // every frame must route through the per-peer pending buffer
            // or interleaved sends would go out of order.
            for msg in msgs {
                self.send(dst, msg);
            }
            return;
        }
        let mut buf = self.pool.get();
        let count = msgs.len();
        let mut bytes = 0u64;
        for msg in &msgs {
            bytes += encode_frame_into(msg, &mut buf) as u64;
        }
        let s = &self.stats[self.me];
        s.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        s.msgs_sent.fetch_add(count as u64, Ordering::Relaxed);
        self.transport.send_frames(dst, buf, count);
    }

    /// Decode one transport frame. `None` means the frame was bad and
    /// the peer is now disconnected (untrusted backends only; for the
    /// in-process backend a decode failure is a codec bug and panics).
    fn open(&mut self, src: MachineId, frame: Vec<u8>) -> Option<Received<M>> {
        if self.dead[src] {
            return None; // disconnected peer: drop its residual frames
        }
        let s = &self.stats[self.me];
        s.bytes_recv.fetch_add(frame.len() as u64, Ordering::Relaxed);
        s.msgs_recv.fetch_add(1, Ordering::Relaxed);
        // Decode in an inner scope so the frame buffer can go back to
        // the pool regardless of outcome (trusted-backend failures still
        // panic inline — they are codec bugs, not peer behavior).
        let decoded = {
            let mut slice = &frame[4..];
            match M::decode(&mut slice) {
                Ok(msg) if slice.is_empty() => Ok(msg),
                Ok(_) if self.transport.trusted() => {
                    panic!("wire: frame has trailing bytes (codec bug — encode/decode disagree)")
                }
                Ok(_) => Err(FrameError::Trailing { extra: slice.len() }),
                Err(e) if self.transport.trusted() => {
                    panic!("wire: frame decode failed (codec bug — encode/decode disagree): {e}")
                }
                Err(e) => Err(FrameError::Decode(e)),
            }
        };
        self.pool.put(frame);
        match decoded {
            Ok(msg) => Some(Received { src, msg }),
            Err(e) => {
                self.disconnect(src, e);
                None
            }
        }
    }

    fn disconnect(&mut self, peer: MachineId, error: FrameError) {
        self.dead[peer] = true;
        self.errors.push(PeerError { peer, error });
    }

    /// Pull transport-level errors (stream failures, oversized frames)
    /// into the endpoint's typed error list. Deliberately does NOT mark
    /// the peer dead: a reader thread records its error strictly *after*
    /// pushing every frame it successfully read, then stops — so frames
    /// already queued predate the failure and must still be delivered
    /// (a finished peer's final `Halt`/`FinalReport`/`Decision` races
    /// its own EOF). Only framing-layer decode errors disconnect a peer,
    /// because that stream keeps producing bytes we can no longer trust.
    fn absorb_transport_errors(&mut self) {
        for e in self.transport.take_errors() {
            self.errors.push(e);
        }
    }

    /// Drain the typed per-peer errors collected so far (frame decode
    /// failures, truncated/oversized frames, stream errors). A peer that
    /// appears here produces no further frames; one that failed at the
    /// framing layer (decode/trailing) is disconnected — its residual
    /// frames are dropped.
    pub fn peer_errors(&mut self) -> Vec<PeerError> {
        self.absorb_transport_errors();
        std::mem::take(&mut self.errors)
    }

    /// Whether `peer` is still trusted at the framing layer (no decoded
    /// garbage from it). Stream-level failures are reported through
    /// [`Endpoint::peer_errors`] instead — their already-received frames
    /// remain deliverable.
    pub fn peer_alive(&mut self, peer: MachineId) -> bool {
        self.absorb_transport_errors();
        !self.dead[peer]
    }

    /// Non-blocking receive honoring the backend's delivery semantics
    /// (hold-back latency on InProc, socket arrival on TCP).
    pub fn try_recv(&mut self) -> Option<Received<M>> {
        if let Ok(msg) = self.self_rx.try_recv() {
            return Some(Received { src: self.me, msg });
        }
        while let Some((src, frame)) = self.transport.recv_frame() {
            if let Some(r) = self.open(src, frame) {
                return Some(r);
            }
        }
        self.absorb_transport_errors();
        None
    }

    /// Blocking receive with timeout. Under autobatch, pending coalesced
    /// sends are flushed first: a machine about to block must not be the
    /// reason its peers starve (the request they are waiting on could be
    /// sitting in a pending buffer — a deadlock, not a slowdown).
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<Received<M>> {
        if self.autobatch.load(Ordering::Relaxed) {
            self.flush();
        }
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(r) = self.try_recv() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            if let Some((src, frame)) = self.transport.recv_frame_timeout(deadline - now) {
                if let Some(r) = self.open(src, frame) {
                    return Some(r);
                }
            }
        }
    }
}

// Autobatch control lives in an unbounded impl block (no `M: Wire`):
// flushing moves already-encoded bytes, so `Drop` can call it without a
// codec bound on the message type.
impl<M> Endpoint<M> {
    /// Switch per-peer send coalescing on or off. While on, every
    /// cross-machine [`Endpoint::send`] appends to that peer's pending
    /// buffer instead of hitting the transport; a buffer goes out when
    /// it reaches the eager-flush threshold, on [`Endpoint::flush`],
    /// before every blocking receive, and on drop. Engines with a pump
    /// structure (the locking engine, `serve`) turn this on and flush
    /// once per pump iteration: many small protocol messages become a
    /// few coalesced writes. Disabling flushes immediately.
    pub fn set_autobatch(&self, on: bool) {
        let was = self.autobatch.swap(on, Ordering::Relaxed);
        if was && !on {
            self.flush();
        }
    }

    /// Hand every peer's pending coalesced buffer to the transport (one
    /// batched send per peer with pending frames). A no-op outside
    /// autobatch mode or when nothing is pending.
    pub fn flush(&self) {
        for dst in 0..self.machines {
            if dst != self.me {
                self.flush_peer(dst);
            }
        }
    }

    fn flush_peer(&self, dst: MachineId) {
        let (buf, count) = {
            let mut p = self.pending[dst].lock().unwrap_or_else(|e| e.into_inner());
            if p.count == 0 {
                return;
            }
            (
                std::mem::replace(&mut p.buf, self.pool.get()),
                std::mem::take(&mut p.count),
            )
        };
        self.transport.send_frames(dst, buf, count);
    }
}

impl<M> Drop for Endpoint<M> {
    /// Backstop flush: frames still coalescing must reach the transport
    /// before it tears down (a follower's final report, a `Halt` sent
    /// just before the machine loop returned). Explicit flush points
    /// cover the protocol paths; this covers everything else.
    fn drop(&mut self) {
        if self.autobatch.load(Ordering::Relaxed) {
            self.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Encoded frame length of one message (length prefix + payload).
    fn frame_len<M: Wire>(msg: &M) -> u64 {
        4 + crate::wire::encoded_len(msg) as u64
    }

    #[test]
    fn point_to_point_delivery_and_accounting() {
        let net: Network<(u32, Vec<u8>)> = Network::new(3, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        let m1 = (7u32, vec![1u8, 2, 3]);
        let m2 = (8u32, Vec::new());
        let expect = frame_len(&m1) + frame_len(&m2);
        eps[0].send(2, m1.clone());
        eps[0].send(2, m2.clone());
        let r1 = eps[2].recv_timeout(Duration::from_secs(1)).unwrap();
        let r2 = eps[2].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!((r1.src, r1.msg), (0, m1));
        assert_eq!((r2.src, r2.msg), (0, m2)); // FIFO per channel
        // Bytes counted are the encoded frame lengths, at both ends.
        assert_eq!(stats[0].bytes_sent.load(Ordering::Relaxed), expect);
        assert_eq!(stats[2].bytes_recv.load(Ordering::Relaxed), expect);
        assert_eq!(stats[2].msgs_recv.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn self_send_costs_no_network_bytes() {
        let net: Network<u32> = Network::new(1, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        eps[0].send(0, 1);
        let r = eps[0].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r.msg, 1);
        assert_eq!(stats[0].bytes_sent.load(Ordering::Relaxed), 0);
        assert_eq!(stats[0].msgs_sent.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn latency_delays_delivery() {
        let net: Network<u32> = Network::new(2, NetworkModel {
            latency: Duration::from_millis(30),
        });
        let mut eps = net.into_endpoints();
        let t0 = Instant::now();
        eps[0].send(1, 42);
        let r = eps[1].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r.msg, 42);
        assert!(
            t0.elapsed() >= Duration::from_millis(28),
            "delivered after {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn cross_thread_usage() {
        let net: Network<u64> = Network::new(4, NetworkModel::default());
        let eps = net.into_endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    // Everyone sends its id to everyone else, then sums
                    // what it receives.
                    for d in 0..ep.machines() {
                        if d != ep.me() {
                            ep.send(d, ep.me() as u64);
                        }
                    }
                    let mut sum = 0;
                    for _ in 0..ep.machines() - 1 {
                        sum += ep.recv_timeout(Duration::from_secs(5)).unwrap().msg;
                    }
                    sum
                })
            })
            .collect();
        let sums: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Machine m receives 0+1+2+3 - m.
        for (m, s) in sums.iter().enumerate() {
            assert_eq!(*s, 6 - m as u64);
        }
    }

    #[test]
    fn structured_message_survives_the_frame() {
        // A message shaped like the engines' protocol frames: enum-free
        // but nested (Vec of tuples + Option + String).
        type M = (Vec<(u32, u64, f32)>, Option<(String, Vec<f64>)>);
        let msg: M = (
            vec![(1, 2, 3.5), (4, 5, -0.25)],
            Some(("total_rank".to_string(), vec![1.0, 2.0])),
        );
        let net: Network<M> = Network::new(2, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        eps[0].send(1, msg.clone());
        let r = eps[1].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r.msg, msg);
        assert_eq!(
            stats[0].bytes_sent.load(Ordering::Relaxed),
            frame_len(&msg)
        );
    }

    #[test]
    fn send_batch_matches_individual_accounting_and_order() {
        type M = (u32, Vec<u8>);
        let net: Network<M> = Network::new(2, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        let msgs: Vec<M> = (0..5u32).map(|i| (i, vec![i as u8; i as usize])).collect();
        let expect: u64 = msgs.iter().map(frame_len).sum();
        eps[0].send_batch(1, msgs.clone());
        for m in &msgs {
            let r = eps[1].recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!((r.src, &r.msg), (0, m));
        }
        // One batched send accounts exactly like five individual sends.
        assert_eq!(stats[0].bytes_sent.load(Ordering::Relaxed), expect);
        assert_eq!(stats[0].msgs_sent.load(Ordering::Relaxed), 5);
        assert_eq!(stats[1].msgs_recv.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn autobatch_coalesces_until_flush_with_identical_accounting() {
        let net: Network<u32> = Network::new(2, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        eps[0].set_autobatch(true);
        for i in 0..10u32 {
            eps[0].send(1, i);
        }
        // Accounting is per logical message, counted at encode time —
        // identical to the unbatched path.
        assert_eq!(stats[0].msgs_sent.load(Ordering::Relaxed), 10);
        let expect = stats[0].bytes_sent.load(Ordering::Relaxed);
        assert_eq!(expect, 10 * frame_len(&0u32));
        // Nothing is deliverable yet: the frames are still coalescing.
        assert!(eps[1].try_recv().is_none());
        eps[0].flush();
        for i in 0..10u32 {
            let r = eps[1].recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!((r.src, r.msg), (0, i)); // FIFO across the flush
        }
        assert_eq!(stats[0].bytes_sent.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn blocking_recv_flushes_pending_batches() {
        let net: Network<u32> = Network::new(2, NetworkModel::default());
        let mut eps = net.into_endpoints();
        let mut ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        ep0.set_autobatch(true);
        ep0.send(1, 7);
        // The send is still coalescing; ep0's blocking receive must push
        // it out before waiting, or this ping-pong would deadlock.
        let h = std::thread::spawn(move || {
            let r = ep1.recv_timeout(Duration::from_secs(5)).unwrap();
            ep1.send(0, r.msg + 1);
        });
        let r = ep0.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.msg, 8);
        h.join().unwrap();
    }

    #[test]
    fn tcp_loopback_delivers_typed_messages_with_accounting() {
        // The same framing-layer semantics over real loopback sockets.
        type M = (u32, Vec<u8>, Option<String>);
        let net: Network<M> = Network::tcp_loopback(2).unwrap();
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        let msg: M = (9, vec![1, 2, 3, 4], Some("over tcp".into()));
        eps[0].send(1, msg.clone());
        eps[0].send(1, (0, vec![], None));
        let r1 = eps[1].recv_timeout(Duration::from_secs(5)).unwrap();
        let r2 = eps[1].recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!((r1.src, r1.msg), (0, msg.clone()));
        assert_eq!((r2.src, r2.msg), (0, (0, vec![], None))); // FIFO per peer
        assert_eq!(
            stats[0].bytes_sent.load(Ordering::Relaxed),
            frame_len(&msg) + frame_len(&(0u32, Vec::<u8>::new(), Option::<String>::None))
        );
        assert!(stats[1].bytes_recv.load(Ordering::Relaxed) > 0);
        assert!(eps[1].peer_errors().is_empty());
    }
}
