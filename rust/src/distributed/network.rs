//! In-process message-passing network with **real encoded frames**, byte
//! accounting, and injected latency.
//!
//! Machines communicate only through [`Endpoint`]s (mpsc channels), which
//! preserves the FIFO-per-channel property of the paper's TCP sockets —
//! the ordering guarantee the ghost-coherence and lock protocols rely on.
//! Every send serializes its message through the [`Wire`] codec into a
//! length-prefixed frame; the frame's encoded length is what lands in the
//! per-machine [`NetStats`] (Fig. 6(b) plots these), and the receiver
//! decodes the frame back — so the byte counters are measurements of real
//! serialization, not size models. Self-sends skip the frame copy (the
//! value is delivered in-memory) but still run the encoder, so every
//! message pays the same measurement path; they account zero *network*
//! bytes, as before. A [`NetworkModel`] latency delays *delivery* (not
//! send), emulating one-way network latency for the Fig. 8(b)
//! lock-pipelining experiment.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::partition::MachineId;
use crate::wire::Wire;

/// Per-machine traffic counters (all byte counts are encoded frame
/// lengths, including the 4-byte length prefix).
#[derive(Default)]
pub struct NetStats {
    /// Frame bytes sent by this machine to other machines.
    pub bytes_sent: AtomicU64,
    /// Messages sent by this machine to other machines.
    pub msgs_sent: AtomicU64,
    /// Frame bytes received from other machines.
    pub bytes_recv: AtomicU64,
    /// Messages received from other machines.
    pub msgs_recv: AtomicU64,
}

/// Network shape parameters.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// One-way delivery latency injected at the receiver.
    pub latency: Duration,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            latency: Duration::ZERO,
        }
    }
}

/// What travels down the channel: remote messages go as encoded frames
/// (decoded by the receiver), self-sends skip the copy.
enum Payload<M> {
    /// The un-serialized value (self-send fast path).
    Inline(M),
    /// `[u32 len][payload]` frame, decoded on receipt.
    Frame(Vec<u8>),
}

struct EnvelopeInner<M> {
    src: MachineId,
    /// Frame bytes accounted at the receiver (0 for self-sends).
    bytes: u64,
    deliver_at: Instant,
    payload: Payload<M>,
}

/// Construction handle: build one, split into per-machine endpoints.
pub struct Network<M> {
    endpoints: Vec<Endpoint<M>>,
}

/// One machine's connection to the cluster.
pub struct Endpoint<M> {
    me: MachineId,
    machines: usize,
    senders: Vec<mpsc::Sender<EnvelopeInner<M>>>,
    rx: mpsc::Receiver<EnvelopeInner<M>>,
    /// Messages received from the channel but not yet deliverable
    /// (latency hold-back queue; FIFO order preserved).
    pending: VecDeque<EnvelopeInner<M>>,
    stats: Arc<Vec<NetStats>>,
    model: NetworkModel,
}

impl<M: Send + Wire> Network<M> {
    /// Create a fully-connected network of `machines` endpoints.
    pub fn new(machines: usize, model: NetworkModel) -> Self {
        let stats: Arc<Vec<NetStats>> =
            Arc::new((0..machines).map(|_| NetStats::default()).collect());
        let mut senders = Vec::with_capacity(machines);
        let mut receivers = Vec::with_capacity(machines);
        for _ in 0..machines {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let endpoints = receivers
            .into_iter()
            .enumerate()
            .map(|(me, rx)| Endpoint {
                me,
                machines,
                senders: senders.clone(),
                rx,
                pending: VecDeque::new(),
                stats: stats.clone(),
                model,
            })
            .collect();
        Network { endpoints }
    }

    /// Split into the per-machine endpoints (index = machine id).
    pub fn into_endpoints(self) -> Vec<Endpoint<M>> {
        self.endpoints
    }

    /// Shared stats handle (read by the harness after the run).
    pub fn stats(&self) -> Arc<Vec<NetStats>> {
        self.endpoints[0].stats.clone()
    }
}

/// Received message with its source.
pub struct Received<M> {
    /// Sender machine.
    pub src: MachineId,
    /// The message.
    pub msg: M,
}

impl<M: Send + Wire> Endpoint<M> {
    /// This machine's id.
    pub fn me(&self) -> MachineId {
        self.me
    }

    /// Cluster size.
    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Shared stats handle.
    pub fn stats(&self) -> Arc<Vec<NetStats>> {
        self.stats.clone()
    }

    /// Serialize `msg` into a frame and send it to `dst`. The frame's
    /// encoded length (payload + 4-byte length prefix) is recorded in
    /// [`NetStats`].
    ///
    /// Sending to self is allowed and delivered through the same path
    /// (simplifies engine loops); it still encodes — parity with remote
    /// accounting — but skips the frame copy and counts zero network
    /// bytes (nothing crosses the wire).
    pub fn send(&self, dst: MachineId, msg: M) {
        let mut frame = Vec::with_capacity(64);
        frame.extend_from_slice(&[0u8; 4]);
        msg.encode(&mut frame);
        let payload_len = (frame.len() - 4) as u32;
        frame[..4].copy_from_slice(&payload_len.to_le_bytes());
        let s = &self.stats[self.me];
        let (bytes, payload) = if dst == self.me {
            (0, Payload::Inline(msg))
        } else {
            let wire = frame.len() as u64;
            s.bytes_sent.fetch_add(wire, Ordering::Relaxed);
            s.msgs_sent.fetch_add(1, Ordering::Relaxed);
            (wire, Payload::Frame(frame))
        };
        let deliver_at = if dst == self.me {
            Instant::now()
        } else {
            Instant::now() + self.model.latency
        };
        // Receiver may have exited (engine shutdown); drop silently then.
        let _ = self.senders[dst].send(EnvelopeInner {
            src: self.me,
            bytes,
            deliver_at,
            payload,
        });
    }

    fn open(&self, env: EnvelopeInner<M>) -> Received<M> {
        let s = &self.stats[self.me];
        s.bytes_recv.fetch_add(env.bytes, Ordering::Relaxed);
        s.msgs_recv
            .fetch_add((env.src != self.me) as u64, Ordering::Relaxed);
        let msg = match env.payload {
            Payload::Inline(m) => m,
            Payload::Frame(buf) => {
                let mut slice = &buf[4..];
                let m = M::decode(&mut slice)
                    .expect("wire: frame decode failed (codec bug — encode/decode disagree)");
                debug_assert!(slice.is_empty(), "wire: frame has trailing bytes");
                m
            }
        };
        Received { src: env.src, msg }
    }

    /// Non-blocking receive honoring delivery latency.
    pub fn try_recv(&mut self) -> Option<Received<M>> {
        // Pull everything currently in the channel into the hold-back queue.
        while let Ok(env) = self.rx.try_recv() {
            self.pending.push_back(env);
        }
        if let Some(front) = self.pending.front() {
            if front.deliver_at <= Instant::now() {
                let env = self.pending.pop_front().unwrap();
                return Some(self.open(env));
            }
        }
        None
    }

    /// Blocking receive with timeout, honoring delivery latency.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<Received<M>> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(r) = self.try_recv() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            // Sleep until the earliest of: held-back delivery time, deadline,
            // or a short poll for new channel arrivals.
            let mut wait = deadline - now;
            if let Some(front) = self.pending.front() {
                let until = front.deliver_at.saturating_duration_since(now);
                wait = wait.min(until);
            } else {
                match self.rx.recv_timeout(wait.min(Duration::from_millis(1))) {
                    Ok(env) => {
                        self.pending.push_back(env);
                        continue;
                    }
                    Err(_) => continue,
                }
            }
            if !wait.is_zero() {
                std::thread::sleep(wait.min(Duration::from_millis(1)));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Encoded frame length of one message (length prefix + payload).
    fn frame_len<M: Wire>(msg: &M) -> u64 {
        4 + crate::wire::encoded_len(msg) as u64
    }

    #[test]
    fn point_to_point_delivery_and_accounting() {
        let net: Network<(u32, Vec<u8>)> = Network::new(3, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        let m1 = (7u32, vec![1u8, 2, 3]);
        let m2 = (8u32, Vec::new());
        let expect = frame_len(&m1) + frame_len(&m2);
        eps[0].send(2, m1.clone());
        eps[0].send(2, m2.clone());
        let r1 = eps[2].recv_timeout(Duration::from_secs(1)).unwrap();
        let r2 = eps[2].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!((r1.src, r1.msg), (0, m1));
        assert_eq!((r2.src, r2.msg), (0, m2)); // FIFO per channel
        // Bytes counted are the encoded frame lengths, at both ends.
        assert_eq!(stats[0].bytes_sent.load(Ordering::Relaxed), expect);
        assert_eq!(stats[2].bytes_recv.load(Ordering::Relaxed), expect);
        assert_eq!(stats[2].msgs_recv.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn self_send_costs_no_network_bytes() {
        let net: Network<u32> = Network::new(1, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        eps[0].send(0, 1);
        let r = eps[0].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r.msg, 1);
        assert_eq!(stats[0].bytes_sent.load(Ordering::Relaxed), 0);
        assert_eq!(stats[0].msgs_sent.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn latency_delays_delivery() {
        let net: Network<u32> = Network::new(2, NetworkModel {
            latency: Duration::from_millis(30),
        });
        let mut eps = net.into_endpoints();
        let t0 = Instant::now();
        eps[0].send(1, 42);
        let r = eps[1].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r.msg, 42);
        assert!(
            t0.elapsed() >= Duration::from_millis(28),
            "delivered after {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn cross_thread_usage() {
        let net: Network<u64> = Network::new(4, NetworkModel::default());
        let eps = net.into_endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                std::thread::spawn(move || {
                    // Everyone sends its id to everyone else, then sums
                    // what it receives.
                    for d in 0..ep.machines() {
                        if d != ep.me() {
                            ep.send(d, ep.me() as u64);
                        }
                    }
                    let mut sum = 0;
                    for _ in 0..ep.machines() - 1 {
                        sum += ep.recv_timeout(Duration::from_secs(5)).unwrap().msg;
                    }
                    sum
                })
            })
            .collect();
        let sums: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Machine m receives 0+1+2+3 - m.
        for (m, s) in sums.iter().enumerate() {
            assert_eq!(*s, 6 - m as u64);
        }
    }

    #[test]
    fn structured_message_survives_the_frame() {
        // A message shaped like the engines' protocol frames: enum-free
        // but nested (Vec of tuples + Option + String).
        type M = (Vec<(u32, u64, f32)>, Option<(String, Vec<f64>)>);
        let msg: M = (
            vec![(1, 2, 3.5), (4, 5, -0.25)],
            Some(("total_rank".to_string(), vec![1.0, 2.0])),
        );
        let net: Network<M> = Network::new(2, NetworkModel::default());
        let stats = net.stats();
        let mut eps = net.into_endpoints();
        eps[0].send(1, msg.clone());
        let r = eps[1].recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(r.msg, msg);
        assert_eq!(
            stats[0].bytes_sent.load(Ordering::Relaxed),
            frame_len(&msg)
        );
    }
}
