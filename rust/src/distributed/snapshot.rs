//! Chandy–Lamport **distributed snapshots** and crash recovery (paper
//! Sec. 4.3).
//!
//! The paper expresses its asynchronous snapshot as a vertex-program-like
//! protocol: a machine that starts (or first hears about) snapshot epoch
//! `e` immediately records its local state, then emits a *token* (a
//! marker message) on every outbound channel. Channels are FIFO, so
//! everything a peer sent *before* its token belongs to the cut and is
//! recorded as channel state; everything after it belongs to the next
//! epoch. Once a machine has received tokens from every peer, its part of
//! the cut is final and is committed to disk.
//!
//! # On-disk layout
//!
//! A snapshot lives in a `snapshot_<epoch>/` directory next to the atom
//! store, one file per machine (`machine_<m>.bin`), each reusing the atom
//! store's journal conventions: a `magic + WIRE_VERSION` header
//! ([`SNAP_MAGIC`]) followed by [`Wire`]-encoded records. Files are
//! written to a temp name and committed with an atomic `rename`, so a
//! torn file is never observable under its committed name; a crash
//! between machines' commits leaves the directory *incomplete*, which
//! [`latest_complete`] skips and [`load`] reports as a typed error —
//! never a panic.
//!
//! # Recovery
//!
//! Restore replays the atom journals (the PR-4 load path rebuilds every
//! machine's [`LocalGraph`] at version 0), then [`overlay`]s the newest
//! complete snapshot: each machine applies every record it holds locally,
//! gated on the recorded version being newer than what it has. Owner
//! records therefore refresh both the owner copy and every ghost of a
//! vertex, and the recorded in-flight channel writes land idempotently
//! (a record that lost the version race is already covered by a newer
//! one). The result is exactly the consistent cut the tokens delimited.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _};

use crate::distributed::localgraph::LocalGraph;
use crate::graph::{EdgeId, VertexId};
use crate::partition::atoms::check_header;
use crate::partition::MachineId;
use crate::wire::{self, Wire, WIRE_VERSION};

/// Snapshot-file magic (`"GLSN"`, little-endian), sharing the atom
/// store's header grammar.
pub const SNAP_MAGIC: u32 = u32::from_le_bytes(*b"GLSN");

/// When the snapshot leader cuts a new epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotTrigger {
    /// Cut after this many updates since the previous cut.
    Updates(u64),
    /// Cut after this much wall-clock time since the previous cut.
    Interval(Duration),
}

impl SnapshotTrigger {
    /// Parse the `--snapshot-every` argument: a bare integer is an
    /// update count, an integer with an `s` suffix is seconds.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if let Some(secs) = s.strip_suffix('s') {
            let secs: u64 = secs.parse().with_context(|| {
                format!(
                    "--snapshot-every: bad seconds value '{s}' \
                     (accepted forms: 'K' updates, e.g. 50000, or 'Ns' seconds, e.g. 10s)"
                )
            })?;
            anyhow::ensure!(
                secs > 0,
                "--snapshot-every: interval must be positive (accepted forms: 'K' | 'Ns')"
            );
            Ok(SnapshotTrigger::Interval(Duration::from_secs(secs)))
        } else {
            let k: u64 = s.parse().with_context(|| {
                format!(
                    "--snapshot-every: unrecognized value '{s}' \
                     (accepted forms: 'K' updates, e.g. 50000, or 'Ns' seconds, e.g. 10s)"
                )
            })?;
            anyhow::ensure!(
                k > 0,
                "--snapshot-every: update count must be positive (accepted forms: 'K' | 'Ns')"
            );
            Ok(SnapshotTrigger::Updates(k))
        }
    }
}

impl std::fmt::Display for SnapshotTrigger {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotTrigger::Updates(k) => write!(f, "{k} updates"),
            SnapshotTrigger::Interval(d) => write!(f, "{}s", d.as_secs()),
        }
    }
}

/// Where and how often a run snapshots (threaded from the [`Engine`]
/// builder into both distributed engines).
///
/// [`Engine`]: crate::engine::Engine
#[derive(Debug, Clone)]
pub struct SnapshotCfg {
    /// Directory the `snapshot_<epoch>/` directories are created in
    /// (normally the atom-store directory).
    pub root: PathBuf,
    /// When the leader cuts a new epoch.
    pub trigger: SnapshotTrigger,
}

/// The merged records of one complete snapshot.
pub struct SnapshotData<V, E> {
    /// The snapshot epoch.
    pub epoch: u64,
    /// How many machines cut this snapshot.
    pub machines: usize,
    /// Recorded vertex copies: `(global id, version, data)`.
    pub verts: Vec<(VertexId, u64, V)>,
    /// Recorded edge copies: `(global edge id, version, data)`.
    pub edges: Vec<(EdgeId, u64, E)>,
}

fn dir_name(epoch: u64) -> String {
    format!("snapshot_{epoch}")
}

fn machine_file(m: MachineId) -> String {
    format!("machine_{m}.bin")
}

/// Snapshot epochs present under `root` (complete or torn), unsorted.
fn epochs_under(root: &Path) -> Vec<u64> {
    let Ok(entries) = std::fs::read_dir(root) else {
        return Vec::new();
    };
    entries
        .flatten()
        .filter_map(|e| {
            e.file_name()
                .to_str()
                .and_then(|n| n.strip_prefix("snapshot_"))
                .and_then(|n| n.parse::<u64>().ok())
        })
        .collect()
}

/// The epoch the next snapshot under `root` must use: one above anything
/// already on disk (complete or torn), so a restarted run never collides
/// with its predecessor's directories.
pub fn next_epoch(root: &Path) -> u64 {
    epochs_under(root).into_iter().max().unwrap_or(0) + 1
}

/// Write machine `me`'s part of snapshot `epoch` under `root`,
/// committing with an atomic rename (a torn file is never visible under
/// its committed name).
pub fn write_machine<V: Wire, E: Wire>(
    root: &Path,
    epoch: u64,
    me: MachineId,
    machines: usize,
    verts: &[(VertexId, u64, V)],
    edges: &[(EdgeId, u64, E)],
) -> anyhow::Result<PathBuf> {
    let dir = root.join(dir_name(epoch));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating snapshot dir {}", dir.display()))?;
    let mut buf = Vec::with_capacity(64 + verts.len() * 16 + edges.len() * 16);
    SNAP_MAGIC.encode(&mut buf);
    WIRE_VERSION.encode(&mut buf);
    epoch.encode(&mut buf);
    (me as u32).encode(&mut buf);
    (machines as u32).encode(&mut buf);
    (verts.len() as u32).encode(&mut buf);
    for (v, ver, data) in verts {
        v.encode(&mut buf);
        ver.encode(&mut buf);
        data.encode(&mut buf);
    }
    (edges.len() as u32).encode(&mut buf);
    for (e, ver, data) in edges {
        e.encode(&mut buf);
        ver.encode(&mut buf);
        data.encode(&mut buf);
    }
    let committed = dir.join(machine_file(me));
    let tmp = dir.join(format!("machine_{me}.bin.tmp"));
    std::fs::write(&tmp, &buf)
        .with_context(|| format!("writing snapshot part {}", tmp.display()))?;
    std::fs::rename(&tmp, &committed)
        .with_context(|| format!("committing snapshot part {}", committed.display()))?;
    Ok(committed)
}

struct MachinePart<V, E> {
    epoch: u64,
    me: u32,
    machines: u32,
    verts: Vec<(VertexId, u64, V)>,
    edges: Vec<(EdgeId, u64, E)>,
}

fn decode_part<V: Wire, E: Wire>(input: &mut &[u8]) -> wire::Result<MachinePart<V, E>> {
    Ok(MachinePart {
        epoch: u64::decode(input)?,
        me: u32::decode(input)?,
        machines: u32::decode(input)?,
        verts: Vec::decode(input)?,
        edges: Vec::decode(input)?,
    })
}

fn read_machine_file<V: Wire, E: Wire>(path: &Path) -> anyhow::Result<MachinePart<V, E>> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading snapshot part {}", path.display()))?;
    let mut input = &buf[..];
    check_header(&mut input, SNAP_MAGIC, path)?;
    let part = decode_part::<V, E>(&mut input)
        .with_context(|| format!("{}: truncated or corrupt snapshot part", path.display()))?;
    if !input.is_empty() {
        bail!(
            "{}: {} trailing bytes after snapshot records",
            path.display(),
            input.len()
        );
    }
    Ok(part)
}

/// Load one `snapshot_<epoch>/` directory. Incomplete (missing machine
/// parts), truncated, or corrupt snapshots are typed errors — never
/// panics.
pub fn load<V: Wire, E: Wire>(dir: &Path) -> anyhow::Result<SnapshotData<V, E>> {
    let first = dir.join(machine_file(0));
    if !first.exists() {
        bail!(
            "{}: incomplete snapshot (missing {})",
            dir.display(),
            machine_file(0)
        );
    }
    let part0 = read_machine_file::<V, E>(&first)?;
    if part0.me != 0 {
        bail!("{}: holds machine {}, expected 0", first.display(), part0.me);
    }
    let machines = part0.machines as usize;
    if machines == 0 {
        bail!("{}: snapshot claims zero machines", first.display());
    }
    let mut data = SnapshotData {
        epoch: part0.epoch,
        machines,
        verts: part0.verts,
        edges: part0.edges,
    };
    for m in 1..machines {
        let path = dir.join(machine_file(m));
        if !path.exists() {
            bail!(
                "{}: incomplete snapshot (missing {})",
                dir.display(),
                machine_file(m)
            );
        }
        let part = read_machine_file::<V, E>(&path)?;
        if part.epoch != data.epoch || part.machines as usize != machines || part.me as usize != m
        {
            bail!(
                "{}: inconsistent snapshot part (epoch {} of {} machines, holds machine {}; \
                 expected epoch {} of {machines} machines, machine {m})",
                path.display(),
                part.epoch,
                part.machines,
                part.me,
                data.epoch
            );
        }
        data.verts.extend(part.verts);
        data.edges.extend(part.edges);
    }
    Ok(data)
}

/// The newest *complete* snapshot under `root`: scan `snapshot_<epoch>/`
/// directories in descending epoch order and return the first that loads
/// cleanly. Torn directories — the expected debris of a crash mid-cut —
/// are skipped, not errors; `Ok(None)` means nothing restorable exists.
pub fn latest_complete<V: Wire, E: Wire>(
    root: &Path,
) -> anyhow::Result<Option<SnapshotData<V, E>>> {
    let mut epochs = epochs_under(root);
    epochs.sort_unstable_by(|a, b| b.cmp(a));
    for epoch in epochs {
        if let Ok(data) = load::<V, E>(&root.join(dir_name(epoch))) {
            return Ok(Some(data));
        }
    }
    Ok(None)
}

/// Apply a snapshot to one machine's freshly-built local graph: every
/// record the machine holds locally (owned or ghost) lands if its
/// recorded version is newer than the local copy's. Order-independent:
/// the highest version wins regardless of which machine's part supplied
/// it.
pub fn overlay<V: Clone, E: Clone>(lg: &mut LocalGraph<V, E>, snap: &SnapshotData<V, E>) {
    for (v, ver, data) in &snap.verts {
        if let Some(&lv) = lg.g2l.get(v) {
            if *ver > lg.vversion[lv as usize] {
                lg.vdata[lv as usize] = data.clone();
                lg.vversion[lv as usize] = *ver;
            }
        }
    }
    for (e, ver, data) in &snap.edges {
        if let Some(&le) = lg.ge2l.get(e) {
            if *ver > lg.eversion[le as usize] {
                lg.edata[le as usize] = data.clone();
                lg.eversion[le as usize] = *ver;
            }
        }
    }
}

/// Record every local copy a [`LocalGraph`] still holding its data makes
/// — the "own state" half of a cut for callers that did not move the
/// data into engine-private stores.
pub(crate) fn record_from_graph<V: Clone, E: Clone>(
    lg: &LocalGraph<V, E>,
    verts: &mut Vec<(VertexId, u64, V)>,
    edges: &mut Vec<(EdgeId, u64, E)>,
) {
    verts.reserve(lg.l2g.len());
    for (i, &gv) in lg.l2g.iter().enumerate() {
        verts.push((gv, lg.vversion[i], lg.vdata[i].clone()));
    }
    edges.reserve(lg.le2g.len());
    for (i, &ge) in lg.le2g.iter().enumerate() {
        edges.push((ge, lg.eversion[i], lg.edata[i].clone()));
    }
}

/// One machine's view of the token protocol, owned by its engine loop.
///
/// The engine calls [`SnapshotSession::due`] + [`SnapshotSession::begin`]
/// on the leader to initiate a cut, [`SnapshotSession::on_token`] for
/// every snapshot-token message, and
/// [`SnapshotSession::record_vertex`]/[`record_edge`] when applying a
/// remote write that might be channel state. Both `begin` and `on_token`
/// take a `record` closure that appends the machine's current local
/// state (owned + ghosts), because each engine keeps that state in its
/// own shape ([`record_from_graph`] covers the plain-`LocalGraph` case).
/// The session commits its machine file the moment the last peer token
/// arrives.
///
/// [`record_edge`]: SnapshotSession::record_edge
pub(crate) struct SnapshotSession<V, E> {
    root: PathBuf,
    trigger: SnapshotTrigger,
    me: MachineId,
    machines: usize,
    /// The epoch currently being recorded, if any.
    active: Option<u64>,
    /// Peers whose token for the active epoch is still outstanding.
    pending: Vec<bool>,
    pending_count: usize,
    verts: Vec<(VertexId, u64, V)>,
    edges: Vec<(EdgeId, u64, E)>,
    /// Highest epoch started or heard of (tokens below this are stale).
    highest_seen: u64,
    last_cut_updates: u64,
    last_cut_at: Instant,
    /// Cuts this machine committed to disk (diagnostics).
    pub committed: u64,
}

impl<V: Clone + Wire, E: Clone + Wire> SnapshotSession<V, E> {
    pub fn new(cfg: &SnapshotCfg, me: MachineId, machines: usize) -> Self {
        SnapshotSession {
            root: cfg.root.clone(),
            trigger: cfg.trigger,
            me,
            machines,
            active: None,
            pending: vec![false; machines],
            pending_count: 0,
            verts: Vec::new(),
            edges: Vec::new(),
            // Resume numbering above anything already on disk so a
            // restarted run never overwrites its predecessor's cuts.
            highest_seen: next_epoch(&cfg.root).saturating_sub(1),
            last_cut_updates: 0,
            last_cut_at: Instant::now(),
            committed: 0,
        }
    }

    /// Leader-side trigger check: is a new cut due, given the updates
    /// completed so far? (Never true while a cut is in flight.)
    pub fn due(&self, updates_done: u64) -> bool {
        if self.active.is_some() {
            return false;
        }
        match self.trigger {
            SnapshotTrigger::Updates(k) => {
                updates_done.saturating_sub(self.last_cut_updates) >= k
            }
            SnapshotTrigger::Interval(d) => self.last_cut_at.elapsed() >= d,
        }
    }

    /// Initiate a cut: record local state now (via `record`) and return
    /// the epoch whose token the caller must send on every outbound
    /// channel.
    pub fn begin<F>(&mut self, updates_done: u64, record: F) -> anyhow::Result<u64>
    where
        F: FnOnce(&mut Vec<(VertexId, u64, V)>, &mut Vec<(EdgeId, u64, E)>),
    {
        let epoch = self.highest_seen + 1;
        self.start(epoch, record)?;
        self.last_cut_updates = updates_done;
        self.last_cut_at = Instant::now();
        Ok(epoch)
    }

    fn start<F>(&mut self, epoch: u64, record: F) -> anyhow::Result<()>
    where
        F: FnOnce(&mut Vec<(VertexId, u64, V)>, &mut Vec<(EdgeId, u64, E)>),
    {
        self.highest_seen = epoch;
        self.active = Some(epoch);
        self.pending = vec![true; self.machines];
        self.pending[self.me] = false;
        self.pending_count = self.machines - 1;
        self.verts.clear();
        self.edges.clear();
        record(&mut self.verts, &mut self.edges);
        if self.pending_count == 0 {
            self.commit()?;
        }
        Ok(())
    }

    /// Handle a token from `src` for `epoch`. `Ok(true)` means a cut just
    /// started at this machine and the caller must broadcast the token on
    /// every outbound channel (the Chandy–Lamport marker rule).
    pub fn on_token<F>(&mut self, src: MachineId, epoch: u64, record: F) -> anyhow::Result<bool>
    where
        F: FnOnce(&mut Vec<(VertexId, u64, V)>, &mut Vec<(EdgeId, u64, E)>),
    {
        match self.active {
            Some(e) if epoch == e => {
                self.clear_pending(src)?;
                Ok(false)
            }
            Some(e) if epoch < e => Ok(false), // stale: a cut we already superseded
            None if epoch <= self.highest_seen => Ok(false), // stale: already committed
            _ => {
                // First token of a new epoch (possibly abandoning an
                // older in-flight cut — never committed here, so its
                // directory stays incomplete and restore skips it).
                self.start(epoch, record)?;
                self.clear_pending(src)?;
                Ok(true)
            }
        }
    }

    fn clear_pending(&mut self, src: MachineId) -> anyhow::Result<()> {
        if self.active.is_some() && self.pending[src] {
            self.pending[src] = false;
            self.pending_count -= 1;
            if self.pending_count == 0 {
                self.commit()?;
            }
        }
        Ok(())
    }

    /// Whether in-flight writes from `src` are still channel state of the
    /// active cut (i.e. `src`'s token has not arrived yet).
    pub fn recording_from(&self, src: MachineId) -> bool {
        self.active.is_some() && self.pending[src]
    }

    /// Record an in-flight remote vertex write as channel state. The
    /// caller guards with [`SnapshotSession::recording_from`].
    pub fn record_vertex(&mut self, v: VertexId, ver: u64, data: &V) {
        self.verts.push((v, ver, data.clone()));
    }

    /// Record an in-flight remote edge write as channel state.
    pub fn record_edge(&mut self, e: EdgeId, ver: u64, data: &E) {
        self.edges.push((e, ver, data.clone()));
    }

    fn commit(&mut self) -> anyhow::Result<()> {
        let epoch = self
            .active
            .take()
            .expect("snapshot commit without an active cut");
        write_machine(
            &self.root,
            epoch,
            self.me,
            self.machines,
            &self.verts,
            &self.edges,
        )?;
        self.verts.clear();
        self.edges.clear();
        self.committed += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::partition::Partition;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "graphlab-snap-{name}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn trigger_parses_updates_and_seconds() {
        assert_eq!(
            SnapshotTrigger::parse("500").unwrap(),
            SnapshotTrigger::Updates(500)
        );
        assert_eq!(
            SnapshotTrigger::parse("5s").unwrap(),
            SnapshotTrigger::Interval(Duration::from_secs(5))
        );
        for bad in ["", "0", "0s", "-3", "5m", "s"] {
            assert!(SnapshotTrigger::parse(bad).is_err(), "'{bad}' should not parse");
        }
        // The diagnostic must teach the accepted grammar ('K' | 'Ns').
        let why = format!("{:#}", SnapshotTrigger::parse("5m").unwrap_err());
        assert!(why.contains("accepted forms"), "unhelpful error: {why}");
        assert!(why.contains("'Ns'") || why.contains("10s"), "grammar not named: {why}");
    }

    #[test]
    fn write_load_round_trip_merges_machine_parts() {
        let root = tmp("roundtrip");
        write_machine::<u32, u32>(&root, 3, 0, 2, &[(0, 1, 10), (1, 2, 20)], &[(0, 1, 7)])
            .unwrap();
        write_machine::<u32, u32>(&root, 3, 1, 2, &[(2, 5, 30)], &[]).unwrap();
        let snap = load::<u32, u32>(&root.join("snapshot_3")).unwrap();
        assert_eq!((snap.epoch, snap.machines), (3, 2));
        assert_eq!(snap.verts.len(), 3);
        assert_eq!(snap.edges, vec![(0, 1, 7)]);
        assert_eq!(next_epoch(&root), 4);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn torn_snapshots_are_typed_errors_and_skipped_by_discovery() {
        let root = tmp("torn");
        // Epoch 1: complete and loadable.
        write_machine::<u32, u32>(&root, 1, 0, 1, &[(0, 1, 99)], &[]).unwrap();
        // Epoch 2: missing machine 1's part.
        write_machine::<u32, u32>(&root, 2, 0, 2, &[(0, 7, 1)], &[]).unwrap();
        // Epoch 3: machine 0's part truncated mid-record.
        let p3 = write_machine::<u32, u32>(&root, 3, 0, 1, &[(0, 9, 5)], &[]).unwrap();
        let bytes = std::fs::read(&p3).unwrap();
        std::fs::write(&p3, &bytes[..bytes.len() - 3]).unwrap();
        // Epoch 4: garbage magic.
        let d4 = root.join("snapshot_4");
        std::fs::create_dir_all(&d4).unwrap();
        std::fs::write(d4.join("machine_0.bin"), b"not a snapshot").unwrap();

        for epoch in [2u64, 3, 4] {
            let err = load::<u32, u32>(&root.join(format!("snapshot_{epoch}")));
            assert!(err.is_err(), "epoch {epoch} should be a typed error");
        }
        // Discovery skips every torn epoch and lands on the complete one.
        let best = latest_complete::<u32, u32>(&root).unwrap().unwrap();
        assert_eq!(best.epoch, 1);
        assert_eq!(best.verts, vec![(0, 1, 99)]);
        // And numbering still resumes above the torn debris.
        assert_eq!(next_epoch(&root), 5);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn empty_root_has_no_restorable_snapshot() {
        let root = tmp("empty");
        assert!(latest_complete::<u32, u32>(&root).unwrap().is_none());
        assert!(latest_complete::<u32, u32>(&root.join("absent")).unwrap().is_none());
        assert_eq!(next_epoch(&root), 1);
        std::fs::remove_dir_all(&root).ok();
    }

    /// 2-machine path graph for session tests.
    fn locals() -> Vec<LocalGraph<u32, u32>> {
        let mut b = GraphBuilder::new();
        b.add_vertices(4, |i| i as u32);
        for i in 0..3u32 {
            b.add_edge(i, i + 1, 100 + i);
        }
        let g = b.build();
        let part = Partition::from_assignment(vec![0, 0, 1, 1], 2);
        (0..2).map(|m| LocalGraph::build(&g, &part, m)).collect()
    }

    #[test]
    fn token_session_commits_when_all_tokens_arrive() {
        let root = tmp("session");
        let cfg = SnapshotCfg {
            root: root.clone(),
            trigger: SnapshotTrigger::Updates(10),
        };
        let mut lgs = locals();
        let mut s0: SnapshotSession<u32, u32> = SnapshotSession::new(&cfg, 0, 2);
        let mut s1: SnapshotSession<u32, u32> = SnapshotSession::new(&cfg, 1, 2);
        assert!(s0.due(10));
        let epoch = s0
            .begin(10, |vs, es| record_from_graph(&lgs[0], vs, es))
            .unwrap();
        assert_eq!(epoch, 1);
        assert!(!s0.due(10), "no overlapping cuts");
        // Machine 1 first hears of the cut via the token: records its
        // state, must broadcast.
        assert!(s1
            .on_token(0, epoch, |vs, es| record_from_graph(&lgs[1], vs, es))
            .unwrap());
        assert_eq!(s1.committed, 1, "2-machine cut completes on one token");
        // A write from machine 1 racing its token is channel state at 0.
        assert!(s0.recording_from(1));
        lgs[0].apply_vertex(2, 3, 777);
        s0.record_vertex(2, 3, &777);
        assert!(s0
            .on_token(1, epoch, |vs, es| record_from_graph(&lgs[0], vs, es))
            .is_ok());
        assert_eq!(s0.committed, 1);
        assert!(!s0.recording_from(1));
        // Both parts on disk: the snapshot is complete and carries the
        // channel-state record.
        let snap = load::<u32, u32>(&root.join("snapshot_1")).unwrap();
        assert!(snap.verts.iter().any(|&(v, ver, d)| (v, ver, d) == (2, 3, 777)));
        // Duplicate / stale tokens are ignored.
        assert!(!s0
            .on_token(1, epoch, |vs, es| record_from_graph(&lgs[0], vs, es))
            .unwrap());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn overlay_is_version_gated_and_order_independent() {
        let mut lgs = locals();
        let snap = SnapshotData {
            epoch: 1,
            machines: 2,
            verts: vec![(2, 1, 555), (2, 4, 999), (0, 0, 42)],
            edges: vec![(1, 2, 888)],
        };
        overlay(&mut lgs[1], &snap);
        let lv = lgs[1].g2l[&2] as usize;
        assert_eq!((lgs[1].vdata[lv], lgs[1].vversion[lv]), (999, 4));
        // Version-0 records never displace built state (data is the
        // initial value anyway); foreign vertices are ignored — vertex 0
        // is not local to machine 1.
        assert!(!lgs[1].g2l.contains_key(&0));
        let le = lgs[1].ge2l[&1] as usize;
        assert_eq!((lgs[1].edata[le], lgs[1].eversion[le]), (888, 2));
    }
}
