//! The **byte-level transport** under the framing layer: how encoded
//! frames move between machines.
//!
//! [`crate::distributed::network::Endpoint`] owns the codec and the byte
//! accounting; everything below it speaks only `[u32 len][payload]`
//! frames through the object-safe [`Transport`] trait. Two backends:
//!
//! * [`InProcTransport`] — the in-process cluster: one mpsc channel per
//!   machine carrying frames, with the [`NetworkModel`] latency applied as
//!   a delivery hold-back at the receiver. This is the default substrate
//!   for tests, figures, and single-host runs; a frame that fails to
//!   decode here is a *codec bug* (both ends are the same build), so the
//!   backend reports itself as [`Transport::trusted`].
//! * [`TcpTransport`] — real sockets (`std::net`, no external deps): a
//!   full mesh of loopback-or-LAN TCP connections, one listener per
//!   machine, a **handshake** on every connection carrying the sender's
//!   machine id, the wire version, the cluster size, and the application
//!   type tag (so a PageRank worker cannot join an ALS cluster), one
//!   **writer thread per peer** draining a frame queue, and **reader
//!   threads** feeding the shared receive queue. Frames from the network
//!   are *untrusted*: malformed input surfaces as a typed [`PeerError`]
//!   and a disconnect of that peer, never a process abort.
//!
//! Construction paths: [`tcp_loopback_mesh`] builds all `N` transports in
//! one process over real `127.0.0.1` sockets (the test/bench harness and
//! `--transport tcp`); [`TcpBound::bind`] + [`TcpBound::connect`] build
//! one machine's transport in its own process (the `graphlab worker` /
//! `run --cluster` path, the paper's actual deployment shape).
//!
//! A third piece is not a backend but a decorator: [`Faulty`] wraps any
//! transport with a deterministic [`FaultPlan`] (kill a machine after k
//! frames, drop/duplicate/delay frame n, sever one direction) so every
//! failure mode the snapshot/recovery layer must survive is reproducible
//! in-process, without real process kills.

use std::collections::VecDeque;
use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _};

use crate::partition::MachineId;
use crate::wire::{self, Wire, WireError, WIRE_VERSION};

/// Stable marker embedded in bind-failure errors when the cause is a
/// port collision (`EADDRINUSE`). Run supervisors — the experiment lab's
/// executor — grep child output for this exact string to decide that a
/// failed run is retryable rather than broken.
pub const PORT_CONFLICT_MARKER: &str = "port-conflict";

// ---------------------------------------------------------------------------
// Tuning constants
// ---------------------------------------------------------------------------
// Every magic number of the byte layer lives here, so the knobs that
// govern wire behavior are visible (and auditable) in one place instead
// of scattered through constructors and thread loops.

/// Default cap on a frame's length prefix: a garbage prefix from a
/// hostile or corrupted stream must not trigger a giant allocation.
/// Carried per-connection in [`TcpConfig::max_frame`].
pub const DEFAULT_MAX_FRAME: u32 = 256 << 20;

/// Default window for outbound connects / inbound accepts during mesh
/// formation (override with `GRAPHLAB_CONNECT_TIMEOUT_SECS` — manual
/// multi-host startups can easily take longer than any fixed default).
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Default peer-failure grace of the chromatic engine's barrier waits
/// (a sweep barrier legitimately waits for the slowest machine).
/// Overridable via `GRAPHLAB_PEER_GRACE_SECS`; see [`peer_grace`].
pub const CHROMATIC_GRACE: Duration = Duration::from_secs(30);

/// Default peer-failure grace of the locking engine's idle watchdog
/// (its pump makes progress continuously, so prolonged silence means a
/// dead peer much sooner than a barrier wait does). Overridable via
/// `GRAPHLAB_PEER_GRACE_SECS`; see [`peer_grace`].
pub const LOCKING_GRACE: Duration = Duration::from_secs(5);

/// Hard cap on the encoded connection handshake (type tags are short).
const MAX_HANDSHAKE: u32 = 4096;

/// The TCP writer coalesces at most this many queued frames into one
/// vectored write (the OS caps iovecs around 1024; staying far below
/// keeps each syscall cheap to assemble).
const COALESCE_MAX_FRAMES: usize = 64;

/// ... and at most this many payload bytes per coalesced write, so one
/// giant frame queued behind small ones does not balloon a batch.
const COALESCE_MAX_BYTES: usize = 1 << 20;

/// A [`FramePool`] keeps at most this many recycled buffers; overflow is
/// simply freed so a send burst cannot pin memory forever.
const POOL_MAX_BUFFERS: usize = 64;

/// Buffers that grew beyond this capacity are freed on return instead of
/// pooled — one huge ghost flush must not turn the pool into a cache of
/// multi-megabyte allocations.
const POOL_MAX_BUFFER_CAPACITY: usize = 4 << 20;

// ---------------------------------------------------------------------------
// Frame-buffer pool
// ---------------------------------------------------------------------------

/// A recycling pool of frame buffers shared between the framing layer's
/// send path and the transport's writer/reader threads. `Endpoint::send`
/// encodes into a pooled `Vec<u8>` instead of allocating; the TCP writer
/// returns buffers after the bytes are on the wire, and the framing
/// layer returns received buffers after decoding. Cheap to clone (one
/// `Arc`), safe to share across threads.
#[derive(Clone, Default)]
pub struct FramePool {
    free: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl FramePool {
    /// Pop a recycled buffer (empty, capacity retained) or allocate a
    /// fresh one.
    pub fn get(&self) -> Vec<u8> {
        self.free
            .lock()
            .ok()
            .and_then(|mut f| f.pop())
            .unwrap_or_default()
    }

    /// Return a buffer for reuse. Oversized buffers and overflow beyond
    /// [`POOL_MAX_BUFFERS`] are dropped (freed) rather than pooled.
    pub fn put(&self, mut buf: Vec<u8>) {
        if buf.capacity() > POOL_MAX_BUFFER_CAPACITY {
            return;
        }
        buf.clear();
        if let Ok(mut free) = self.free.lock() {
            if free.len() < POOL_MAX_BUFFERS {
                free.push(buf);
            }
        }
    }
}

/// Split a contiguous multi-frame buffer (`count` back-to-back
/// `[u32 len][payload]` frames) into its logical frames, each keeping its
/// length prefix. Used by [`Transport::send_frames`]'s default
/// implementation so backends (and decorators like [`Faulty`]) that have
/// no batched fast path observe exactly `count` ordinary sends.
pub(crate) fn split_frames(buf: &[u8], count: usize) -> Vec<Vec<u8>> {
    let mut out = Vec::with_capacity(count);
    let mut off = 0usize;
    for _ in 0..count {
        if off + 4 > buf.len() {
            break; // malformed batch: deliver what parses, drop the rest
        }
        let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
        let end = (off + 4 + len).min(buf.len());
        out.push(buf[off..end].to_vec());
        off = end;
    }
    out
}

/// Which byte-level substrate carries the frames of a distributed run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channels (one thread per machine, the default).
    InProc,
    /// Real TCP sockets — loopback full mesh in one process, or one
    /// socket endpoint per worker process in cluster mode.
    Tcp,
}

impl TransportKind {
    /// Parse a CLI name; unknown names are an error, not a panic.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "inproc" => TransportKind::InProc,
            "tcp" => TransportKind::Tcp,
            other => bail!("unknown transport '{other}' (inproc|tcp)"),
        })
    }

    /// The CLI name of this transport.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        TransportKind::parse(s)
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One process's place in a multi-process cluster: which machine it is
/// and where every machine listens (`host:port`, index = machine id).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// This process's machine id.
    pub me: MachineId,
    /// Listen addresses of all machines, index = machine id.
    pub hosts: Vec<String>,
}

/// Network shape parameters (the injected one-way delivery latency of the
/// in-process backend; the TCP backend has real wires and ignores it).
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    /// One-way delivery latency injected at the receiver (InProc only).
    pub latency: Duration,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            latency: Duration::ZERO,
        }
    }
}

/// A transport-level failure attributed to one peer.
#[derive(Debug, Clone)]
pub struct PeerError {
    /// The peer the failure is attributed to.
    pub peer: MachineId,
    /// What went wrong.
    pub error: FrameError,
}

impl std::fmt::Display for PeerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "peer {}: {}", self.peer, self.error)
    }
}

/// What can go wrong with a frame (or the stream carrying it) from an
/// untrusted peer.
#[derive(Debug, Clone)]
pub enum FrameError {
    /// The frame payload failed to decode as the expected message type.
    Decode(WireError),
    /// The frame decoded but left unconsumed payload bytes.
    Trailing {
        /// Leftover byte count.
        extra: usize,
    },
    /// The length prefix exceeded the frame-size cap.
    Oversized {
        /// Claimed payload length.
        len: u32,
        /// The configured cap.
        max: u32,
    },
    /// The stream died mid-frame (truncated input, reset, …).
    Io(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Decode(e) => write!(f, "frame decode failed: {e}"),
            FrameError::Trailing { extra } => {
                write!(f, "frame has {extra} trailing bytes")
            }
            FrameError::Oversized { len, max } => {
                write!(f, "frame length {len} exceeds cap {max}")
            }
            FrameError::Io(e) => write!(f, "stream error: {e}"),
        }
    }
}

/// The byte-level substrate under an `Endpoint`: moves opaque
/// `[u32 len][payload]` frames between machines. Object-safe; `send` is
/// `&self` (engines send while holding shared borrows), receive is
/// `&mut self` (each endpoint is owned by exactly one machine loop).
pub trait Transport: Send {
    /// This machine's id.
    fn me(&self) -> MachineId;

    /// Cluster size.
    fn machines(&self) -> usize;

    /// Queue `frame` for delivery to `dst`. Infallible by design: a peer
    /// that is gone (engine shutdown) swallows the frame, matching the
    /// "receiver may have exited" semantics engines already rely on.
    fn send_frame(&self, dst: MachineId, frame: Vec<u8>);

    /// Queue a contiguous buffer of `count` back-to-back
    /// `[u32 len][payload]` frames for delivery to `dst`. Semantically
    /// identical to `count` individual [`Transport::send_frame`] calls in
    /// order. The default implementation does exactly that — splitting
    /// the buffer at frame boundaries — which keeps decorators such as
    /// [`Faulty`] batching-invariant *by construction*: fault-plan frame
    /// indices always count logical frames, never coalesced writes.
    /// Backends with a batched fast path override this (TCP ships the
    /// whole buffer as one write; the receiver's read loop re-splits it).
    fn send_frames(&self, dst: MachineId, buf: Vec<u8>, count: usize) {
        if count <= 1 {
            if count == 1 {
                self.send_frame(dst, buf);
            }
            return;
        }
        for frame in split_frames(&buf, count) {
            self.send_frame(dst, frame);
        }
    }

    /// Attach the framing layer's [`FramePool`] so this backend can
    /// recycle frame buffers after use (the TCP writer returns written
    /// buffers; the TCP reader allocates incoming frames from it).
    /// Default: no-op — backends without internal buffering have nothing
    /// to recycle.
    fn install_pool(&mut self, _pool: &FramePool) {}

    /// Non-blocking receive: the next deliverable frame, if any.
    fn recv_frame(&mut self) -> Option<(MachineId, Vec<u8>)>;

    /// Blocking receive with timeout.
    fn recv_frame_timeout(&mut self, timeout: Duration) -> Option<(MachineId, Vec<u8>)>;

    /// Drain transport-level peer errors (stream failures, oversized
    /// frames). The framing layer adds its own decode errors on top.
    fn take_errors(&mut self) -> Vec<PeerError>;

    /// Whether frames are trusted: `true` for the in-process backend
    /// (both ends are the same build, so a decode failure is a local
    /// codec bug and panicking is the correct invariant), `false` for
    /// anything that crossed a process boundary.
    fn trusted(&self) -> bool;

    /// Which backend this is (for logs and stats labels).
    fn kind(&self) -> TransportKind;
}

// ---------------------------------------------------------------------------
// InProc backend
// ---------------------------------------------------------------------------

struct InProcEnvelope {
    src: MachineId,
    deliver_at: Instant,
    frame: Vec<u8>,
}

/// The in-process backend: today's mpsc channels carrying encoded frames,
/// with the [`NetworkModel`] latency applied as a delivery hold-back at
/// the receiver (FIFO order preserved).
pub struct InProcTransport {
    me: MachineId,
    machines: usize,
    senders: Vec<mpsc::Sender<InProcEnvelope>>,
    rx: mpsc::Receiver<InProcEnvelope>,
    /// Frames received from the channel but not yet deliverable.
    pending: VecDeque<InProcEnvelope>,
    latency: Duration,
}

impl InProcTransport {
    /// Build a fully-connected in-process mesh of `machines` transports.
    pub fn mesh(machines: usize, model: NetworkModel) -> Vec<InProcTransport> {
        let mut senders = Vec::with_capacity(machines);
        let mut receivers = Vec::with_capacity(machines);
        for _ in 0..machines {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            receivers.push(rx);
        }
        receivers
            .into_iter()
            .enumerate()
            .map(|(me, rx)| InProcTransport {
                me,
                machines,
                senders: senders.clone(),
                rx,
                pending: VecDeque::new(),
                latency: model.latency,
            })
            .collect()
    }

    /// Pull everything currently in the channel into the hold-back queue,
    /// then pop the front if its delivery time has arrived.
    fn pop_deliverable(&mut self) -> Option<(MachineId, Vec<u8>)> {
        while let Ok(env) = self.rx.try_recv() {
            self.pending.push_back(env);
        }
        if let Some(front) = self.pending.front() {
            if front.deliver_at <= Instant::now() {
                let env = self.pending.pop_front().unwrap();
                return Some((env.src, env.frame));
            }
        }
        None
    }
}

impl Transport for InProcTransport {
    fn me(&self) -> MachineId {
        self.me
    }

    fn machines(&self) -> usize {
        self.machines
    }

    fn send_frame(&self, dst: MachineId, frame: Vec<u8>) {
        // Receiver may have exited (engine shutdown); drop silently then.
        let _ = self.senders[dst].send(InProcEnvelope {
            src: self.me,
            deliver_at: Instant::now() + self.latency,
            frame,
        });
    }

    fn recv_frame(&mut self) -> Option<(MachineId, Vec<u8>)> {
        self.pop_deliverable()
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Option<(MachineId, Vec<u8>)> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(f) = self.pop_deliverable() {
                return Some(f);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let wait = deadline - now;
            if let Some(front) = self.pending.front() {
                // Held-back frame: sleep until the earliest of its delivery
                // time, the deadline, or a short poll for new arrivals.
                let until = front.deliver_at.saturating_duration_since(now);
                let nap = wait.min(until).min(Duration::from_millis(1));
                if !nap.is_zero() {
                    std::thread::sleep(nap);
                }
            } else {
                match self.rx.recv_timeout(wait.min(Duration::from_millis(1))) {
                    Ok(env) => self.pending.push_back(env),
                    Err(_) => continue,
                }
            }
        }
    }

    fn take_errors(&mut self) -> Vec<PeerError> {
        Vec::new()
    }

    fn trusted(&self) -> bool {
        true
    }

    fn kind(&self) -> TransportKind {
        TransportKind::InProc
    }
}

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

/// How long an engine tolerates silent or failed peers before aborting
/// the run with a typed error instead of hanging forever. Both distributed
/// engines read their grace window through this one helper, so the
/// `GRAPHLAB_PEER_GRACE_SECS` override governs every peer-failure abort
/// path (chromatic barrier timeouts, locking idle-grace) uniformly.
pub fn peer_grace(default: Duration) -> Duration {
    std::env::var("GRAPHLAB_PEER_GRACE_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&s| s > 0)
        .map(Duration::from_secs)
        .unwrap_or(default)
}

/// A deterministic schedule of injected transport faults. Frame indices
/// are 0-based counts of a machine's *cross-machine* outbound frames
/// (self-sends never reach the transport); a dropped, delayed, or killed
/// frame still consumes its index, so a plan replays identically on every
/// run of the same message schedule.
///
/// `kill` and `sever` preserve every engine-level invariant (frames are
/// only ever lost wholesale, exactly like a process death or a cut
/// cable), so they can be injected under a full engine run. `drop`,
/// `duplicate`, and `delay` break per-peer FIFO/exactly-once delivery —
/// they exercise the transport and protocol layers directly and are for
/// transport-level tests.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Label for logs and test diagnostics; the plan itself is
    /// deterministic by construction.
    pub seed: u64,
    /// Kill machine `.0` once it has sent `.1` frames: from then on it
    /// sends nothing and receives nothing (a simulated SIGKILL). Peers
    /// observe the death as a typed [`PeerError`] plus silence.
    pub kill: Option<(MachineId, u64)>,
    /// Silently drop the sender's `n`th outbound frame, per `(machine, n)`.
    pub drop: Vec<(MachineId, u64)>,
    /// Send the `n`th outbound frame twice.
    pub duplicate: Vec<(MachineId, u64)>,
    /// Hold the `n`th outbound frame for the given duration before
    /// handing it to the inner transport (released on the sender's next
    /// transport call after the hold elapses — a reordering fault).
    pub delay: Vec<(MachineId, u64, Duration)>,
    /// Silently discard every frame from `.0` to `.1` (one direction
    /// only; the reverse direction keeps flowing).
    pub sever: Vec<(MachineId, MachineId)>,
}

impl FaultPlan {
    /// The workhorse plan for crash-recovery tests: machine `machine`
    /// dies after sending `frames` frames.
    pub fn kill_at(machine: MachineId, frames: u64) -> Self {
        FaultPlan {
            kill: Some((machine, frames)),
            ..FaultPlan::default()
        }
    }

    /// Whether this plan injects anything at all.
    pub fn is_empty(&self) -> bool {
        self.kill.is_none()
            && self.drop.is_empty()
            && self.duplicate.is_empty()
            && self.delay.is_empty()
            && self.sever.is_empty()
    }
}

/// Cross-wrapper state for one faulty mesh: which machines have died, so
/// surviving machines can surface a typed error (mirroring how a real
/// peer death eventually surfaces as a stream error on TCP).
#[derive(Default)]
struct FaultShared {
    /// `(machine, frames it had sent when it died)`.
    killed: Mutex<Vec<(MachineId, u64)>>,
}

/// A [`Transport`] decorator that applies a [`FaultPlan`] to one
/// machine's frame stream. Wrap a whole in-process mesh with
/// [`Faulty::wrap_mesh`] (so peer deaths are observable as typed errors
/// across the mesh) or a single per-process transport with
/// [`Faulty::new`].
pub struct Faulty<T: Transport> {
    inner: T,
    plan: FaultPlan,
    /// Cross-machine outbound frames counted so far (fault indices).
    sent: AtomicU64,
    /// Set once the kill point is reached: no more sends or receives.
    dead: AtomicBool,
    /// Delayed frames awaiting their release time.
    held: Mutex<Vec<(Instant, MachineId, Vec<u8>)>>,
    shared: Arc<FaultShared>,
    /// Which peers' deaths this wrapper has already reported.
    reported: Vec<bool>,
}

impl<T: Transport> Faulty<T> {
    /// Wrap one transport. Peer kills in the plan still apply to *this*
    /// machine if it is the target; deaths of other machines are only
    /// observable as silence (use [`Faulty::wrap_mesh`] for typed
    /// cross-machine death reporting in one process).
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        let machines = inner.machines();
        Faulty {
            inner,
            plan,
            sent: AtomicU64::new(0),
            dead: AtomicBool::new(false),
            held: Mutex::new(Vec::new()),
            shared: Arc::new(FaultShared::default()),
            reported: vec![false; machines],
        }
    }

    /// Wrap every transport of an in-process mesh under one shared plan,
    /// so a machine's death surfaces as a typed [`PeerError`] at every
    /// surviving machine.
    pub fn wrap_mesh(inners: Vec<T>, plan: FaultPlan) -> Vec<Faulty<T>> {
        let shared = Arc::new(FaultShared::default());
        inners
            .into_iter()
            .map(|inner| {
                let machines = inner.machines();
                Faulty {
                    inner,
                    plan: plan.clone(),
                    sent: AtomicU64::new(0),
                    dead: AtomicBool::new(false),
                    held: Mutex::new(Vec::new()),
                    shared: shared.clone(),
                    reported: vec![false; machines],
                }
            })
            .collect()
    }

    /// Release delayed frames whose hold time has elapsed.
    fn flush_held(&self) {
        if let Ok(mut held) = self.held.lock() {
            let now = Instant::now();
            let mut i = 0;
            while i < held.len() {
                if held[i].0 <= now {
                    let (_, dst, frame) = held.remove(i);
                    self.inner.send_frame(dst, frame);
                } else {
                    i += 1;
                }
            }
        }
    }
}

impl<T: Transport> Transport for Faulty<T> {
    fn me(&self) -> MachineId {
        self.inner.me()
    }

    fn machines(&self) -> usize {
        self.inner.machines()
    }

    fn send_frame(&self, dst: MachineId, frame: Vec<u8>) {
        if self.dead.load(Ordering::SeqCst) {
            return;
        }
        self.flush_held();
        let me = self.inner.me();
        let n = self.sent.fetch_add(1, Ordering::SeqCst);
        if let Some((m, k)) = self.plan.kill {
            if m == me && n >= k {
                self.dead.store(true, Ordering::SeqCst);
                if let Ok(mut killed) = self.shared.killed.lock() {
                    killed.push((me, k));
                }
                return;
            }
        }
        if self.plan.sever.iter().any(|&(s, d)| s == me && d == dst) {
            return;
        }
        if self.plan.drop.iter().any(|&(m, i)| m == me && i == n) {
            return;
        }
        if let Some(&(_, _, hold)) = self.plan.delay.iter().find(|&&(m, i, _)| m == me && i == n) {
            if let Ok(mut held) = self.held.lock() {
                held.push((Instant::now() + hold, dst, frame));
            }
            return;
        }
        if self.plan.duplicate.iter().any(|&(m, i)| m == me && i == n) {
            self.inner.send_frame(dst, frame.clone());
        }
        self.inner.send_frame(dst, frame);
    }

    // `send_frames` deliberately stays on the trait default: it splits a
    // batched buffer into logical frames *before* this wrapper counts
    // them, so a fault plan's kill/drop/delay indices land on the same
    // frames whether or not the sender coalesced.

    fn install_pool(&mut self, pool: &FramePool) {
        self.inner.install_pool(pool);
    }

    fn recv_frame(&mut self) -> Option<(MachineId, Vec<u8>)> {
        if self.dead.load(Ordering::SeqCst) {
            return None;
        }
        self.flush_held();
        self.inner.recv_frame()
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Option<(MachineId, Vec<u8>)> {
        // Wait in short slices so delayed outbound frames still flush on
        // time while this machine is blocked receiving, and so a machine
        // killed mid-wait stops delivering promptly.
        let deadline = Instant::now() + timeout;
        loop {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let slice = (deadline - now).min(Duration::from_millis(20));
            if self.dead.load(Ordering::SeqCst) {
                std::thread::sleep(slice);
                continue;
            }
            self.flush_held();
            if let Some(f) = self.inner.recv_frame_timeout(slice) {
                return Some(f);
            }
        }
    }

    fn take_errors(&mut self) -> Vec<PeerError> {
        let mut errs = self.inner.take_errors();
        if let Ok(killed) = self.shared.killed.lock() {
            for &(m, frames) in killed.iter() {
                if !self.reported[m] {
                    self.reported[m] = true;
                    // The dead machine reports its own death too: its
                    // engine loop must abort like a crashed process would,
                    // not spin forever on a silent transport.
                    let who = if m == self.inner.me() {
                        "this machine"
                    } else {
                        "peer machine"
                    };
                    errs.push(PeerError {
                        peer: m,
                        error: FrameError::Io(format!(
                            "{who} {m} killed by fault plan after sending {frames} frames"
                        )),
                    });
                }
            }
        }
        errs
    }

    fn trusted(&self) -> bool {
        // The plan loses or reorders whole frames; it never corrupts
        // bytes, so the inner backend's trust level stands.
        self.inner.trusted()
    }

    fn kind(&self) -> TransportKind {
        self.inner.kind()
    }
}

// ---------------------------------------------------------------------------
// TCP backend
// ---------------------------------------------------------------------------

/// Connection-handshake magic (`"GLTC"`, little-endian).
pub const TCP_MAGIC: u32 = u32::from_le_bytes(*b"GLTC");

/// TCP backend parameters.
#[derive(Debug, Clone)]
pub struct TcpConfig {
    /// Cluster size (handshakes from clusters of a different size are
    /// rejected).
    pub machines: usize,
    /// Application type tag carried in the handshake — the framing layer
    /// uses the message type's name, so two apps (or two incompatible
    /// builds of one app) cannot form a cluster by accident.
    pub tag: String,
    /// How long [`TcpBound::connect`] retries outbound connections and
    /// the acceptor waits for inbound ones.
    pub connect_timeout: Duration,
    /// Reject frames whose length prefix exceeds this (a garbage prefix
    /// must not trigger a giant allocation).
    pub max_frame: u32,
}

impl TcpConfig {
    /// Defaults for `machines` machines exchanging `tag`-typed messages:
    /// [`DEFAULT_CONNECT_TIMEOUT`] connect window (override with
    /// `GRAPHLAB_CONNECT_TIMEOUT_SECS` — manual multi-host startups can
    /// easily take longer than any fixed default), [`DEFAULT_MAX_FRAME`]
    /// frame cap.
    pub fn new(machines: usize, tag: impl Into<String>) -> Self {
        let secs = std::env::var("GRAPHLAB_CONNECT_TIMEOUT_SECS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .filter(|&s| s > 0)
            .unwrap_or(DEFAULT_CONNECT_TIMEOUT.as_secs());
        TcpConfig {
            machines,
            tag: tag.into(),
            connect_timeout: Duration::from_secs(secs),
            max_frame: DEFAULT_MAX_FRAME,
        }
    }
}

/// Role byte: this connection belongs to a cluster machine (a full-mesh
/// peer that will speak the engine protocol).
pub const ROLE_WORKER: u8 = 0;
/// Role byte: this connection is a serving-mode client (speaks the
/// `serve` request/reply grammar; never joins the mesh).
pub const ROLE_CLIENT: u8 = 1;

/// The decoded contents of a connection handshake.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Handshake {
    /// Sender's machine id.
    pub sender: u32,
    /// Sender's cluster size.
    pub machines: u32,
    /// Sender's wire-codec version.
    pub wire_version: u32,
    /// Sender's application type tag.
    pub tag: String,
    /// Connection role: [`ROLE_WORKER`] (mesh peer) or [`ROLE_CLIENT`]
    /// (serve-mode client).
    pub role: u8,
}

/// Write a handshake (public so tests and diagnostic tooling can speak
/// the protocol — including deliberately wrong versions/tags).
pub fn write_handshake(
    stream: &mut TcpStream,
    sender: MachineId,
    machines: usize,
    wire_version: u32,
    tag: &str,
    role: u8,
) -> std::io::Result<()> {
    let mut body = Vec::with_capacity(64);
    TCP_MAGIC.encode(&mut body);
    wire_version.encode(&mut body);
    (sender as u32).encode(&mut body);
    (machines as u32).encode(&mut body);
    tag.to_string().encode(&mut body);
    role.encode(&mut body);
    let mut msg = Vec::with_capacity(body.len() + 4);
    (body.len() as u32).encode(&mut msg);
    msg.extend_from_slice(&body);
    stream.write_all(&msg)
}

fn io_invalid(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Read one handshake off `stream` (magic checked; version/size/tag are
/// returned for the caller to validate).
pub fn read_handshake(stream: &mut TcpStream) -> std::io::Result<Handshake> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4);
    if len == 0 || len > MAX_HANDSHAKE {
        return Err(io_invalid(format!("handshake length {len} out of range")));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    let mut input = &buf[..];
    let parsed = (|| -> wire::Result<Handshake> {
        let magic = u32::decode(&mut input)?;
        if magic != TCP_MAGIC {
            return Err(WireError::BadTag {
                what: "transport handshake magic",
                tag: magic as u8,
            });
        }
        let wire_version = u32::decode(&mut input)?;
        let sender = u32::decode(&mut input)?;
        let machines = u32::decode(&mut input)?;
        let tag = String::decode(&mut input)?;
        let role = u8::decode(&mut input)?;
        Ok(Handshake {
            sender,
            machines,
            wire_version,
            tag,
            role,
        })
    })();
    parsed.map_err(|e| io_invalid(format!("handshake decode failed: {e}")))
}

/// Read the one-byte handshake ack: `Ok(true)` = accepted, `Ok(false)` =
/// explicitly rejected, `Err` = connection dropped before answering.
pub fn read_ack(stream: &mut TcpStream) -> std::io::Result<bool> {
    let mut b = [0u8; 1];
    stream.read_exact(&mut b)?;
    Ok(b[0] == 1)
}

/// After a reject ack (`0`), the acceptor sends a wire-encoded reason
/// string naming the exact mismatched field. Best-effort: the peer may
/// have closed without one.
pub fn read_reject_reason(stream: &mut TcpStream) -> Option<String> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).ok()?;
    let len = u32::from_le_bytes(len4);
    if len > MAX_HANDSHAKE {
        return None;
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf).ok()?;
    String::from_utf8(buf).ok()
}

/// Shared state between the acceptor/reader threads and the transport.
struct TcpShared {
    frames_tx: mpsc::Sender<(MachineId, Vec<u8>)>,
    errors: Mutex<Vec<PeerError>>,
    stop: AtomicBool,
    /// Frame-buffer pool installed by the owning `Endpoint` (writer
    /// threads return written buffers to it; reader threads allocate
    /// incoming frames from it). Late-bound because writer/reader
    /// threads spawn during mesh formation, before any endpoint exists;
    /// `OnceLock` keeps the per-batch read lock-free.
    pool: OnceLock<FramePool>,
}

impl TcpShared {
    fn record(&self, peer: MachineId, error: FrameError) {
        if let Ok(mut errs) = self.errors.lock() {
            errs.push(PeerError { peer, error });
        }
    }
}

/// A machine's TCP listener, bound and accepting: the first half of
/// transport construction. `bind` starts the acceptor thread immediately,
/// so peers can complete their handshakes before this machine calls
/// [`TcpBound::connect`] — that is what lets a single thread construct a
/// whole loopback mesh sequentially.
pub struct TcpBound {
    me: MachineId,
    cfg: TcpConfig,
    local_addr: SocketAddr,
    shared: Arc<TcpShared>,
    frames_rx: Option<mpsc::Receiver<(MachineId, Vec<u8>)>>,
    acceptor: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl Drop for TcpBound {
    /// Abandoned before the mesh formed (construction error, handshake
    /// rejection, test teardown): tell the acceptor to stop so it frees
    /// the listen port promptly instead of running out its deadline.
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

impl TcpBound {
    /// Bind machine `me`'s listener at `addr` (`host:port`; port 0 picks
    /// an ephemeral port — read it back with [`TcpBound::local_addr`])
    /// and start accepting peer connections in a background thread.
    pub fn bind(me: MachineId, addr: &str, cfg: TcpConfig) -> anyhow::Result<TcpBound> {
        let listener = TcpListener::bind(addr).map_err(|e| {
            // Tag bind collisions with a stable marker so supervisors
            // (the experiment lab's executor) can detect them in child
            // output and retry, instead of string-matching OS errnos.
            let tag = if e.kind() == std::io::ErrorKind::AddrInUse {
                format!(" [{PORT_CONFLICT_MARKER}]")
            } else {
                String::new()
            };
            anyhow::anyhow!(e)
                .context(format!("machine {me}: binding TCP listener at {addr}{tag}"))
        })?;
        let local_addr = listener.local_addr()?;
        let (frames_tx, frames_rx) = mpsc::channel();
        let shared = Arc::new(TcpShared {
            frames_tx,
            errors: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
            pool: OnceLock::new(),
        });
        let expected = cfg.machines.saturating_sub(1);
        let acceptor = if expected == 0 {
            None
        } else {
            let shared = shared.clone();
            let cfg = cfg.clone();
            let claimed = Arc::new(Mutex::new(vec![false; cfg.machines]));
            listener.set_nonblocking(true)?;
            Some(std::thread::spawn(move || {
                accept_peers(me, &listener, &cfg, &shared, &claimed)
            }))
        };
        Ok(TcpBound {
            me,
            cfg,
            local_addr,
            shared,
            frames_rx: Some(frames_rx),
            acceptor,
        })
    }

    /// The bound listen address (resolves ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Establish the outbound half of the mesh: connect to every peer in
    /// `peers` (index = machine id; the own slot is ignored), handshake,
    /// and start one writer thread per peer. The acceptor keeps running;
    /// call [`TcpHalfConnected::finish`] to wait for the inbound half.
    pub fn connect_outbound(self, peers: &[String]) -> anyhow::Result<TcpHalfConnected> {
        if peers.len() != self.cfg.machines {
            bail!(
                "machine {}: {} peer addresses for a {}-machine cluster",
                self.me,
                peers.len(),
                self.cfg.machines
            );
        }
        let deadline = Instant::now() + self.cfg.connect_timeout;
        let mut writers: Vec<Option<mpsc::Sender<Vec<u8>>>> = Vec::new();
        let mut writer_handles = Vec::new();
        for (dst, addr) in peers.iter().enumerate() {
            if dst == self.me {
                writers.push(None);
                continue;
            }
            let mut stream = connect_retry(addr, deadline)
                .with_context(|| format!("machine {}: connecting to machine {dst} at {addr}", self.me))?;
            stream.set_nodelay(true).ok();
            write_handshake(
                &mut stream,
                self.me,
                self.cfg.machines,
                WIRE_VERSION,
                &self.cfg.tag,
                ROLE_WORKER,
            )
                .with_context(|| format!("machine {}: handshake to machine {dst}", self.me))?;
            stream.set_read_timeout(Some(self.cfg.connect_timeout))?;
            let accepted = read_ack(&mut stream).with_context(|| {
                format!("machine {}: no handshake ack from machine {dst}", self.me)
            })?;
            if !accepted {
                let why = read_reject_reason(&mut stream).unwrap_or_else(|| {
                    "no reason received (wire-version, cluster-size, or \
                     app/--engine tag mismatch)"
                        .to_string()
                });
                bail!(
                    "machine {}: machine {dst} rejected the handshake: {why}",
                    self.me
                );
            }
            stream.set_read_timeout(None)?;
            let (tx, rx) = mpsc::channel::<Vec<u8>>();
            let shared = self.shared.clone();
            writer_handles.push(std::thread::spawn(move || {
                write_loop(dst, stream, rx, &shared)
            }));
            writers.push(Some(tx));
        }
        Ok(TcpHalfConnected {
            bound: self,
            writers,
            writer_handles,
        })
    }

    /// Outbound + inbound in one call (the per-process cluster path; for
    /// a single-thread loopback mesh use [`tcp_loopback_mesh`], which
    /// needs the two phases separated).
    pub fn connect(self, peers: &[String]) -> anyhow::Result<TcpTransport> {
        self.connect_outbound(peers)?.finish()
    }
}

/// A transport with its outbound connections established, still waiting
/// for the inbound half (the acceptor thread).
pub struct TcpHalfConnected {
    bound: TcpBound,
    writers: Vec<Option<mpsc::Sender<Vec<u8>>>>,
    writer_handles: Vec<std::thread::JoinHandle<()>>,
}

impl TcpHalfConnected {
    /// Wait for every peer's inbound connection to complete its
    /// handshake, then return the ready transport.
    pub fn finish(self) -> anyhow::Result<TcpTransport> {
        let TcpHalfConnected {
            mut bound,
            writers,
            writer_handles,
        } = self;
        if let Some(handle) = bound.acceptor.take() {
            match handle.join() {
                Ok(result) => result?,
                Err(_) => bail!("machine {}: acceptor thread panicked", bound.me),
            }
        }
        // `bound` has a Drop impl (acceptor stop flag), so its fields are
        // extracted rather than destructured; the drop itself is a no-op
        // here — the acceptor has already been joined.
        let frames_rx = bound
            .frames_rx
            .take()
            .expect("transport receive queue already taken");
        Ok(TcpTransport {
            me: bound.me,
            machines: bound.cfg.machines,
            writers,
            writer_handles,
            frames_rx,
            shared: bound.shared.clone(),
        })
    }
}

/// Retry `TcpStream::connect` until `deadline` (peers bind their
/// listeners at their own pace during cluster startup).
fn connect_retry(addr: &str, deadline: Instant) -> anyhow::Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("connect to {addr} timed out (last error: {e})");
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Acceptor loop: accept until every peer has one validated inbound
/// connection (or the deadline passes). Each accepted connection is
/// handshaken on its own thread — a silent or hostile connection must
/// not head-of-line-block the legitimate peers behind it — and rejected
/// handshakes do not count toward the mesh.
fn accept_peers(
    me: MachineId,
    listener: &TcpListener,
    cfg: &TcpConfig,
    shared: &Arc<TcpShared>,
    claimed: &Arc<Mutex<Vec<bool>>>,
) -> anyhow::Result<()> {
    let deadline = Instant::now() + cfg.connect_timeout;
    let all_in = || {
        claimed
            .lock()
            .map(|cl| (0..cfg.machines).filter(|&m| m != me).all(|m| cl[m]))
            .unwrap_or(false)
    };
    while !all_in() {
        if shared.stop.load(Ordering::Relaxed) {
            bail!("machine {me}: transport shut down during accept");
        }
        if Instant::now() >= deadline {
            let absent: Vec<usize> = match claimed.lock() {
                Ok(cl) => (0..cfg.machines).filter(|&m| m != me && !cl[m]).collect(),
                Err(_) => Vec::new(),
            };
            bail!("machine {me}: peers {absent:?} never connected within {:?}", cfg.connect_timeout);
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let shared = shared.clone();
                let claimed = claimed.clone();
                let cfg = cfg.clone();
                // Detached: validates the greeting, then (on success)
                // becomes the peer's reader thread.
                std::thread::spawn(move || {
                    handshake_then_read(me, stream, &cfg, &shared, &claimed)
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => bail!("machine {me}: accept failed: {e}"),
        }
    }
    Ok(())
}

/// Validate one inbound connection's handshake; on success, claim the
/// sender's slot (duplicates are rejected), ack, and keep running as
/// that peer's reader.
fn handshake_then_read(
    me: MachineId,
    mut stream: TcpStream,
    cfg: &TcpConfig,
    shared: &Arc<TcpShared>,
    claimed: &Arc<Mutex<Vec<bool>>>,
) {
    // The stream must block for the handshake (the listener is
    // nonblocking and accepted sockets inherit no timeout of ours).
    stream.set_nonblocking(false).ok();
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    let Ok(hs) = read_handshake(&mut stream) else {
        return; // garbage greeting: drop the connection
    };
    let sender = hs.sender as usize;
    // Name the exact mismatched field: the rejected side relays this to
    // the operator (an `--engine` mismatch shows up as a tag mismatch —
    // the tag is the engine's message type).
    let mut reject: Option<String> = if hs.wire_version != WIRE_VERSION {
        Some(format!(
            "wire version {} != this build's {WIRE_VERSION}",
            hs.wire_version
        ))
    } else if hs.machines as usize != cfg.machines {
        Some(format!(
            "cluster size {} != this cluster's {}",
            hs.machines, cfg.machines
        ))
    } else if hs.role != ROLE_WORKER {
        Some(format!(
            "connection role {} is not a cluster machine — serve clients must \
             dial the frontend's --listen port, not the worker mesh",
            hs.role
        ))
    } else if hs.tag != cfg.tag {
        Some(format!(
            "app/engine tag {:?} != expected {:?} (every process must run the \
             same app AND the same --engine)",
            hs.tag, cfg.tag
        ))
    } else if sender >= cfg.machines || sender == me {
        Some(format!("invalid sender machine id {sender}"))
    } else {
        None
    };
    // Claim + ack atomically under the lock (the ack is one byte into a
    // fresh socket buffer — it cannot meaningfully block): by the time
    // the acceptor's all-connected check can see this slot, the peer has
    // its ack. A peer that dies before the ack is never claimed, so the
    // acceptor keeps waiting and a reconnect can land.
    if reject.is_none() {
        match claimed.lock() {
            Ok(mut cl) => {
                if cl[sender] {
                    reject = Some(format!("machine {sender} is already connected"));
                } else if stream.write_all(&[1u8]).is_ok() {
                    cl[sender] = true;
                } else {
                    return;
                }
            }
            Err(_) => reject = Some("acceptor state poisoned".to_string()),
        }
    }
    if let Some(reason) = reject {
        let mut buf = Vec::with_capacity(reason.len() + 8);
        buf.push(0u8);
        reason.encode(&mut buf);
        let _ = stream.write_all(&buf);
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }
    stream.set_read_timeout(None).ok();
    stream.set_nodelay(true).ok();
    read_loop(sender, stream, cfg.max_frame, shared);
}

/// Reader thread: `[u32 len][payload]` frames off one inbound stream into
/// the shared receive queue. Stream problems become [`PeerError`]s; the
/// frame handed upward includes its length prefix (accounting parity with
/// the in-process backend).
fn read_loop(src: MachineId, mut stream: TcpStream, max_frame: u32, shared: &Arc<TcpShared>) {
    // Payloads are read through this bounded scratch buffer so the frame
    // vector grows with bytes that actually arrived — a hostile length
    // prefix must not trigger a giant upfront allocation.
    let mut scratch = vec![0u8; 64 * 1024];
    loop {
        let mut len4 = [0u8; 4];
        match stream.read_exact(&mut len4) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
                // A FIN at a frame boundary: clean for a peer whose run
                // has finished, but indistinguishable from a mid-run
                // process death — so it is recorded. Engines consult
                // these only when stuck or timed out, so a normal
                // teardown's EOF is never reported to anyone.
                shared.record(src, FrameError::Io("connection closed by peer".to_string()));
                return;
            }
            Err(e) => {
                shared.record(src, FrameError::Io(e.to_string()));
                return;
            }
        }
        let len = u32::from_le_bytes(len4);
        if len > max_frame {
            shared.record(src, FrameError::Oversized { len, max: max_frame });
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        // Recycled buffer when the endpoint has installed a pool (the
        // framing layer returns it after decoding); fresh otherwise.
        let mut frame = match shared.pool.get() {
            Some(pool) => pool.get(),
            None => Vec::new(),
        };
        frame.reserve((len as usize).min(scratch.len()) + 4);
        frame.extend_from_slice(&len4);
        let mut remaining = len as usize;
        while remaining > 0 {
            let take = remaining.min(scratch.len());
            if let Err(e) = stream.read_exact(&mut scratch[..take]) {
                // Truncated frame: the peer died (or lied) mid-payload.
                shared.record(src, FrameError::Io(e.to_string()));
                return;
            }
            frame.extend_from_slice(&scratch[..take]);
            remaining -= take;
        }
        if shared.frames_tx.send((src, frame)).is_err() {
            return; // transport dropped; nobody is listening
        }
    }
}

/// Writer thread: drain one peer's frame queue onto its stream. Queued
/// frames behind the first are coalesced — up to [`COALESCE_MAX_FRAMES`]
/// buffers / [`COALESCE_MAX_BYTES`] bytes per vectored write — so
/// backpressure turns many small frames into one syscall instead of one
/// each. `TCP_NODELAY` is set on every mesh socket, so batching is this
/// loop's decision, not Nagle's. Written buffers return to the
/// endpoint's frame pool. On channel close (transport drop), flush and
/// close the write half so the peer's reader sees a clean EOF.
fn write_loop(
    dst: MachineId,
    mut stream: TcpStream,
    rx: mpsc::Receiver<Vec<u8>>,
    shared: &Arc<TcpShared>,
) {
    let mut batch: Vec<Vec<u8>> = Vec::with_capacity(COALESCE_MAX_FRAMES);
    while let Ok(first) = rx.recv() {
        let mut bytes = first.len();
        batch.push(first);
        while batch.len() < COALESCE_MAX_FRAMES && bytes < COALESCE_MAX_BYTES {
            match rx.try_recv() {
                Ok(next) => {
                    bytes += next.len();
                    batch.push(next);
                }
                Err(_) => break,
            }
        }
        if let Err(e) = write_all_vectored(&mut stream, &batch) {
            shared.record(dst, FrameError::Io(e.to_string()));
            return;
        }
        match shared.pool.get() {
            Some(pool) => batch.drain(..).for_each(|buf| pool.put(buf)),
            None => batch.clear(),
        }
    }
    let _ = stream.flush();
    let _ = stream.shutdown(Shutdown::Write);
}

/// Write every buffer in `bufs` to `stream` via vectored writes,
/// advancing through partial writes by hand (`IoSlice::advance_slices`
/// postdates this crate's toolchain floor).
fn write_all_vectored(stream: &mut TcpStream, bufs: &[Vec<u8>]) -> std::io::Result<()> {
    // (skip_buf, skip_bytes): how much of the batch is already written.
    let mut skip_buf = 0usize;
    let mut skip_bytes = 0usize;
    let mut slices: Vec<IoSlice<'_>> = Vec::with_capacity(bufs.len());
    while skip_buf < bufs.len() {
        slices.clear();
        slices.push(IoSlice::new(&bufs[skip_buf][skip_bytes..]));
        for buf in &bufs[skip_buf + 1..] {
            slices.push(IoSlice::new(buf));
        }
        let mut n = match stream.write_vectored(&slices) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "wrote zero bytes",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while skip_buf < bufs.len() {
            let rest = bufs[skip_buf].len() - skip_bytes;
            if n < rest {
                skip_bytes += n;
                break;
            }
            n -= rest;
            skip_buf += 1;
            skip_bytes = 0;
        }
    }
    Ok(())
}

/// The ready TCP backend: writer thread + queue per peer, reader threads
/// feeding one shared receive queue.
pub struct TcpTransport {
    me: MachineId,
    machines: usize,
    writers: Vec<Option<mpsc::Sender<Vec<u8>>>>,
    writer_handles: Vec<std::thread::JoinHandle<()>>,
    frames_rx: mpsc::Receiver<(MachineId, Vec<u8>)>,
    shared: Arc<TcpShared>,
}

impl Transport for TcpTransport {
    fn me(&self) -> MachineId {
        self.me
    }

    fn machines(&self) -> usize {
        self.machines
    }

    fn send_frame(&self, dst: MachineId, frame: Vec<u8>) {
        if let Some(Some(tx)) = self.writers.get(dst) {
            // Writer gone (peer dead / shutdown): drop, as documented.
            let _ = tx.send(frame);
        }
    }

    fn send_frames(&self, dst: MachineId, buf: Vec<u8>, count: usize) {
        if count == 0 {
            return;
        }
        // One queue entry for the whole batch: the writer flushes it in
        // one write and the receiver's read loop re-splits it at frame
        // boundaries — indistinguishable on the wire from `count` sends.
        self.send_frame(dst, buf);
    }

    fn install_pool(&mut self, pool: &FramePool) {
        let _ = self.shared.pool.set(pool.clone());
    }

    fn recv_frame(&mut self) -> Option<(MachineId, Vec<u8>)> {
        self.frames_rx.try_recv().ok()
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Option<(MachineId, Vec<u8>)> {
        self.frames_rx.recv_timeout(timeout).ok()
    }

    fn take_errors(&mut self) -> Vec<PeerError> {
        match self.shared.errors.lock() {
            Ok(mut errs) => std::mem::take(&mut *errs),
            Err(_) => Vec::new(),
        }
    }

    fn trusted(&self) -> bool {
        false
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }
}

impl Drop for TcpTransport {
    /// Clean shutdown: close every writer queue (writers flush what is
    /// already queued, then close the socket's write half so peers see
    /// EOF) and join them so queued frames are on the wire before the
    /// machine loop returns. Reader threads are detached — they exit on
    /// their peer's EOF or when the receive queue drops.
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        for w in &mut self.writers {
            *w = None;
        }
        for h in self.writer_handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build a full in-process mesh of `machines` TCP transports over real
/// loopback sockets (ephemeral ports): the harness behind
/// `--transport tcp`, the transport tests, and `bench-net`. Single
/// threaded construction works because every listener's acceptor thread
/// runs from `bind` time.
pub fn tcp_loopback_mesh(machines: usize, tag: &str) -> anyhow::Result<Vec<TcpTransport>> {
    let mut bounds = Vec::with_capacity(machines);
    for me in 0..machines {
        bounds.push(TcpBound::bind(me, "127.0.0.1:0", TcpConfig::new(machines, tag))?);
    }
    let addrs: Vec<String> = bounds.iter().map(|b| b.local_addr().to_string()).collect();
    let halves: Vec<TcpHalfConnected> = bounds
        .into_iter()
        .map(|b| b.connect_outbound(&addrs))
        .collect::<anyhow::Result<_>>()?;
    halves.into_iter().map(|h| h.finish()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses_and_rejects() {
        assert_eq!("tcp".parse::<TransportKind>().unwrap(), TransportKind::Tcp);
        assert_eq!(
            "inproc".parse::<TransportKind>().unwrap(),
            TransportKind::InProc
        );
        assert!("udp".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::Tcp.to_string(), "tcp");
    }

    #[test]
    fn inproc_frames_round_trip_with_fifo_order() {
        let mut mesh = InProcTransport::mesh(2, NetworkModel::default());
        let t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        t1.send_frame(0, vec![1, 2, 3]);
        t1.send_frame(0, vec![4]);
        let (src, f) = t0.recv_frame_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!((src, f), (1, vec![1, 2, 3]));
        let (src, f) = t0.recv_frame_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!((src, f), (1, vec![4]));
        assert!(t0.recv_frame().is_none());
        assert!(t0.take_errors().is_empty());
        assert!(t0.trusted());
    }

    #[test]
    fn inproc_latency_holds_back_delivery() {
        let mut mesh = InProcTransport::mesh(2, NetworkModel {
            latency: Duration::from_millis(30),
        });
        let t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        let start = Instant::now();
        t1.send_frame(0, vec![9]);
        let got = t0.recv_frame_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(got.1, vec![9]);
        assert!(start.elapsed() >= Duration::from_millis(28));
    }

    #[test]
    fn tcp_loopback_mesh_exchanges_frames() {
        let mut mesh = tcp_loopback_mesh(3, "test-tag").unwrap();
        assert!(!mesh[0].trusted());
        mesh[0].send_frame(2, frame_of(&[7, 7]));
        mesh[1].send_frame(2, frame_of(&[8]));
        let mut got = Vec::new();
        for _ in 0..2 {
            let (src, frame) = mesh[2]
                .recv_frame_timeout(Duration::from_secs(5))
                .expect("frame over loopback");
            got.push((src, frame));
        }
        got.sort();
        assert_eq!(got, vec![(0, frame_of(&[7, 7])), (1, frame_of(&[8]))]);
    }

    #[test]
    fn tcp_fifo_per_peer() {
        let mut mesh = tcp_loopback_mesh(2, "fifo").unwrap();
        for i in 0..50u8 {
            mesh[0].send_frame(1, frame_of(&[i]));
        }
        for i in 0..50u8 {
            let (src, frame) = mesh[1]
                .recv_frame_timeout(Duration::from_secs(5))
                .expect("frame");
            assert_eq!((src, frame), (0, frame_of(&[i])));
        }
    }

    #[test]
    fn mismatched_tag_is_rejected() {
        // One bound endpoint; a client with the wrong tag must get ack 0.
        let bound = TcpBound::bind(0, "127.0.0.1:0", TcpConfig::new(2, "right-tag")).unwrap();
        let addr = bound.local_addr();
        let mut s = TcpStream::connect(addr).unwrap();
        write_handshake(&mut s, 1, 2, WIRE_VERSION, "wrong-tag", ROLE_WORKER).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let accepted = read_ack(&mut s).unwrap_or(false);
        assert!(!accepted, "wrong tag must be rejected");
        // The right tag on a fresh connection is accepted.
        let mut s2 = TcpStream::connect(addr).unwrap();
        write_handshake(&mut s2, 1, 2, WIRE_VERSION, "right-tag", ROLE_WORKER).unwrap();
        s2.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(read_ack(&mut s2).unwrap());
        // A serve client dialing the worker mesh is rejected by role.
        let mut s3 = TcpStream::connect(addr).unwrap();
        write_handshake(&mut s3, 1, 2, WIRE_VERSION, "right-tag", ROLE_CLIENT).unwrap();
        s3.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(!read_ack(&mut s3).unwrap_or(false), "client role must be rejected by the mesh");
    }

    /// `[u32 len][payload]` helper for the raw-frame tests.
    fn frame_of(payload: &[u8]) -> Vec<u8> {
        let mut f = Vec::with_capacity(payload.len() + 4);
        f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        f.extend_from_slice(payload);
        f
    }

    fn faulty_pair(plan: FaultPlan) -> Vec<Faulty<InProcTransport>> {
        Faulty::wrap_mesh(InProcTransport::mesh(2, NetworkModel::default()), plan)
    }

    #[test]
    fn fault_kill_stops_traffic_and_is_reported_to_peers() {
        let mut mesh = faulty_pair(FaultPlan::kill_at(1, 2));
        let mut t0 = mesh.remove(0);
        let mut t1 = mesh.remove(0);
        for i in 0..4u8 {
            t1.send_frame(0, frame_of(&[i]));
        }
        // Exactly the two pre-kill frames arrive.
        assert_eq!(
            t0.recv_frame_timeout(Duration::from_secs(1)),
            Some((1, frame_of(&[0])))
        );
        assert_eq!(
            t0.recv_frame_timeout(Duration::from_secs(1)),
            Some((1, frame_of(&[1])))
        );
        assert!(t0.recv_frame_timeout(Duration::from_millis(50)).is_none());
        // The survivor sees a typed death report, exactly once.
        let errs = t0.take_errors();
        assert!(
            errs.iter().any(|e| e.peer == 1),
            "expected a kill report for machine 1, got {errs:?}"
        );
        assert!(t0.take_errors().is_empty(), "kill must be reported once");
        // The dead machine learns of its own death (so an in-process
        // engine loop aborts instead of hanging), also exactly once.
        let own = t1.take_errors();
        assert!(
            own.iter().any(|e| e.peer == 1),
            "expected a self-kill report on machine 1, got {own:?}"
        );
        assert!(t1.take_errors().is_empty());
        // The dead machine neither sends nor receives.
        t0.send_frame(1, frame_of(&[9]));
        assert!(t1.recv_frame_timeout(Duration::from_millis(50)).is_none());
    }

    #[test]
    fn fault_drop_and_duplicate_hit_exact_frame_indices() {
        let plan = FaultPlan {
            drop: vec![(0, 0)],
            duplicate: vec![(0, 2)],
            ..FaultPlan::default()
        };
        let mut mesh = faulty_pair(plan);
        let mut t1 = mesh.pop().unwrap();
        let t0 = mesh.pop().unwrap();
        for i in 0..3u8 {
            t0.send_frame(1, frame_of(&[i]));
        }
        // Frame 0 dropped, frame 1 delivered once, frame 2 twice.
        let mut got = Vec::new();
        while let Some((_, f)) = t1.recv_frame_timeout(Duration::from_millis(200)) {
            got.push(f);
        }
        assert_eq!(got, vec![frame_of(&[1]), frame_of(&[2]), frame_of(&[2])]);
    }

    #[test]
    fn fault_delay_holds_back_one_frame() {
        let plan = FaultPlan {
            delay: vec![(0, 0, Duration::from_millis(80))],
            ..FaultPlan::default()
        };
        let mut mesh = faulty_pair(plan);
        let mut t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        let start = Instant::now();
        t0.send_frame(1, frame_of(&[1]));
        t0.send_frame(1, frame_of(&[2])); // undelayed: overtakes frame 0
        assert_eq!(
            t1.recv_frame_timeout(Duration::from_secs(1)),
            Some((0, frame_of(&[2])))
        );
        // Held frames release on the *sender's* next transport call once
        // their hold time elapses (engine loops make such calls
        // constantly; here the test drives one by hand).
        std::thread::sleep(Duration::from_millis(90));
        assert!(t0.recv_frame().is_none());
        let (_, late) = t1.recv_frame_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(late, frame_of(&[1]));
        assert!(start.elapsed() >= Duration::from_millis(75));
    }

    #[test]
    fn fault_sever_cuts_one_direction_only() {
        let plan = FaultPlan {
            sever: vec![(0, 1)],
            ..FaultPlan::default()
        };
        let mut mesh = faulty_pair(plan);
        let mut t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        t0.send_frame(1, frame_of(&[1]));
        t1.send_frame(0, frame_of(&[2]));
        assert!(t1.recv_frame_timeout(Duration::from_millis(100)).is_none());
        assert_eq!(
            t0.recv_frame_timeout(Duration::from_secs(1)),
            Some((1, frame_of(&[2])))
        );
    }

    #[test]
    fn frame_pool_recycles_buffers() {
        let pool = FramePool::default();
        let mut a = pool.get();
        a.extend_from_slice(&[1, 2, 3]);
        a.reserve(512);
        let cap = a.capacity();
        pool.put(a);
        // The recycled buffer comes back empty with capacity retained.
        let b = pool.get();
        assert!(b.is_empty());
        assert_eq!(b.capacity(), cap);
        // A buffer over the capacity cap is freed, not pooled.
        pool.put(Vec::with_capacity(POOL_MAX_BUFFER_CAPACITY + 1));
        assert_eq!(pool.get().capacity(), 0);
    }

    #[test]
    fn split_frames_recovers_logical_frames() {
        let frames = [frame_of(&[1, 2, 3]), frame_of(&[]), frame_of(&[9; 70])];
        let mut buf = Vec::new();
        for f in &frames {
            buf.extend_from_slice(f);
        }
        assert_eq!(split_frames(&buf, 3), frames.to_vec());
        // A truncated batch yields only the frames that parse.
        assert_eq!(split_frames(&buf[..frames[0].len() + 2], 3).len(), 1);
    }

    #[test]
    fn send_frames_default_splits_for_inproc() {
        let mut mesh = InProcTransport::mesh(2, NetworkModel::default());
        let t1 = mesh.pop().unwrap();
        let mut t0 = mesh.pop().unwrap();
        let mut buf = frame_of(&[1]);
        buf.extend_from_slice(&frame_of(&[2, 2]));
        buf.extend_from_slice(&frame_of(&[3]));
        t1.send_frames(0, buf, 3);
        for payload in [vec![1u8], vec![2, 2], vec![3]] {
            let (src, f) = t0.recv_frame_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!((src, f), (1, frame_of(&payload)));
        }
        assert!(t0.recv_frame().is_none());
    }

    #[test]
    fn fault_indices_count_logical_frames_not_batches() {
        // Regression: a fault plan targeting frame 1 must hit the second
        // *message* even when all three ride in one coalesced batch.
        let plan = FaultPlan {
            drop: vec![(0, 1)],
            ..FaultPlan::default()
        };
        let mut mesh = faulty_pair(plan);
        let mut t1 = mesh.pop().unwrap();
        let t0 = mesh.pop().unwrap();
        let mut buf = frame_of(&[0]);
        buf.extend_from_slice(&frame_of(&[1]));
        buf.extend_from_slice(&frame_of(&[2]));
        t0.send_frames(1, buf, 3);
        let mut got = Vec::new();
        while let Some((_, f)) = t1.recv_frame_timeout(Duration::from_millis(200)) {
            got.push(f);
        }
        assert_eq!(got, vec![frame_of(&[0]), frame_of(&[2])]);
    }

    #[test]
    fn tcp_send_frames_delivers_individual_frames() {
        let mut mesh = tcp_loopback_mesh(2, "batch").unwrap();
        let mut buf = Vec::new();
        let payloads: Vec<Vec<u8>> = (0..20u8).map(|i| vec![i; (i as usize % 5) + 1]).collect();
        for p in &payloads {
            buf.extend_from_slice(&frame_of(p));
        }
        mesh[0].send_frames(1, buf, payloads.len());
        mesh[0].send_frame(1, frame_of(&[99])); // FIFO after the batch
        for p in &payloads {
            let (src, f) = mesh[1]
                .recv_frame_timeout(Duration::from_secs(5))
                .expect("batched frame");
            assert_eq!((src, f), (0, frame_of(p)));
        }
        let (_, tail) = mesh[1].recv_frame_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(tail, frame_of(&[99]));
    }

    #[test]
    fn peer_grace_env_override() {
        // No env set in the test runner by default: the default passes
        // through. (The override path is covered by the fault-injection
        // integration tests, which set the variable process-wide.)
        assert_eq!(
            peer_grace(Duration::from_secs(30)),
            std::env::var("GRAPHLAB_PEER_GRACE_SECS")
                .ok()
                .and_then(|v| v.parse::<u64>().ok())
                .map(Duration::from_secs)
                .unwrap_or(Duration::from_secs(30))
        );
    }
}
