//! Distributed substrate: the machinery under both distributed engines
//! (paper Sec. 4).
//!
//! The paper runs on 64 EC2 nodes over TCP; here a *cluster* is a set of
//! in-process machines (one OS thread each) communicating exclusively by
//! message passing over [`network`] endpoints — no shared mutable state —
//! with full byte accounting (for Fig. 6(b)) and optional injected latency
//! (for the Fig. 8(b) lock-pipelining study). Every machine holds a
//! [`localgraph::LocalGraph`]: its owned partition plus **ghost** copies of
//! boundary vertices/edges with version-based cache coherence (paper Sec.
//! 4.1, Fig. 4(b)).
//!
//! [`locks`] is the distributed reader–writer lock table with FIFO wait
//! queues (paper Sec. 4.2.2); [`termination`] is the Misra/Safra-style
//! token-ring termination detector the locking engine uses.

pub mod localgraph;
pub mod locks;
pub mod network;
pub mod termination;

pub use localgraph::LocalGraph;
pub use network::{Endpoint, Network, NetworkModel};

/// Application data stored on vertices/edges of a distributed graph.
///
/// `wire_bytes` is the modeled serialized size: the in-process transport
/// moves values by `Clone`, but every message's wire size is accounted so
/// network figures (Fig. 6(b)) reflect what a TCP deployment would send.
pub trait DataValue: Clone + Send + Sync + 'static {
    /// Modeled serialized size in bytes.
    fn wire_bytes(&self) -> u64;
}

macro_rules! impl_datavalue_prim {
    ($($t:ty),*) => {
        $(impl DataValue for $t {
            fn wire_bytes(&self) -> u64 {
                std::mem::size_of::<$t>() as u64
            }
        })*
    };
}

impl_datavalue_prim!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64, usize, isize);

impl DataValue for () {
    fn wire_bytes(&self) -> u64 {
        0
    }
}

impl<T: DataValue> DataValue for Vec<T> {
    fn wire_bytes(&self) -> u64 {
        4 + self.iter().map(|x| x.wire_bytes()).sum::<u64>()
    }
}

impl<A: DataValue, B: DataValue> DataValue for (A, B) {
    fn wire_bytes(&self) -> u64 {
        self.0.wire_bytes() + self.1.wire_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes() {
        assert_eq!(3.0f32.wire_bytes(), 4);
        assert_eq!(vec![1.0f32; 8].wire_bytes(), 4 + 32);
        assert_eq!(().wire_bytes(), 0);
        assert_eq!((1u32, 2.0f64).wire_bytes(), 12);
    }
}
