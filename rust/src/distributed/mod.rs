//! Distributed substrate: the machinery under both distributed engines
//! (paper Sec. 4).
//!
//! The paper runs on 64 EC2 nodes over TCP. Here the substrate is split
//! into two layers, mirroring that deployment: the [`network`] framing
//! layer ([`Endpoint`]s speak typed messages, serialized through the
//! [`crate::wire`] codec into real length-prefixed frames, so byte
//! accounting for Fig. 6(b) is a measurement of the encoded traffic) and
//! the byte-level [`transport`] backends underneath — `InProc` (mpsc
//! channels, one thread per machine, optional injected latency for the
//! Fig. 8(b) lock-pipelining study) and `Tcp` (real `std::net` sockets:
//! a loopback full mesh in one process, or one endpoint per worker
//! process in `graphlab worker` cluster mode). Every machine holds a
//! [`localgraph::LocalGraph`]: its owned partition plus **ghost** copies of
//! boundary vertices/edges with version-based cache coherence (paper Sec.
//! 4.1, Fig. 4(b)), built either from an in-memory global graph or by
//! replaying this machine's on-disk atom journals
//! ([`localgraph::LocalGraph::from_atom_files`]).
//!
//! [`locks`] is the distributed reader–writer lock table with FIFO wait
//! queues (paper Sec. 4.2.2); [`termination`] is the Misra/Safra-style
//! token-ring termination detector the locking engine uses.

pub mod localgraph;
pub mod locks;
pub mod network;
pub mod snapshot;
pub mod termination;
pub mod transport;

pub use localgraph::LocalGraph;
pub use network::{Endpoint, Network, NetworkModel};
pub use snapshot::SnapshotTrigger;
pub use transport::{
    ClusterConfig, FaultPlan, Faulty, FramePool, TransportKind, PORT_CONFLICT_MARKER,
};

use std::path::Path;
use std::sync::Arc;

use crate::graph::{Graph, GraphTopology};
use crate::partition::atoms::AtomPlacement;
use crate::partition::{MachineId, Partition};

use crate::wire::Wire;

/// Application data stored on vertices/edges of a distributed graph.
///
/// Every such value must speak the [`Wire`] codec: the in-process network
/// serializes each message into a real frame (counting the encoded bytes
/// in [`network::NetStats`]) and the atom store writes the same encoding
/// to disk. The trait is a blanket alias — implement [`Wire`] (plus the
/// usual `Clone + Send + Sync`) and `DataValue` comes for free.
pub trait DataValue: Clone + Send + Sync + Wire + 'static {}

impl<T: Clone + Send + Sync + Wire + 'static> DataValue for T {}

/// Everything a distributed engine needs before spawning its machine
/// loops, assembled in the one order that works on every backend: pick
/// the local ranks, load their [`LocalGraph`]s, form the mesh, split the
/// input graph into topology plus (cluster-mode-only) reassembly
/// fallback data.
pub(crate) struct ClusterSetup<V, E, M> {
    /// One local graph per locally-run machine (rank order).
    pub locals: Vec<LocalGraph<V, E>>,
    /// One endpoint per locally-run machine (same order).
    pub endpoints: Vec<Endpoint<M>>,
    /// Per-machine wire counters (all slots; only local ones written).
    pub stats: Arc<Vec<network::NetStats>>,
    /// Input vertex data, retained only in cluster mode as the
    /// reassembly fallback for slots owned by other processes.
    pub vfallback: Option<Vec<V>>,
    /// Input edge data, ditto.
    pub efallback: Option<Vec<E>>,
    /// The input graph's topology (reassembly + canonical edge owners).
    pub topo: GraphTopology,
}

/// The shared front half of both distributed engines' `run`:
/// ranks → local graphs → (restore overlay) → mesh → topology/fallback
/// split. Local graphs are loaded **before** the mesh forms so that, in
/// cluster mode, per-process journal-replay skew burns the generous
/// connect window rather than the protocol's barrier timeouts.
///
/// `restore` is the recovery path (paper Sec. 4.3): after the journals
/// rebuild each local graph at version 0, the newest *complete*
/// `snapshot_<epoch>/` under the given directory is overlaid
/// version-gated; torn snapshot directories are skipped. `fault` wraps
/// every transport in a [`Faulty`] decorator for deterministic failure
/// testing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn cluster_setup<V, E, M>(
    graph: Graph<V, E>,
    partition: &Partition,
    atoms: Option<&AtomPlacement>,
    machines: usize,
    model: NetworkModel,
    transport: TransportKind,
    cluster: Option<&ClusterConfig>,
    fault: Option<&FaultPlan>,
    restore: Option<&Path>,
) -> anyhow::Result<ClusterSetup<V, E, M>>
where
    V: Clone + Wire,
    E: Clone + Wire,
    M: Send + Wire,
{
    // Which machines run in this process: all of them on the in-process
    // backends, exactly one in multi-process cluster mode.
    let ranks: Vec<MachineId> = match cluster {
        Some(c) => vec![c.me],
        None => (0..machines).collect(),
    };
    // The paper's load step: merge your atom files (disk path) or slice
    // the already-loaded global graph (in-memory path, same result).
    let mut locals: Vec<LocalGraph<V, E>> = match atoms {
        None => ranks
            .iter()
            .map(|&m| LocalGraph::build(&graph, partition, m))
            .collect(),
        Some(placement) => {
            let mut ls = Vec::with_capacity(ranks.len());
            for &m in &ranks {
                ls.push(LocalGraph::from_atom_files(
                    &placement.dir,
                    &placement.atom_to_machine,
                    m,
                )?);
            }
            ls
        }
    };
    if let Some(root) = restore {
        if let Some(snap) = snapshot::latest_complete::<V, E>(root)? {
            anyhow::ensure!(
                snap.machines == machines,
                "snapshot under {} was cut by {} machines, run uses {machines}",
                root.display(),
                snap.machines
            );
            for lg in &mut locals {
                snapshot::overlay(lg, &snap);
            }
        }
    }
    let (endpoints, stats) =
        network::cluster_endpoints::<M>(machines, model, transport, cluster, fault)?;
    debug_assert!(endpoints.iter().map(|ep| ep.me()).eq(ranks.iter().copied()));
    // Cluster mode keeps the input data as the reassembly fallback for
    // slots owned by other worker processes; in-process runs free it
    // right here (every machine already holds its LocalGraph copy — no
    // reason to double the graph's memory for the whole run).
    let (vdata0, edata0, topo) = graph.into_parts();
    let (vfallback, efallback) = if cluster.is_some() {
        (Some(vdata0), Some(edata0))
    } else {
        drop(vdata0);
        drop(edata0);
        (None, None)
    };
    Ok(ClusterSetup {
        locals,
        endpoints,
        stats,
        vfallback,
        efallback,
        topo,
    })
}

/// Reassemble one global data vector from per-machine outputs (both
/// engines' final step). An in-process run must cover every slot — an
/// uncovered one is a partition/ownership bug and panics loudly — while
/// a cluster-mode run supplies the input data as `fallback` for the
/// slots owned by other worker processes.
pub(crate) fn reassemble<T>(
    slots: Vec<Option<T>>,
    fallback: Option<Vec<T>>,
    what: &str,
) -> Vec<T> {
    match fallback {
        Some(orig) => slots
            .into_iter()
            .zip(orig)
            .map(|(slot, fb)| slot.unwrap_or(fb))
            .collect(),
        None => slots
            .into_iter()
            .map(|slot| slot.unwrap_or_else(|| panic!("{what} unowned")))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire;

    fn assert_datavalue<T: DataValue>() {}

    #[test]
    fn primitive_and_container_data_values_encode() {
        // The blanket impl covers everything Wire covers.
        assert_datavalue::<f32>();
        assert_datavalue::<()>();
        assert_datavalue::<Vec<f32>>();
        assert_datavalue::<(u32, f64)>();
        // Encoded sizes are the codec's, not a model: f32 = 4, Vec adds a
        // u32 length prefix, tuples concatenate.
        assert_eq!(wire::encoded_len(&3.0f32), 4);
        assert_eq!(wire::encoded_len(&vec![1.0f32; 8]), 4 + 32);
        assert_eq!(wire::encoded_len(&()), 0);
        assert_eq!(wire::encoded_len(&(1u32, 2.0f64)), 12);
    }
}
