//! Distributed substrate: the machinery under both distributed engines
//! (paper Sec. 4).
//!
//! The paper runs on 64 EC2 nodes over TCP; here a *cluster* is a set of
//! in-process machines (one OS thread each) communicating exclusively by
//! message passing over [`network`] endpoints — no shared mutable state —
//! with every message serialized through the [`crate::wire`] codec into a
//! real length-prefixed frame, so byte accounting (for Fig. 6(b)) is a
//! measurement of the encoded traffic, with optional injected latency
//! (for the Fig. 8(b) lock-pipelining study). Every machine holds a
//! [`localgraph::LocalGraph`]: its owned partition plus **ghost** copies of
//! boundary vertices/edges with version-based cache coherence (paper Sec.
//! 4.1, Fig. 4(b)), built either from an in-memory global graph or by
//! replaying this machine's on-disk atom journals
//! ([`localgraph::LocalGraph::from_atom_files`]).
//!
//! [`locks`] is the distributed reader–writer lock table with FIFO wait
//! queues (paper Sec. 4.2.2); [`termination`] is the Misra/Safra-style
//! token-ring termination detector the locking engine uses.

pub mod localgraph;
pub mod locks;
pub mod network;
pub mod termination;

pub use localgraph::LocalGraph;
pub use network::{Endpoint, Network, NetworkModel};

use crate::wire::Wire;

/// Application data stored on vertices/edges of a distributed graph.
///
/// Every such value must speak the [`Wire`] codec: the in-process network
/// serializes each message into a real frame (counting the encoded bytes
/// in [`network::NetStats`]) and the atom store writes the same encoding
/// to disk. The trait is a blanket alias — implement [`Wire`] (plus the
/// usual `Clone + Send + Sync`) and `DataValue` comes for free.
pub trait DataValue: Clone + Send + Sync + Wire + 'static {}

impl<T: Clone + Send + Sync + Wire + 'static> DataValue for T {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire;

    fn assert_datavalue<T: DataValue>() {}

    #[test]
    fn primitive_and_container_data_values_encode() {
        // The blanket impl covers everything Wire covers.
        assert_datavalue::<f32>();
        assert_datavalue::<()>();
        assert_datavalue::<Vec<f32>>();
        assert_datavalue::<(u32, f64)>();
        // Encoded sizes are the codec's, not a model: f32 = 4, Vec adds a
        // u32 length prefix, tuples concatenate.
        assert_eq!(wire::encoded_len(&3.0f32), 4);
        assert_eq!(wire::encoded_len(&vec![1.0f32; 8]), 4 + 32);
        assert_eq!(wire::encoded_len(&()), 0);
        assert_eq!(wire::encoded_len(&(1u32, 2.0f64)), 12);
    }
}
