//! Machine-local view of the distributed data graph (paper Sec. 4.1).
//!
//! Each machine materializes its **local partition**: the vertices it owns
//! plus **ghosts** — copies of boundary vertices and edges adjacent to the
//! partition — which "act as local caches for their true counterparts
//! across the network" with version-based coherence. All engine data access
//! goes through local indices; only the coherence protocols speak global
//! ids.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context as _};

use crate::graph::{EdgeId, Graph, VertexId};
use crate::partition::{atoms, MachineId, Partition};
use crate::wire::Wire;

/// Local vertex index (dense, machine-private).
pub type LocalVid = u32;
/// Local edge index (dense, machine-private).
pub type LocalEid = u32;

/// One machine's partition + ghosts.
pub struct LocalGraph<V, E> {
    /// This machine.
    pub machine: MachineId,
    /// Local → global vertex id. Indices `< owned` are owned, the rest are
    /// ghosts.
    pub l2g: Vec<VertexId>,
    /// Global → local vertex id (only defined for local vertices).
    pub g2l: HashMap<VertexId, LocalVid>,
    /// Number of owned vertices (prefix of `l2g`).
    pub owned: usize,
    /// Owner machine of each local vertex (self for the owned prefix).
    pub owner: Vec<MachineId>,
    /// Vertex data copies (owned = authoritative, ghosts = cached).
    pub vdata: Vec<V>,
    /// Vertex data versions (bumped on write; ghosts track last applied).
    pub vversion: Vec<u64>,
    /// CSR offsets over owned vertices only (scopes are assembled for
    /// owned centers; ghosts need no adjacency).
    pub adj_offsets: Vec<u32>,
    /// CSR payload: (local neighbor, local edge).
    pub adj: Vec<(LocalVid, LocalEid)>,
    /// Local edge → global edge id.
    pub le2g: Vec<EdgeId>,
    /// Global edge → local edge id.
    pub ge2l: HashMap<EdgeId, LocalEid>,
    /// Edge data copies.
    pub edata: Vec<E>,
    /// Edge data versions.
    pub eversion: Vec<u64>,
    /// For each owned vertex: machines holding it as a ghost (sorted).
    pub mirrors: Vec<Vec<MachineId>>,
    /// For each local edge: the other machine holding a copy, if any.
    pub edge_mirror: Vec<Option<MachineId>>,
}

impl<V: Clone, E: Clone> LocalGraph<V, E> {
    /// Build machine `m`'s local graph from the global graph + partition.
    /// (The paper builds this by merging atom files; in-process we read
    /// from the already-loaded global graph, which models the same
    /// result.)
    pub fn build(g: &Graph<V, E>, part: &Partition, m: MachineId) -> Self {
        let mut l2g: Vec<VertexId> = Vec::new();
        let mut g2l: HashMap<VertexId, LocalVid> = HashMap::new();
        // Owned prefix.
        for v in g.vertex_ids() {
            if part.owner(v) == m {
                g2l.insert(v, l2g.len() as LocalVid);
                l2g.push(v);
            }
        }
        let owned = l2g.len();
        // Ghosts: neighbors of owned vertices owned elsewhere.
        for i in 0..owned {
            let v = l2g[i];
            for &(u, _) in g.neighbors(v) {
                if part.owner(u) != m && !g2l.contains_key(&u) {
                    g2l.insert(u, l2g.len() as LocalVid);
                    l2g.push(u);
                }
            }
        }
        // Local edges: every global edge incident to an owned vertex.
        let mut le2g: Vec<EdgeId> = Vec::new();
        let mut ge2l: HashMap<EdgeId, LocalEid> = HashMap::new();
        let mut adj_offsets = vec![0u32; owned + 1];
        let mut adj: Vec<(LocalVid, LocalEid)> = Vec::new();
        for i in 0..owned {
            let v = l2g[i];
            for &(u, e) in g.neighbors(v) {
                let le = *ge2l.entry(e).or_insert_with(|| {
                    le2g.push(e);
                    (le2g.len() - 1) as LocalEid
                });
                adj.push((g2l[&u], le));
            }
            adj_offsets[i + 1] = adj.len() as u32;
        }
        // Data copies.
        let vdata: Vec<V> = l2g.iter().map(|&v| g.vertex_data(v).clone()).collect();
        let edata: Vec<E> = le2g.iter().map(|&e| g.edge_data(e).clone()).collect();
        let owner: Vec<MachineId> = l2g.iter().map(|&v| part.owner(v)).collect();
        // Mirrors of owned vertices: owners of their (distinct) remote
        // neighbors.
        let mut mirrors = vec![Vec::new(); owned];
        for i in 0..owned {
            let v = l2g[i];
            let mut ms: Vec<MachineId> = g
                .neighbors(v)
                .iter()
                .map(|&(u, _)| part.owner(u))
                .filter(|&o| o != m)
                .collect();
            ms.sort_unstable();
            ms.dedup();
            mirrors[i] = ms;
        }
        // Edge mirrors: an edge incident to an owned vertex is also held by
        // the other endpoint's owner when that differs.
        let edge_mirror: Vec<Option<MachineId>> = le2g
            .iter()
            .map(|&e| {
                let (a, b) = g.endpoints(e);
                let (oa, ob) = (part.owner(a), part.owner(b));
                if oa == m && ob != m {
                    Some(ob)
                } else if ob == m && oa != m {
                    Some(oa)
                } else {
                    None
                }
            })
            .collect();
        let n_local = l2g.len();
        let n_edges = le2g.len();
        LocalGraph {
            machine: m,
            l2g,
            g2l,
            owned,
            owner,
            vdata,
            vversion: vec![0; n_local],
            adj_offsets,
            adj,
            le2g,
            ge2l,
            edata,
            eversion: vec![0; n_edges],
            mirrors,
            edge_mirror,
        }
    }

    /// Build machine `machine`'s local graph by replaying **only its own
    /// atom journals** from the on-disk store at `dir` (paper Sec. 4.1:
    /// "Each atom file is a simple binary compressed journal of graph
    /// generating commands" — Distributed GraphLab, arXiv 1204.6078).
    ///
    /// `atom_to_machine` is the phase-2 placement from
    /// [`atoms::AtomStore::place`]. The replay runs the same construction
    /// algorithm as [`LocalGraph::build`] over the journal records (whose
    /// adjacency is stored in global CSR order), so the result is
    /// field-for-field identical to the in-memory build with the matching
    /// partition — property-tested in `rust/tests/atoms_disk.rs`.
    pub fn from_atom_files(
        dir: &Path,
        atom_to_machine: &[MachineId],
        machine: MachineId,
    ) -> anyhow::Result<Self>
    where
        V: Wire,
        E: Wire,
    {
        let store = atoms::AtomStore::open(dir)?;
        store.check_types::<V, E>()?;
        if atom_to_machine.len() != store.atoms.num_atoms() {
            bail!(
                "atom placement covers {} atoms but the store has {}",
                atom_to_machine.len(),
                store.atoms.num_atoms()
            );
        }
        let n = store.num_vertices;
        let owner_of = |v: VertexId| atom_to_machine[store.atoms.atom(v)];

        // Replay this machine's journals into lookup maps.
        let mut vdata_map: HashMap<VertexId, V> = HashMap::new();
        let mut adj_map: HashMap<VertexId, Vec<(VertexId, EdgeId)>> = HashMap::new();
        let mut edge_map: HashMap<EdgeId, (VertexId, VertexId, E)> = HashMap::new();
        for atom in 0..store.atoms.num_atoms() {
            if atom_to_machine[atom] != machine {
                continue;
            }
            let (verts, ghosts, edges) = atoms::read_atom_file::<V, E>(dir, atom)?;
            for (v, adj, data) in verts {
                vdata_map.insert(v, data);
                adj_map.insert(v, adj);
            }
            for (v, data) in ghosts {
                // Ghost snapshots may duplicate vertices owned by another
                // of this machine's atoms; interior records win.
                vdata_map.entry(v).or_insert(data);
            }
            for (e, a, b, data) in edges {
                edge_map.entry(e).or_insert((a, b, data));
            }
        }

        // From here on: the same construction as `build`, reading the
        // journal maps instead of the global graph.
        let mut l2g: Vec<VertexId> = Vec::new();
        let mut g2l: HashMap<VertexId, LocalVid> = HashMap::new();
        for v in 0..n as VertexId {
            if owner_of(v) == machine {
                g2l.insert(v, l2g.len() as LocalVid);
                l2g.push(v);
            }
        }
        let owned = l2g.len();
        fn nbrs_of<'a>(
            adj_map: &'a HashMap<VertexId, Vec<(VertexId, EdgeId)>>,
            v: VertexId,
        ) -> anyhow::Result<&'a [(VertexId, EdgeId)]> {
            adj_map
                .get(&v)
                .map(Vec::as_slice)
                .with_context(|| format!("atom store: owned vertex {v} has no journal record"))
        }
        for i in 0..owned {
            let v = l2g[i];
            for &(u, _) in nbrs_of(&adj_map, v)? {
                if owner_of(u) != machine && !g2l.contains_key(&u) {
                    g2l.insert(u, l2g.len() as LocalVid);
                    l2g.push(u);
                }
            }
        }
        let mut le2g: Vec<EdgeId> = Vec::new();
        let mut ge2l: HashMap<EdgeId, LocalEid> = HashMap::new();
        let mut adj_offsets = vec![0u32; owned + 1];
        let mut adj: Vec<(LocalVid, LocalEid)> = Vec::new();
        for i in 0..owned {
            let v = l2g[i];
            for &(u, e) in nbrs_of(&adj_map, v)? {
                let le = *ge2l.entry(e).or_insert_with(|| {
                    le2g.push(e);
                    (le2g.len() - 1) as LocalEid
                });
                adj.push((g2l[&u], le));
            }
            adj_offsets[i + 1] = adj.len() as u32;
        }
        let mut vdata: Vec<V> = Vec::with_capacity(l2g.len());
        for &v in &l2g {
            let Some(data) = vdata_map.remove(&v) else {
                bail!("atom store: vertex {v} (local to machine {machine}) has no data record");
            };
            vdata.push(data);
        }
        let mut edata: Vec<E> = Vec::with_capacity(le2g.len());
        let mut edge_mirror: Vec<Option<MachineId>> = Vec::with_capacity(le2g.len());
        for &e in &le2g {
            let Some((a, b, data)) = edge_map.remove(&e) else {
                bail!("atom store: edge {e} (local to machine {machine}) has no data record");
            };
            let (oa, ob) = (owner_of(a), owner_of(b));
            edge_mirror.push(if oa == machine && ob != machine {
                Some(ob)
            } else if ob == machine && oa != machine {
                Some(oa)
            } else {
                None
            });
            edata.push(data);
        }
        let owner: Vec<MachineId> = l2g.iter().map(|&v| owner_of(v)).collect();
        let mut mirrors = vec![Vec::new(); owned];
        for i in 0..owned {
            let v = l2g[i];
            let mut ms: Vec<MachineId> = nbrs_of(&adj_map, v)?
                .iter()
                .map(|&(u, _)| owner_of(u))
                .filter(|&o| o != machine)
                .collect();
            ms.sort_unstable();
            ms.dedup();
            mirrors[i] = ms;
        }
        let n_local = l2g.len();
        let n_edges = le2g.len();
        Ok(LocalGraph {
            machine,
            l2g,
            g2l,
            owned,
            owner,
            vdata,
            vversion: vec![0; n_local],
            adj_offsets,
            adj,
            le2g,
            ge2l,
            edata,
            eversion: vec![0; n_edges],
            mirrors,
            edge_mirror,
        })
    }

    /// Whether local vertex `lv` is owned by this machine.
    #[inline]
    pub fn is_owned(&self, lv: LocalVid) -> bool {
        (lv as usize) < self.owned
    }

    /// Neighbors of owned local vertex `lv`.
    #[inline]
    pub fn neighbors(&self, lv: LocalVid) -> &[(LocalVid, LocalEid)] {
        let i = lv as usize;
        debug_assert!(i < self.owned);
        &self.adj[self.adj_offsets[i] as usize..self.adj_offsets[i + 1] as usize]
    }

    /// Degree of owned local vertex `lv`.
    #[inline]
    pub fn degree(&self, lv: LocalVid) -> usize {
        let i = lv as usize;
        (self.adj_offsets[i + 1] - self.adj_offsets[i]) as usize
    }

    /// Apply a remote vertex-data write (ghost coherence).
    pub fn apply_vertex(&mut self, v: VertexId, version: u64, data: V) {
        if let Some(&lv) = self.g2l.get(&v) {
            debug_assert!(
                version > self.vversion[lv as usize],
                "stale ghost write: v={v} incoming={version} have={}",
                self.vversion[lv as usize]
            );
            self.vdata[lv as usize] = data;
            self.vversion[lv as usize] = version;
        }
    }

    /// Apply a remote edge-data write.
    pub fn apply_edge(&mut self, e: EdgeId, version: u64, data: E) {
        if let Some(&le) = self.ge2l.get(&e) {
            debug_assert!(version > self.eversion[le as usize]);
            self.edata[le as usize] = data;
            self.eversion[le as usize] = version;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// 2-machine split of a path 0-1-2-3-4-5: machine 0 owns {0,1,2}.
    fn setup() -> (Graph<u32, u32>, Partition) {
        let mut b = GraphBuilder::new();
        b.add_vertices(6, |i| i as u32 * 10);
        for i in 0..5u32 {
            b.add_edge(i, i + 1, 100 + i);
        }
        let g = b.build();
        let part = Partition::from_assignment(vec![0, 0, 0, 1, 1, 1], 2);
        (g, part)
    }

    #[test]
    fn ghosts_are_boundary_only() {
        let (g, part) = setup();
        let lg: LocalGraph<u32, u32> = LocalGraph::build(&g, &part, 0);
        assert_eq!(lg.owned, 3);
        // Machine 0's ghosts: vertex 3 only (neighbor of owned 2).
        assert_eq!(lg.l2g.len(), 4);
        assert_eq!(lg.l2g[3], 3);
        assert!(!lg.is_owned(3));
        assert_eq!(lg.owner[3], 1);
        // Data copied correctly.
        assert_eq!(lg.vdata[3], 30);
    }

    #[test]
    fn local_edges_cover_incident() {
        let (g, part) = setup();
        let lg: LocalGraph<u32, u32> = LocalGraph::build(&g, &part, 0);
        // Edges 0-1, 1-2, 2-3 are local; 3-4, 4-5 are not.
        assert_eq!(lg.le2g.len(), 3);
        let cross = lg.ge2l[&2]; // edge 2-3
        assert_eq!(lg.edge_mirror[cross as usize], Some(1));
        let inner = lg.ge2l[&0];
        assert_eq!(lg.edge_mirror[inner as usize], None);
    }

    #[test]
    fn mirrors_computed() {
        let (g, part) = setup();
        let lg: LocalGraph<u32, u32> = LocalGraph::build(&g, &part, 0);
        // Owned vertex 2 (local 2) borders machine 1.
        assert_eq!(lg.mirrors[2], vec![1]);
        assert!(lg.mirrors[0].is_empty());
        assert!(lg.mirrors[1].is_empty());
    }

    #[test]
    fn coherence_apply() {
        let (g, part) = setup();
        let mut lg: LocalGraph<u32, u32> = LocalGraph::build(&g, &part, 0);
        lg.apply_vertex(3, 1, 999);
        assert_eq!(lg.vdata[3], 999);
        assert_eq!(lg.vversion[3], 1);
        // Unknown vertex is ignored (not ghosted here).
        lg.apply_vertex(5, 1, 1);
        assert!(!lg.g2l.contains_key(&5));
    }

    #[test]
    fn machines_cover_graph_disjointly() {
        let (g, part) = setup();
        let lg0: LocalGraph<u32, u32> = LocalGraph::build(&g, &part, 0);
        let lg1: LocalGraph<u32, u32> = LocalGraph::build(&g, &part, 1);
        assert_eq!(lg0.owned + lg1.owned, g.num_vertices());
        // Each machine's scope data is complete: every neighbor of an
        // owned vertex resolves locally.
        for lg in [&lg0, &lg1] {
            for lv in 0..lg.owned as LocalVid {
                for &(nbr, le) in lg.neighbors(lv) {
                    assert!((nbr as usize) < lg.l2g.len());
                    assert!((le as usize) < lg.le2g.len());
                }
            }
        }
    }
}
