//! The experiment lab: config-driven sweeps → supervised runs →
//! structured records → an append-only run database → regression
//! reports.
//!
//! The paper's argument rests on a systematic evaluation (Fig. 6's
//! scaling curves, Fig. 8(b)'s pipelined-locking sweep over 64 EC2
//! nodes); this module is the harness shape that makes such sweeps a
//! one-command job here. Four small stages, in the classic
//! collector → executor → ingestor → storage arrangement:
//!
//! * [`config`] — a JSON sweep description (engine × transport ×
//!   machines × app × scale × scheduler axes) expands into explicit
//!   cells; shipped presets subsume the historical `bench-*`
//!   subcommands.
//! * [`exec`] — supervises each cell as a child `graphlab` process
//!   (timeouts, retry-on-port-conflict, optional CPU pinning) or runs
//!   it in-process.
//! * [`ingest`] — parses run stdout (`lab-metric` lines from
//!   [`crate::engine::ExecStats::lab_metric_line`], `probe` lines, byte
//!   reports) into typed records; garbage in, typed errors out.
//! * [`store`] / [`report`] — append-only JSONL run database under
//!   `artifacts/lab/`, per-cell medians, latest-vs-baseline regression
//!   deltas.
//!
//! [`micro`] holds the non-engine workloads (wire codec, atom store,
//! transport ping-pong). [`json`] is the dependency-free JSON codec the
//! configs and database ride on. The CLI face is `graphlab lab` /
//! `graphlab lab report` / `graphlab lab micro` in `main.rs`; docs in
//! `BENCHMARKS.md` (schema, metrics glossary) and `EXPERIMENTS.md`
//! (per-figure sweep configs).

pub mod config;
pub mod exec;
pub mod ingest;
pub mod json;
pub mod micro;
pub mod report;
pub mod store;

pub use config::{Cell, SweepConfig};
pub use exec::{run_sweep, ExecOpts, SweepSummary};
pub use ingest::{parse_run_output, IngestError, ParsedRun};
pub use store::{Outcome, RunDb, RunRecord};
