//! Ingestor: parse a run's stdout into structured metrics.
//!
//! Three line shapes carry data; everything else (progress chatter,
//! `done:` summaries, warnings) is ignored:
//!
//! - `lab-metric k=v k=v …` — the stable machine-readable stats line
//!   emitted by [`crate::engine::ExecStats::lab_metric_line`] and by the
//!   micro-benchmarks. Values are numbers, `;`-separated number lists, or
//!   bare strings. A malformed pair on a `lab-metric` line is a typed
//!   error (the line claimed to be machine-readable and lied).
//! - `probe <key>=<float>` — the convergence probe `graphlab run` prints
//!   (e.g. `probe total_rank=123.456789000`).
//! - `bytes sent per machine: [a, b, c]` — the per-machine byte report
//!   (Rust `Debug` format of a `Vec<u64>`).
//!
//! The parser is total: truncated or garbage output yields a typed
//! [`IngestError`], never a panic, so a crashed child's half-written
//! stdout degrades into an `error` row in the run database.

use std::fmt;

/// Why a run's output could not be ingested.
#[derive(Debug, Clone, PartialEq)]
pub enum IngestError {
    /// No `lab-metric` line at all — the run died before printing stats.
    NoMetrics,
    /// A `lab-metric` line contained a token that is not `key=value`.
    BadPair { line_no: usize, pair: String },
    /// A numeric-looking value failed to parse (e.g. truncated mid-write).
    BadNumber { line_no: usize, key: String, value: String },
    /// A `bytes sent per machine:` report that is not a `[u64, …]` list.
    BadByteReport { line_no: usize },
}

impl fmt::Display for IngestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IngestError::NoMetrics => {
                write!(f, "no lab-metric line in run output (run died before reporting stats?)")
            }
            IngestError::BadPair { line_no, pair } => {
                write!(f, "line {line_no}: lab-metric token '{pair}' is not key=value")
            }
            IngestError::BadNumber { line_no, key, value } => {
                write!(f, "line {line_no}: lab-metric {key}='{value}' is not a number")
            }
            IngestError::BadByteReport { line_no } => {
                write!(f, "line {line_no}: malformed per-machine byte report")
            }
        }
    }
}

impl std::error::Error for IngestError {}

/// A metric value on a `lab-metric` line.
#[derive(Debug, Clone, PartialEq)]
pub enum MetricValue {
    /// A single number.
    Num(f64),
    /// A `;`-separated number list (e.g. `bytes_per_machine=10;12;9`).
    List(Vec<f64>),
    /// Anything non-numeric (e.g. `engine=chromatic`).
    Str(String),
}

impl MetricValue {
    /// The value as a number, if it is one.
    pub fn as_num(&self) -> Option<f64> {
        match self {
            MetricValue::Num(v) => Some(*v),
            _ => None,
        }
    }
}

/// Everything extracted from one run's stdout.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParsedRun {
    /// Key→value pairs from `lab-metric` lines, in order of appearance.
    /// Later lines append; duplicate keys keep the *last* value (a
    /// restarted in-run phase overrides its earlier report).
    pub metrics: Vec<(String, MetricValue)>,
    /// `probe <key>=<v>` lines, in order.
    pub probes: Vec<(String, f64)>,
    /// The per-machine byte report, if printed.
    pub bytes_per_machine: Option<Vec<u64>>,
}

impl ParsedRun {
    /// Last value recorded for `key` on any `lab-metric` line.
    pub fn metric(&self, key: &str) -> Option<&MetricValue> {
        self.metrics.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Numeric metric shorthand.
    pub fn num(&self, key: &str) -> Option<f64> {
        self.metric(key).and_then(|v| v.as_num())
    }

    /// Last probe value for `key`.
    pub fn probe(&self, key: &str) -> Option<f64> {
        self.probes.iter().rev().find(|(k, _)| k == key).map(|(_, v)| *v)
    }
}

/// Parse a complete run's stdout. Requires at least one `lab-metric`
/// line; use [`parse_lenient`] when stats are optional.
pub fn parse_run_output(text: &str) -> Result<ParsedRun, IngestError> {
    let parsed = parse_lenient(text)?;
    if parsed.metrics.is_empty() {
        return Err(IngestError::NoMetrics);
    }
    Ok(parsed)
}

/// Like [`parse_run_output`] but an output with zero `lab-metric` lines
/// is fine (empty [`ParsedRun`]). Malformed data lines are still errors.
pub fn parse_lenient(text: &str) -> Result<ParsedRun, IngestError> {
    let mut out = ParsedRun::default();
    for (idx, line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = line.trim();
        if let Some(rest) = line.strip_prefix("lab-metric ") {
            parse_metric_pairs(rest, line_no, &mut out.metrics)?;
        } else if let Some(rest) = line.strip_prefix("probe ") {
            // Probe lines come from run_generic's `probe {key}={v:.9}`.
            // Anything else starting with "probe " is chatter: skip it
            // silently rather than erroring on prose.
            if let Some((key, val)) = rest.split_once('=') {
                if let Ok(v) = val.trim().parse::<f64>() {
                    out.probes.push((key.trim().to_string(), v));
                }
            }
        } else if let Some(rest) = line.strip_prefix("bytes sent per machine:") {
            out.bytes_per_machine = Some(parse_byte_report(rest, line_no)?);
        }
    }
    Ok(out)
}

fn parse_metric_pairs(
    rest: &str,
    line_no: usize,
    metrics: &mut Vec<(String, MetricValue)>,
) -> Result<(), IngestError> {
    for token in rest.split_whitespace() {
        let Some((key, value)) = token.split_once('=') else {
            return Err(IngestError::BadPair { line_no, pair: token.to_string() });
        };
        if key.is_empty() {
            return Err(IngestError::BadPair { line_no, pair: token.to_string() });
        }
        let parsed = if value.contains(';') {
            let mut nums = Vec::new();
            for part in value.split(';') {
                match part.parse::<f64>() {
                    Ok(v) if v.is_finite() => nums.push(v),
                    _ => {
                        return Err(IngestError::BadNumber {
                            line_no,
                            key: key.to_string(),
                            value: value.to_string(),
                        })
                    }
                }
            }
            MetricValue::List(nums)
        } else {
            match value.parse::<f64>() {
                Ok(v) if v.is_finite() => MetricValue::Num(v),
                // Non-numeric values are legitimate strings (engine=...)
                // unless they *look* numeric but are truncated — a string
                // starting with a digit, '-', or '.' claimed numberhood.
                _ if value.starts_with(|c: char| c.is_ascii_digit() || c == '-' || c == '.') => {
                    return Err(IngestError::BadNumber {
                        line_no,
                        key: key.to_string(),
                        value: value.to_string(),
                    });
                }
                _ => MetricValue::Str(value.to_string()),
            }
        };
        metrics.push((key.to_string(), parsed));
    }
    Ok(())
}

fn parse_byte_report(rest: &str, line_no: usize) -> Result<Vec<u64>, IngestError> {
    let body = rest.trim();
    let inner = body
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or(IngestError::BadByteReport { line_no })?;
    let inner = inner.trim();
    if inner.is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|part| {
            part.trim().parse::<u64>().map_err(|_| IngestError::BadByteReport { line_no })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shaped like real `graphlab run` output (PR 4's byte report, PR 2's
    /// probe line, this PR's lab-metric line) plus chatter to ignore.
    const REAL: &str = "\
partitioned 1000 vertices over 2 atoms
machine 0: 500 vertices (480 owned, 20 ghosts)
lab-metric updates=12000 sweeps=12 seconds=0.512000 updates_per_sec=23437.5 balance=1.04 machines=2 bytes_sent=20480 msgs_sent=96 updates_per_machine=6010;5990 bytes_per_machine=10240;10240
bytes sent per machine: [10240, 10240]
probe total_rank=999.999999123
done: pagerank chromatic 2 machines in 0.512s
";

    #[test]
    fn parses_real_output() {
        let p = parse_run_output(REAL).unwrap();
        assert_eq!(p.num("updates"), Some(12000.0));
        assert_eq!(p.num("updates_per_sec"), Some(23437.5));
        assert_eq!(p.num("machines"), Some(2.0));
        assert_eq!(
            p.metric("bytes_per_machine"),
            Some(&MetricValue::List(vec![10240.0, 10240.0]))
        );
        assert_eq!(p.bytes_per_machine, Some(vec![10240, 10240]));
        assert_eq!(p.probe("total_rank"), Some(999.999999123));
    }

    #[test]
    fn no_metric_line_is_typed_error() {
        let out = "partitioned 1000 vertices\nprobe total_rank=1.0\n";
        assert_eq!(parse_run_output(out).unwrap_err(), IngestError::NoMetrics);
        // ... but lenient parsing still recovers the probe.
        let p = parse_lenient(out).unwrap();
        assert_eq!(p.probe("total_rank"), Some(1.0));
    }

    #[test]
    fn truncated_metric_line_is_typed_error() {
        // A child killed mid-write leaves a dangling token.
        let out = "lab-metric updates=12000 seconds=0.5 updates_per\n";
        match parse_run_output(out).unwrap_err() {
            IngestError::BadPair { line_no: 1, pair } => assert_eq!(pair, "updates_per"),
            other => panic!("wrong error: {other:?}"),
        }
        // ... or a half-written number.
        let out = "lab-metric updates=12000 seconds=0.5 updates_per_sec=234e\n";
        match parse_run_output(out).unwrap_err() {
            IngestError::BadNumber { key, value, .. } => {
                assert_eq!(key, "updates_per_sec");
                assert_eq!(value, "234e");
            }
            other => panic!("wrong error: {other:?}"),
        }
    }

    #[test]
    fn truncated_list_is_typed_error() {
        let out = "lab-metric bytes_per_machine=10240;102\u{0}\n";
        assert!(matches!(
            parse_run_output(out).unwrap_err(),
            IngestError::BadNumber { .. }
        ));
    }

    #[test]
    fn garbage_byte_report_is_typed_error() {
        for bad in ["bytes sent per machine: [10, oops]", "bytes sent per machine: 10, 20"] {
            assert!(matches!(
                parse_lenient(bad).unwrap_err(),
                IngestError::BadByteReport { line_no: 1 }
            ));
        }
        // Empty vec (0 machines never happens, but Debug prints `[]`).
        let p = parse_lenient("bytes sent per machine: []").unwrap();
        assert_eq!(p.bytes_per_machine, Some(vec![]));
    }

    #[test]
    fn binary_garbage_does_not_panic() {
        let garbage = "\u{0}\u{1}\u{FFFD}žžž\nlab-metric\u{0}x=1\nnot a line";
        // Not prefixed with "lab-metric " (NUL breaks the prefix) → no
        // metrics → NoMetrics, not a panic.
        assert_eq!(parse_run_output(garbage).unwrap_err(), IngestError::NoMetrics);
    }

    #[test]
    fn string_metrics_and_last_value_wins() {
        let out = "lab-metric engine=chromatic updates=5\nlab-metric updates=9\n";
        let p = parse_run_output(out).unwrap();
        assert_eq!(p.metric("engine"), Some(&MetricValue::Str("chromatic".into())));
        assert_eq!(p.num("updates"), Some(9.0));
    }
}
