//! Sweep configs: a JSON-described experiment matrix → a list of cells.
//!
//! A config names the axes of a sweep (apps × engines × transports ×
//! machines × threads × graph scales × schedulers × maxpending depths,
//! plus micro-benchmark cells) and the fixed run parameters (sweeps,
//! seed, eps, injected latency, reps, timeout, retries, CPU pinning).
//! [`SweepConfig::expand`] crosses the axes into [`Cell`]s — one cell per
//! distinct work item, each with a stable fully-qualified id that the run
//! database keys on. Unknown config keys are an error (a typo in a sweep
//! file must not silently produce the wrong matrix), and the `"quick"`
//! sub-object overlays the top level when the `--quick` flag is set, so
//! one file carries both the paper-scale matrix and its CI smoke cut.
//!
//! The shipped preset configs under `configs/` (embedded at compile time)
//! subsume the four historical bench subcommands: `sched` (BENCH_pr2),
//! `engines` (BENCH_pr3), `wire` (BENCH_pr4), `net` (BENCH_pr5), plus the
//! paper-figure sweeps `fig6b` and `fig8b`, the locking-engine scaling
//! sweep `locking_scale` (threads × maxpending), and the default `quick`
//! smoke.

use anyhow::{anyhow, bail, Context as _, Result};

use super::json::Json;

/// Known micro-benchmark cell names (see [`crate::lab::micro`]).
pub const MICRO_NAMES: [&str; 6] = [
    "wire-codec",
    "atom-store",
    "net-pingpong-inproc",
    "net-pingpong-tcp",
    "frame-pool",
    "coalesce",
];

/// Shipped preset names, in `--preset all` order. Each maps 1:1 onto a
/// `configs/<name>.json` file embedded at compile time.
pub const PRESETS: [&str; 9] = [
    "quick",
    "sched",
    "engines",
    "wire",
    "net",
    "serve",
    "fig6b",
    "fig8b",
    "locking_scale",
];

/// The presets `--preset all` expands to: the four historical bench
/// subcommands' workloads (`bench-sched`/`bench-engines`/`bench-wire`/
/// `bench-net` → `sched`/`engines`/`wire`/`net`) plus the serving-mode
/// sweep (`bench-serve` → `serve`).
pub const PRESET_ALL: [&str; 5] = ["sched", "engines", "wire", "net", "serve"];

/// The JSON text of a shipped preset config.
pub fn preset_text(name: &str) -> Result<&'static str> {
    Ok(match name {
        "quick" => include_str!("../../../configs/quick.json"),
        "sched" => include_str!("../../../configs/sched.json"),
        "engines" => include_str!("../../../configs/engines.json"),
        "wire" => include_str!("../../../configs/wire.json"),
        "net" => include_str!("../../../configs/net.json"),
        "serve" => include_str!("../../../configs/serve.json"),
        "fig6b" => include_str!("../../../configs/fig6b.json"),
        "fig8b" => include_str!("../../../configs/fig8b.json"),
        "locking_scale" => include_str!("../../../configs/locking_scale.json"),
        other => bail!(
            "unknown preset '{other}' (one of: {}, or 'all' for {})",
            PRESETS.join("|"),
            PRESET_ALL.join("+")
        ),
    })
}

/// One sweep: the cross-product axes plus fixed run parameters.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Sweep name, recorded on every run row.
    pub name: String,
    /// Application axis (`pagerank|als|ner|coseg|gibbs`).
    pub apps: Vec<String>,
    /// Engine axis (`shared|chromatic|locking`).
    pub engines: Vec<String>,
    /// Transport axis (`inproc|tcp`); normalized away for `shared`.
    pub transports: Vec<String>,
    /// Machine-count axis (distributed engines; normalized to 1 for shared).
    pub machines: Vec<usize>,
    /// Worker-thread axis (shared / chromatic threads-per-machine).
    pub threads: Vec<usize>,
    /// Graph-scale axis: the app's primary size flag (`--n` for pagerank).
    pub scales: Vec<u64>,
    /// Scheduler axis (`default` = the engine's own default policy).
    pub schedulers: Vec<String>,
    /// Lock-pipelining depth axis (locking engine; Fig. 8(b)).
    pub maxpendings: Vec<usize>,
    /// Micro-benchmark cells (crossed with `scales` only).
    pub micros: Vec<String>,
    /// Serving-mode mutation rates (mutations per batch). A non-empty
    /// list adds `bench-serve` cells crossing transports × machines ×
    /// scales × mutrates.
    pub mutrates: Vec<u64>,
    /// Sweep budget per run (`--sweeps`).
    pub sweeps: u64,
    /// Seed for datagen/partitioning/schedulers (`--seed`).
    pub seed: u64,
    /// PageRank tolerance; `0` keeps every update rescheduling so all
    /// cells execute the same capped workload (the bench convention).
    pub eps: Option<f64>,
    /// Injected one-way latency in µs (in-proc transport only).
    pub latency_us: Option<u64>,
    /// Repetitions per cell (medians are taken across reps).
    pub reps: usize,
    /// Per-run wall-clock timeout (child runs are killed past this).
    pub timeout_secs: u64,
    /// Retries per run on port-conflict failures.
    pub retries: u32,
    /// Pin each run to a contiguous block of logical CPUs via `taskset`.
    pub pin_cpus: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            name: "unnamed".into(),
            apps: vec![],
            engines: vec![],
            transports: vec!["inproc".into()],
            machines: vec![2],
            threads: vec![2],
            scales: vec![10_000],
            schedulers: vec!["default".into()],
            maxpendings: vec![64],
            micros: vec![],
            mutrates: vec![],
            sweeps: 10,
            seed: 1,
            eps: None,
            latency_us: None,
            reps: 1,
            timeout_secs: 300,
            retries: 2,
            pin_cpus: false,
        }
    }
}

impl SweepConfig {
    /// Parse a sweep config from JSON text. With `quick`, the `"quick"`
    /// sub-object (if present) overlays the top-level fields.
    pub fn from_json_text(text: &str, quick: bool) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut cfg = SweepConfig::default();
        apply_fields(&mut cfg, &root, true)?;
        if quick {
            if let Some(q) = root.get("quick") {
                apply_fields(&mut cfg, q, false)
                    .context("in the \"quick\" overlay")?;
            }
        }
        if cfg.apps.is_empty() && cfg.micros.is_empty() && cfg.mutrates.is_empty() {
            bail!(
                "config '{}' lists no apps, no micros, and no mutrates: nothing to run",
                cfg.name
            );
        }
        if !cfg.apps.is_empty() && cfg.engines.is_empty() {
            bail!("config '{}' lists apps but no engines", cfg.name);
        }
        for m in &cfg.micros {
            if !MICRO_NAMES.contains(&m.as_str()) {
                bail!(
                    "config '{}': unknown micro '{m}' (one of: {})",
                    cfg.name,
                    MICRO_NAMES.join("|")
                );
            }
        }
        for axis in [&cfg.machines, &cfg.threads] {
            if axis.iter().any(|&v| v == 0) {
                bail!("config '{}': machine/thread counts must be >= 1", cfg.name);
            }
        }
        Ok(cfg)
    }

    /// Load a shipped preset by name.
    pub fn preset(name: &str, quick: bool) -> Result<Self> {
        SweepConfig::from_json_text(preset_text(name)?, quick)
            .with_context(|| format!("preset '{name}'"))
    }

    /// Cross the axes into the cell list. Axis combinations that differ
    /// only in a dimension the engine ignores are normalized and deduped
    /// (the shared engine has no transport or machine count; only locking
    /// uses maxpending), so each cell is a genuinely distinct work item.
    pub fn expand(&self) -> Vec<Cell> {
        let mut cells: Vec<Cell> = Vec::new();
        let mut seen: Vec<String> = Vec::new();
        for app in &self.apps {
            for engine in &self.engines {
                for transport in &self.transports {
                    for &machines in &self.machines {
                        for &threads in &self.threads {
                            for &scale in &self.scales {
                                for sched in &self.schedulers {
                                    for &maxpending in &self.maxpendings {
                                        let mut cell = Cell {
                                            kind: CellKind::Engine,
                                            app: app.clone(),
                                            engine: engine.clone(),
                                            transport: transport.clone(),
                                            machines,
                                            threads,
                                            scale,
                                            scheduler: sched.clone(),
                                            maxpending,
                                            mutrate: 0,
                                            sweeps: self.sweeps,
                                            seed: self.seed,
                                            eps: self.eps,
                                            latency_us: self.latency_us,
                                        };
                                        cell.normalize();
                                        let id = cell.id();
                                        if !seen.contains(&id) {
                                            seen.push(id);
                                            cells.push(cell);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        for micro in &self.micros {
            for &scale in &self.scales {
                let cell = Cell {
                    kind: CellKind::Micro,
                    app: micro.clone(),
                    engine: "-".into(),
                    transport: "-".into(),
                    machines: 1,
                    threads: 1,
                    scale,
                    scheduler: "-".into(),
                    maxpending: 0,
                    mutrate: 0,
                    sweeps: self.sweeps,
                    seed: self.seed,
                    eps: None,
                    latency_us: None,
                };
                let id = cell.id();
                if !seen.contains(&id) {
                    seen.push(id);
                    cells.push(cell);
                }
            }
        }
        for &mutrate in &self.mutrates {
            for transport in &self.transports {
                for &machines in &self.machines {
                    for &scale in &self.scales {
                        let cell = Cell {
                            kind: CellKind::Serve,
                            app: "serve".into(),
                            engine: "-".into(),
                            transport: transport.clone(),
                            machines,
                            threads: 1,
                            scale,
                            scheduler: "-".into(),
                            maxpending: 0,
                            mutrate,
                            sweeps: self.sweeps,
                            seed: self.seed,
                            eps: self.eps,
                            latency_us: None,
                        };
                        let id = cell.id();
                        if !seen.contains(&id) {
                            seen.push(id);
                            cells.push(cell);
                        }
                    }
                }
            }
        }
        cells
    }
}

/// Apply one JSON object's fields onto `cfg`. `top_level` allows the
/// `name`/`quick` keys; the quick overlay may restate any axis or scalar
/// but not rename the sweep.
fn apply_fields(cfg: &mut SweepConfig, obj: &Json, top_level: bool) -> Result<()> {
    let Json::Obj(fields) = obj else {
        bail!("expected a JSON object");
    };
    for (key, val) in fields {
        match key.as_str() {
            "name" if top_level => {
                cfg.name = str_field(val, key)?;
            }
            "quick" if top_level => {} // applied separately by the caller
            "apps" => cfg.apps = str_list(val, key)?,
            "engines" => cfg.engines = str_list(val, key)?,
            "transports" => cfg.transports = str_list(val, key)?,
            "machines" => cfg.machines = usize_list(val, key)?,
            "threads" => cfg.threads = usize_list(val, key)?,
            "scales" => cfg.scales = u64_list(val, key)?,
            "schedulers" => cfg.schedulers = str_list(val, key)?,
            "maxpendings" => cfg.maxpendings = usize_list(val, key)?,
            "micros" => cfg.micros = str_list(val, key)?,
            "mutrates" => cfg.mutrates = u64_list(val, key)?,
            "sweeps" => cfg.sweeps = u64_field(val, key)?,
            "seed" => cfg.seed = u64_field(val, key)?,
            "eps" => {
                cfg.eps = Some(
                    val.as_f64()
                        .ok_or_else(|| anyhow!("config key '{key}': expected a number"))?,
                )
            }
            "latency_us" => cfg.latency_us = Some(u64_field(val, key)?),
            "reps" => cfg.reps = u64_field(val, key)?.max(1) as usize,
            "timeout_secs" => cfg.timeout_secs = u64_field(val, key)?,
            "retries" => cfg.retries = u64_field(val, key)? as u32,
            "pin_cpus" => {
                cfg.pin_cpus = val
                    .as_bool()
                    .ok_or_else(|| anyhow!("config key '{key}': expected true/false"))?
            }
            other => bail!(
                "unknown config key '{other}' (a typo here would silently \
                 change the sweep matrix, so unknown keys are rejected)"
            ),
        }
    }
    Ok(())
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    Ok(v.as_str()
        .ok_or_else(|| anyhow!("config key '{key}': expected a string"))?
        .to_string())
}

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    v.as_u64()
        .ok_or_else(|| anyhow!("config key '{key}': expected a non-negative integer"))
}

fn str_list(v: &Json, key: &str) -> Result<Vec<String>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("config key '{key}': expected an array of strings"))?;
    arr.iter()
        .map(|x| {
            Ok(x.as_str()
                .ok_or_else(|| anyhow!("config key '{key}': expected strings"))?
                .to_string())
        })
        .collect()
}

fn u64_list(v: &Json, key: &str) -> Result<Vec<u64>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("config key '{key}': expected an array of integers"))?;
    arr.iter()
        .map(|x| {
            x.as_u64()
                .ok_or_else(|| anyhow!("config key '{key}': expected non-negative integers"))
        })
        .collect()
}

fn usize_list(v: &Json, key: &str) -> Result<Vec<usize>> {
    Ok(u64_list(v, key)?.into_iter().map(|x| x as usize).collect())
}

/// What kind of work a cell is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellKind {
    /// A full engine run (`graphlab run <app> …` in a child process).
    Engine,
    /// A micro-benchmark (`graphlab lab micro <name> …`).
    Micro,
    /// A serving-mode bench (`graphlab bench-serve …`): resident cluster,
    /// streaming mutation batches, query latency.
    Serve,
}

/// One work item of a sweep: a fully-resolved point in the matrix.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Engine run or micro-benchmark.
    pub kind: CellKind,
    /// App name (engine cells) or micro name (micro cells).
    pub app: String,
    /// Engine (`-` for micros).
    pub engine: String,
    /// Transport (`-` where irrelevant).
    pub transport: String,
    /// Machine count.
    pub machines: usize,
    /// Worker threads.
    pub threads: usize,
    /// Graph scale (the app's primary size flag).
    pub scale: u64,
    /// Scheduler policy (`default` = engine default, `-` where ignored).
    pub scheduler: String,
    /// Lock-pipelining depth (locking engine only; 0 where ignored).
    pub maxpending: usize,
    /// Mutations per batch (serve cells only; 0 where ignored).
    pub mutrate: u64,
    /// Sweep budget.
    pub sweeps: u64,
    /// Seed.
    pub seed: u64,
    /// PageRank tolerance override.
    pub eps: Option<f64>,
    /// Injected in-proc latency (µs).
    pub latency_us: Option<u64>,
}

impl Cell {
    /// Collapse axis values the engine ignores so the cross product does
    /// not produce duplicate work items.
    fn normalize(&mut self) {
        match self.engine.as_str() {
            "shared" => {
                // No network, no machines; scheduler + threads matter.
                self.transport = "-".into();
                self.machines = 1;
                self.maxpending = 0;
                self.latency_us = None;
            }
            "chromatic" => {
                // Static schedule; maxpending is a locking knob.
                self.scheduler = "-".into();
                self.maxpending = 0;
            }
            // The locking engine keeps every axis: threads is the
            // per-machine executor-pool size since the pump/pool split
            // (it was pinned to 1 back when the engine was a single
            // event loop per machine).
            _ => {}
        }
    }

    /// Stable fully-qualified id — the run database's grouping key. Every
    /// axis value appears, so two cells with the same id are the same
    /// workload.
    pub fn id(&self) -> String {
        match self.kind {
            CellKind::Micro => format!("micro/{}/n{}", self.app, self.scale),
            CellKind::Serve => format!(
                "serve/{}/m{}/n{}/mr{}/s{}",
                self.transport, self.machines, self.scale, self.mutrate, self.sweeps
            ),
            CellKind::Engine => {
                let lat = match self.latency_us {
                    Some(us) => format!("/lat{us}us"),
                    None => String::new(),
                };
                format!(
                    "{}/{}/{}/m{}/t{}/n{}/{}/p{}/s{}{}",
                    self.app,
                    self.engine,
                    self.transport,
                    self.machines,
                    self.threads,
                    self.scale,
                    self.scheduler,
                    self.maxpending,
                    self.sweeps,
                    lat
                )
            }
        }
    }

    /// Worker parallelism of this cell (how many logical CPUs it can
    /// use), for CPU pinning.
    pub fn parallelism(&self) -> usize {
        match (self.kind, self.engine.as_str()) {
            (CellKind::Micro, _) => 2, // ping-pong echo thread at most
            // One thread per machine plus the bench driver itself.
            (CellKind::Serve, _) => self.machines + 1,
            (_, "shared") => self.threads,
            (_, "chromatic") => self.machines * self.threads,
            // threads > 1 adds a pool of `threads` executors per machine
            // on top of each machine's pump thread; at threads == 1 the
            // pump evaluates inline and is the only busy thread.
            (_, "locking") => {
                self.machines * self.threads
                    + if self.threads > 1 { self.machines } else { 0 }
            }
            _ => self.machines.max(self.threads),
        }
    }

    /// The `graphlab` argv (without the binary path) that executes this
    /// cell in a child process.
    pub fn argv(&self) -> Vec<String> {
        let mut args: Vec<String> = Vec::new();
        match self.kind {
            CellKind::Micro => {
                args.extend(["lab".into(), "micro".into(), self.app.clone()]);
                args.extend(["--n".into(), self.scale.to_string()]);
                args.extend(["--seed".into(), self.seed.to_string()]);
            }
            CellKind::Serve => {
                args.push("bench-serve".into());
                args.extend(["--machines".into(), self.machines.to_string()]);
                args.extend(["--transport".into(), self.transport.clone()]);
                args.extend(["--n".into(), self.scale.to_string()]);
                args.extend(["--mutrate".into(), self.mutrate.to_string()]);
                // The sweep budget doubles as the batch count.
                args.extend(["--batches".into(), self.sweeps.to_string()]);
                args.extend(["--seed".into(), self.seed.to_string()]);
                if let Some(eps) = self.eps {
                    args.extend(["--eps".into(), format!("{eps}")]);
                }
            }
            CellKind::Engine => {
                args.extend(["run".into(), self.app.clone()]);
                args.extend(["--engine".into(), self.engine.clone()]);
                if self.transport != "-" {
                    args.extend(["--transport".into(), self.transport.clone()]);
                }
                args.extend(["--machines".into(), self.machines.to_string()]);
                args.extend(["--threads".into(), self.threads.to_string()]);
                args.extend([scale_flag(&self.app).into(), self.scale.to_string()]);
                if self.scheduler != "default" && self.scheduler != "-" {
                    args.extend(["--scheduler".into(), self.scheduler.clone()]);
                }
                if self.maxpending > 0 {
                    args.extend(["--maxpending".into(), self.maxpending.to_string()]);
                }
                args.extend(["--sweeps".into(), self.sweeps.to_string()]);
                args.extend(["--seed".into(), self.seed.to_string()]);
                if let Some(eps) = self.eps {
                    args.extend(["--eps".into(), format!("{eps}")]);
                }
                if let Some(us) = self.latency_us {
                    args.extend(["--latency-us".into(), us.to_string()]);
                }
            }
        }
        args
    }
}

/// The app's primary size flag, which the `scales` axis drives.
pub fn scale_flag(app: &str) -> &'static str {
    match app {
        "als" => "--users",
        "ner" => "--nps",
        "coseg" => "--frames",
        "gibbs" => "--side",
        _ => "--n", // pagerank and anything pagerank-shaped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
        "name": "mini",
        "apps": ["pagerank"],
        "engines": ["chromatic", "locking"],
        "transports": ["inproc", "tcp"],
        "scales": [1000, 2000],
        "sweeps": 3,
        "eps": 0,
        "quick": { "scales": [500] }
    }"#;

    #[test]
    fn expands_the_cross_product() {
        let cfg = SweepConfig::from_json_text(MINI, false).unwrap();
        let cells = cfg.expand();
        // 2 engines × 2 transports × 2 scales = 8 distinct cells.
        assert_eq!(cells.len(), 8);
        let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
        let mut dedup = ids.clone();
        dedup.dedup();
        assert_eq!(ids.len(), dedup.len(), "duplicate cell ids: {ids:?}");
    }

    #[test]
    fn quick_overlay_applies() {
        let cfg = SweepConfig::from_json_text(MINI, true).unwrap();
        assert_eq!(cfg.scales, vec![500]);
        assert_eq!(cfg.expand().len(), 4); // one scale left
        // ... and without --quick the full matrix is untouched.
        let full = SweepConfig::from_json_text(MINI, false).unwrap();
        assert_eq!(full.scales, vec![1000, 2000]);
    }

    #[test]
    fn shared_engine_cells_are_deduped_across_transports() {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"s","apps":["pagerank"],"engines":["shared"],
                "transports":["inproc","tcp"],"machines":[2,4],"scales":[100]}"#,
            false,
        )
        .unwrap();
        // shared ignores transport and machines → exactly one cell.
        assert_eq!(cfg.expand().len(), 1);
    }

    #[test]
    fn unknown_key_is_an_error() {
        let err = SweepConfig::from_json_text(
            r#"{"name":"x","apps":["pagerank"],"engines":["shared"],"scale":[1]}"#,
            false,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown config key 'scale'"), "{err}");
    }

    #[test]
    fn empty_matrix_is_an_error() {
        assert!(SweepConfig::from_json_text(r#"{"name":"x"}"#, false).is_err());
        assert!(
            SweepConfig::from_json_text(r#"{"name":"x","apps":["pagerank"]}"#, false).is_err()
        );
    }

    #[test]
    fn engine_cell_argv_shape() {
        let cfg = SweepConfig::from_json_text(MINI, false).unwrap();
        let cell = &cfg.expand()[0];
        let argv = cell.argv();
        assert_eq!(argv[0], "run");
        assert_eq!(argv[1], "pagerank");
        assert!(argv.contains(&"--engine".to_string()));
        assert!(argv.contains(&"--eps".to_string()));
        // chromatic: scheduler normalized away, no --scheduler flag
        assert!(!argv.contains(&"--scheduler".to_string()));
    }

    #[test]
    fn micro_cells_cross_scales_only() {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"m","micros":["wire-codec","atom-store"],"scales":[100,200]}"#,
            false,
        )
        .unwrap();
        let cells = cfg.expand();
        assert_eq!(cells.len(), 4);
        assert!(cells.iter().all(|c| c.kind == CellKind::Micro));
        assert_eq!(cells[0].argv()[0..3], ["lab", "micro", "wire-codec"]);
    }

    #[test]
    fn serve_cells_cross_transports_machines_scales_mutrates() {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"srv","mutrates":[16,256],"transports":["inproc","tcp"],
                "machines":[2,3],"scales":[1000],"sweeps":4,"eps":1e-7}"#,
            false,
        )
        .unwrap();
        let cells = cfg.expand();
        assert_eq!(cells.len(), 8); // 2 mutrates × 2 transports × 2 machines
        assert!(cells.iter().all(|c| c.kind == CellKind::Serve));
        let argv = cells[0].argv();
        assert_eq!(argv[0], "bench-serve");
        assert!(argv.contains(&"--mutrate".to_string()));
        assert!(argv.contains(&"--eps".to_string()));
        let ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
        assert!(ids[0].starts_with("serve/"), "{}", ids[0]);
        let mut dedup = ids.clone();
        dedup.dedup();
        assert_eq!(ids.len(), dedup.len(), "duplicate serve cell ids: {ids:?}");
    }

    #[test]
    fn locking_cells_keep_the_threads_axis() {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"l","apps":["pagerank"],"engines":["locking"],
                "machines":[2],"threads":[1,2,4],"scales":[100]}"#,
            false,
        )
        .unwrap();
        let cells = cfg.expand();
        // threads used to be normalized to 1 for locking (duplicating
        // the axis away); since the executor-pool split all three are
        // distinct work items.
        assert_eq!(cells.len(), 3);
        let mut threads: Vec<usize> = cells.iter().map(|c| c.threads).collect();
        threads.sort_unstable();
        assert_eq!(threads, vec![1, 2, 4]);
        for c in &cells {
            assert!(c.argv().contains(&"--threads".to_string()));
            // Pool cells claim pump + executors per machine for pinning.
            let want = if c.threads > 1 {
                c.machines * (c.threads + 1)
            } else {
                c.machines
            };
            assert_eq!(c.parallelism(), want, "cell {}", c.id());
        }
    }

    #[test]
    fn unknown_micro_is_an_error() {
        let err = SweepConfig::from_json_text(
            r#"{"name":"m","micros":["warp-drive"],"scales":[100]}"#,
            false,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown micro"), "{err}");
    }
}
