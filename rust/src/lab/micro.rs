//! Micro-benchmark cells: the non-engine workloads of a sweep.
//!
//! Each micro prints `lab-metric …` lines in the same stable format the
//! engines emit (see [`crate::lab::ingest`]), so the executor ingests
//! micro cells and engine cells through one code path. The workloads are
//! the measurement loops of the historical `bench-wire` / `bench-net`
//! subcommands, re-homed here with the scale knob (`--n`) driving the
//! repetition count:
//!
//! * `wire-codec` — encode/decode throughput of the [`crate::wire`]
//!   codec over a ghost-flush-shaped payload (ALS d=20 factors).
//! * `atom-store` — save / per-machine load / full replay timings for an
//!   on-disk PageRank atom store.
//! * `net-pingpong-inproc` / `net-pingpong-tcp` — framing-layer 4 KiB
//!   frame round trips over the in-proc and loopback-TCP transports.
//! * `frame-pool` — frame encode throughput, fresh allocation per frame
//!   vs recycling buffers through a [`crate::distributed::FramePool`].
//! * `coalesce` — small-frame fan-out over loopback TCP, one write per
//!   frame vs [`crate::distributed::Endpoint::send_batch`] coalescing,
//!   with a byte-accounting parity assertion between the two passes.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::apps::{als, pagerank};
use crate::distributed::{Network, NetworkModel};
use crate::partition::atoms::{self, AtomSet};
use crate::wire::{self, Wire};

/// Run one micro by name, printing its `lab-metric` line to stdout.
/// `n` is the scale knob; `seed` feeds the data generators.
pub fn run_micro(name: &str, n: u64, seed: u64) -> Result<()> {
    println!("{}", micro_line(name, n, seed)?);
    Ok(())
}

/// Run one micro and return its `lab-metric` line (the in-proc executor
/// ingests this directly; the CLI prints it).
pub fn micro_line(name: &str, n: u64, seed: u64) -> Result<String> {
    match name {
        "wire-codec" => wire_codec(n),
        "atom-store" => atom_store(n, seed),
        "net-pingpong-inproc" => pingpong(n, false),
        "net-pingpong-tcp" => pingpong(n, true),
        "frame-pool" => frame_pool(n),
        "coalesce" => coalesce(n),
        other => bail!(
            "unknown micro '{other}' (one of: {})",
            super::config::MICRO_NAMES.join("|")
        ),
    }
}

/// Codec throughput over the shape of a chromatic ghost flush:
/// (vertex, version, data) triples with ALS d=20 factors.
fn wire_codec(n: u64) -> Result<String> {
    let d = 20usize;
    let payload: Vec<(u32, u64, als::AlsVertex)> = (0..1024u32)
        .map(|i| {
            (i, i as u64, als::AlsVertex {
                factor: vec![0.1; d],
                sse: 1.0,
                cnt: 3.0,
                is_user: i % 2 == 0,
            })
        })
        .collect();
    let mut buf = Vec::new();
    payload.encode(&mut buf);
    let frame_bytes = buf.len();
    // ~50 reps at the quick scale (n=4000), ~400 at the full (n=20000+).
    let reps = (n / 64).clamp(10, 1000) as usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        buf.clear();
        payload.encode(&mut buf);
    }
    let encode_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let mut decoded_elems = 0usize;
    for _ in 0..reps {
        let v: Vec<(u32, u64, als::AlsVertex)> = wire::from_bytes(&buf)?;
        decoded_elems += v.len();
    }
    let decode_s = t0.elapsed().as_secs_f64();
    let encode_mbps = (frame_bytes * reps) as f64 / encode_s.max(1e-9) / 1e6;
    let decode_mbps = (frame_bytes * reps) as f64 / decode_s.max(1e-9) / 1e6;
    // Combined one-pass rate is the headline (the report keys on
    // `mb_per_sec`); encode/decode split out for the curious.
    let both = (frame_bytes * reps * 2) as f64 / (encode_s + decode_s).max(1e-9) / 1e6;
    Ok(format!(
        "lab-metric micro=wire-codec payload_bytes={frame_bytes} reps={reps} \
         elements={decoded_elems} encode_mb_per_sec={encode_mbps:.1} \
         decode_mb_per_sec={decode_mbps:.1} mb_per_sec={both:.1}"
    ))
}

/// Atom-store save / machine-0 load / full replay over a PageRank web
/// graph of `n` vertices split into BFS-grown journals.
fn atom_store(n: u64, seed: u64) -> Result<String> {
    let n = n.max(256) as usize;
    let edges = crate::datagen::web_graph(n, 8, seed);
    let g = pagerank::build(n, &edges, 0.15);
    let k = (n / 128).clamp(8, 128);
    let machines = 4usize;
    let dir =
        std::env::temp_dir().join(format!("graphlab-lab-atoms-{}", std::process::id()));
    let atom_set = AtomSet::grow_bfs(&g, k, seed);
    let t0 = Instant::now();
    atom_set.save_atoms(&g, &dir)?;
    let save_s = t0.elapsed().as_secs_f64();
    let disk_bytes: u64 = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.metadata().ok())
        .map(|m| m.len())
        .sum();
    let store = atoms::AtomStore::open(&dir)?;
    let (_partition, placement) = store.place(machines);
    let t0 = Instant::now();
    let lg: crate::distributed::LocalGraph<pagerank::PrVertex, pagerank::PrEdge> =
        crate::distributed::LocalGraph::from_atom_files(
            &dir,
            &placement.atom_to_machine,
            0,
        )?;
    let local_load_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let (g2, _) = atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(&dir)?;
    let full_load_s = t0.elapsed().as_secs_f64();
    std::fs::remove_dir_all(&dir).ok();
    anyhow::ensure!(
        g2.num_vertices() == g.num_vertices() && g2.num_edges() == g.num_edges(),
        "atom-store round trip changed the graph shape"
    );
    let replay_mbps = disk_bytes as f64 / full_load_s.max(1e-9) / 1e6;
    Ok(format!(
        "lab-metric micro=atom-store n={n} atoms={k} machines={machines} \
         disk_bytes={disk_bytes} owned_vertices={} save_seconds={save_s:.6} \
         machine0_load_seconds={local_load_s:.6} full_replay_seconds={full_load_s:.6} \
         mb_per_sec={replay_mbps:.1}",
        lg.owned
    ))
}

/// Frame-buffer recycling: encode 64 KiB frames into a fresh `Vec` per
/// frame (the pre-pool send path) vs recycling one buffer through a
/// [`FramePool`] get/put cycle (the pooled path). The fresh pass pays a
/// 64 KiB allocation-and-growth per frame; the pooled pass reuses the
/// retained capacity, so its rate should sit at or above the baseline.
fn frame_pool(n: u64) -> Result<String> {
    use crate::distributed::FramePool;
    let payload = vec![0x5au8; 64 * 1024];
    let reps = n.clamp(200, 50_000) as usize;
    let mut frame_bytes = 0usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut buf = Vec::new();
        payload.encode(&mut buf);
        frame_bytes = buf.len();
        std::hint::black_box(&buf);
    }
    let fresh_s = t0.elapsed().as_secs_f64();
    let pool = FramePool::default();
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut buf = pool.get();
        payload.encode(&mut buf);
        std::hint::black_box(&buf);
        pool.put(buf);
    }
    let pooled_s = t0.elapsed().as_secs_f64();
    let fresh_mbps = (frame_bytes * reps) as f64 / fresh_s.max(1e-9) / 1e6;
    let pooled_mbps = (frame_bytes * reps) as f64 / pooled_s.max(1e-9) / 1e6;
    // The pooled rate is the headline (`mb_per_sec`): it is the path the
    // transport actually runs; the fresh rate is the regression baseline.
    Ok(format!(
        "lab-metric micro=frame-pool frame_bytes={frame_bytes} reps={reps} \
         fresh_mb_per_sec={fresh_mbps:.1} pooled_mb_per_sec={pooled_mbps:.1} \
         mb_per_sec={pooled_mbps:.1}"
    ))
}

/// Coalesced flushes: fan `reps` 256-byte messages machine 0 → machine 1
/// over loopback TCP, once with one `send` (one queue hop, one logical
/// frame) per message and once with [`crate::distributed::Endpoint::send_batch`]
/// in 32-message batches (one multi-frame buffer per batch; the writer
/// thread additionally coalesces queued buffers into vectored writes).
/// Asserts the batched pass accounts exactly the same bytes/msgs as the
/// per-frame pass — coalescing must never change the meters.
fn coalesce(n: u64) -> Result<String> {
    const BATCH: usize = 32;
    let reps = (n.clamp(320, 64_000) as usize / BATCH) * BATCH;
    let payload = vec![3u8; 256];
    let frame_bytes = wire::encoded_len(&payload) + 4;
    let pass = |batched: bool| -> Result<(f64, u64, u64)> {
        let net: Network<Vec<u8>> = Network::tcp_loopback(2)?;
        let mut eps = net.into_endpoints();
        let mut ep1 = eps.pop().unwrap();
        let ep0 = eps.pop().unwrap();
        let sink = std::thread::spawn(move || {
            for _ in 0..reps {
                ep1.recv_timeout(Duration::from_secs(30)).expect("frame lost");
            }
            ep1.send(0, vec![1u8]); // all-received ack
        });
        let t0 = Instant::now();
        if batched {
            for _ in 0..reps / BATCH {
                ep0.send_batch(1, vec![payload.clone(); BATCH]);
            }
        } else {
            for _ in 0..reps {
                ep0.send(1, payload.clone());
            }
        }
        let mut ep0 = ep0;
        ep0.recv_timeout(Duration::from_secs(30)).expect("ack lost");
        let secs = t0.elapsed().as_secs_f64();
        sink.join().map_err(|_| anyhow::anyhow!("sink thread panicked"))?;
        let stats = &ep0.stats()[0];
        Ok((
            secs,
            stats.bytes_sent.load(std::sync::atomic::Ordering::Relaxed),
            stats.msgs_sent.load(std::sync::atomic::Ordering::Relaxed),
        ))
    };
    let (per_frame_s, bytes_a, msgs_a) = pass(false)?;
    let (batched_s, bytes_b, msgs_b) = pass(true)?;
    anyhow::ensure!(
        bytes_a == bytes_b && msgs_a == msgs_b,
        "coalescing changed the accounting: per-frame {bytes_a}B/{msgs_a} msgs \
         vs batched {bytes_b}B/{msgs_b} msgs"
    );
    let per_frame_mbps = (frame_bytes * reps) as f64 / per_frame_s.max(1e-9) / 1e6;
    let batched_mbps = (frame_bytes * reps) as f64 / batched_s.max(1e-9) / 1e6;
    Ok(format!(
        "lab-metric micro=coalesce frame_bytes={frame_bytes} reps={reps} batch={BATCH} \
         accounted_bytes={bytes_a} per_frame_mb_per_sec={per_frame_mbps:.1} \
         batched_mb_per_sec={batched_mbps:.1} mb_per_sec={batched_mbps:.1}"
    ))
}

/// Framing-layer ping-pong: 4 KiB frames between 2 machines, `n` round
/// trips, over the in-proc channel network or real loopback-TCP sockets.
fn pingpong(n: u64, tcp: bool) -> Result<String> {
    let reps = n.clamp(50, 20_000) as usize;
    let payload = vec![7u8; 4096];
    // The bytes NetStats counts per frame: 4-byte frame prefix + the Vec
    // codec's own length prefix + the payload.
    let frame_bytes = wire::encoded_len(&payload) + 4;
    let net: Network<Vec<u8>> = if tcp {
        Network::tcp_loopback(2)?
    } else {
        Network::new(2, NetworkModel::default())
    };
    let mut eps = net.into_endpoints();
    let ep1 = eps.pop().unwrap();
    let mut ep0 = eps.pop().unwrap();
    let echo = std::thread::spawn(move || {
        let mut ep1 = ep1;
        for _ in 0..reps {
            let r = ep1.recv_timeout(Duration::from_secs(30)).expect("ping lost");
            ep1.send(0, r.msg);
        }
    });
    let t0 = Instant::now();
    for _ in 0..reps {
        ep0.send(1, payload.clone());
        ep0.recv_timeout(Duration::from_secs(30)).expect("pong lost");
    }
    let secs = t0.elapsed().as_secs_f64();
    echo.join().map_err(|_| anyhow::anyhow!("echo thread panicked"))?;
    let rt_us = secs / reps as f64 * 1e6;
    let mbps = (frame_bytes * 2 * reps) as f64 / secs.max(1e-9) / 1e6;
    let name = if tcp { "net-pingpong-tcp" } else { "net-pingpong-inproc" };
    // Bandwidth is named `pingpong_mb_per_sec` (not `mb_per_sec`) on
    // purpose: round-trip latency is the headline metric for this cell.
    Ok(format!(
        "lab-metric micro={name} frame_bytes={frame_bytes} reps={reps} \
         round_trip_us={rt_us:.2} pingpong_mb_per_sec={mbps:.1}"
    ))
}
