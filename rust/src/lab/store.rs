//! Run database: append-only JSONL under `artifacts/lab/`.
//!
//! Every executed cell — success, timeout, or failure — becomes exactly
//! one [`RunRecord`] appended as one line of JSON. Append-only is the
//! point: a sweep interrupted at cell 37 of 80 has lost nothing, two
//! sweeps on the same host interleave safely (appends of one line are
//! atomic at these sizes), and history accumulates so `lab report` can
//! take per-cell medians across days of runs. Corrupt or torn lines are
//! surfaced as issues and skipped, never panics — the database must
//! survive its own writers being killed.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context as _, Result};

use super::json::{obj, Json};
use super::config::{Cell, CellKind};
use super::ingest::{MetricValue, ParsedRun};

/// Default run-database path, relative to the repo root.
pub const DEFAULT_DB: &str = "artifacts/lab/runs.jsonl";
/// Default committed baseline path.
pub const DEFAULT_BASELINE: &str = "artifacts/lab/baseline.jsonl";
/// Schema version stamped on every row.
pub const SCHEMA: u64 = 1;

/// How a run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Ran to completion and its output ingested cleanly.
    Ok,
    /// Killed at the per-run timeout.
    Timeout,
    /// Non-zero exit, spawn failure, or unparseable output.
    Error,
}

impl Outcome {
    /// Stable string form used in the JSONL rows.
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Timeout => "timeout",
            Outcome::Error => "error",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s {
            "ok" => Some(Outcome::Ok),
            "timeout" => Some(Outcome::Timeout),
            "error" => Some(Outcome::Error),
            _ => None,
        }
    }
}

/// One row of the run database.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Schema version (see [`SCHEMA`]).
    pub schema: u64,
    /// Sweep-config name this run belonged to.
    pub config: String,
    /// Fully-qualified cell id (see [`Cell::id`]) — the grouping key.
    pub cell: String,
    /// Repetition index within the sweep (0-based).
    pub rep: usize,
    /// `engine` for app runs, `micro` for micro-benchmarks.
    pub kind: String,
    /// App or micro name.
    pub app: String,
    /// How the run ended.
    pub outcome: Outcome,
    /// Wall-clock seconds the executor observed (spawn → exit/kill).
    pub elapsed_s: f64,
    /// Error description for non-`ok` outcomes.
    pub error: Option<String>,
    /// Every metric the ingestor extracted, in emission order.
    pub metrics: Vec<(String, MetricValue)>,
    /// Convergence probes (`probe k=v` lines).
    pub probes: Vec<(String, f64)>,
    /// The per-machine byte report, if the run printed one.
    pub bytes_per_machine: Option<Vec<u64>>,
}

impl RunRecord {
    /// Build a record from an executed cell and its (possibly empty)
    /// parsed output.
    pub fn new(
        config: &str,
        cell: &Cell,
        rep: usize,
        outcome: Outcome,
        elapsed_s: f64,
        error: Option<String>,
        parsed: ParsedRun,
    ) -> Self {
        RunRecord {
            schema: SCHEMA,
            config: config.to_string(),
            cell: cell.id(),
            rep,
            kind: match cell.kind {
                CellKind::Engine => "engine".into(),
                CellKind::Micro => "micro".into(),
                CellKind::Serve => "serve".into(),
            },
            app: cell.app.clone(),
            outcome,
            elapsed_s,
            error,
            metrics: parsed.metrics,
            probes: parsed.probes,
            bytes_per_machine: parsed.bytes_per_machine,
        }
    }

    /// Numeric metric shorthand (last value wins, as in ingest).
    pub fn num(&self, key: &str) -> Option<f64> {
        self.metrics.iter().rev().find(|(k, _)| k == key).and_then(|(_, v)| v.as_num())
    }

    /// Serialize to one JSON object (one JSONL line via `Display`).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("schema", Json::Num(self.schema as f64)),
            ("config", Json::Str(self.config.clone())),
            ("cell", Json::Str(self.cell.clone())),
            ("rep", Json::Num(self.rep as f64)),
            ("kind", Json::Str(self.kind.clone())),
            ("app", Json::Str(self.app.clone())),
            ("outcome", Json::Str(self.outcome.name().into())),
            ("elapsed_s", Json::Num(self.elapsed_s)),
        ];
        if let Some(err) = &self.error {
            fields.push(("error", Json::Str(err.clone())));
        }
        let metrics = self
            .metrics
            .iter()
            .map(|(k, v)| {
                let jv = match v {
                    MetricValue::Num(n) => Json::Num(*n),
                    MetricValue::Str(s) => Json::Str(s.clone()),
                    MetricValue::List(l) => {
                        Json::Arr(l.iter().map(|&x| Json::Num(x)).collect())
                    }
                };
                (k.clone(), jv)
            })
            .collect();
        fields.push(("metrics", Json::Obj(metrics)));
        if !self.probes.is_empty() {
            let probes =
                self.probes.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect();
            fields.push(("probes", Json::Obj(probes)));
        }
        if let Some(bpm) = &self.bytes_per_machine {
            fields.push((
                "bytes_per_machine",
                Json::Arr(bpm.iter().map(|&b| Json::Num(b as f64)).collect()),
            ));
        }
        obj(fields)
    }

    /// Deserialize one row. `None` for rows that are valid JSON but not
    /// run records (e.g. the baseline header row carries no `cell` key).
    pub fn from_json(j: &Json) -> Option<Result<Self, String>> {
        j.get("cell")?;
        Some(Self::from_json_inner(j))
    }

    fn from_json_inner(j: &Json) -> Result<Self, String> {
        let str_of = |key: &str| -> Result<String, String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("missing or non-string field '{key}'"))?
                .to_string())
        };
        let outcome_s = str_of("outcome")?;
        let mut metrics = Vec::new();
        if let Some(Json::Obj(fields)) = j.get("metrics") {
            for (k, v) in fields {
                let mv = match v {
                    Json::Num(n) => MetricValue::Num(*n),
                    Json::Str(s) => MetricValue::Str(s.clone()),
                    Json::Arr(items) => MetricValue::List(
                        items.iter().filter_map(Json::as_f64).collect(),
                    ),
                    _ => continue,
                };
                metrics.push((k.clone(), mv));
            }
        }
        let mut probes = Vec::new();
        if let Some(Json::Obj(fields)) = j.get("probes") {
            for (k, v) in fields {
                if let Some(n) = v.as_f64() {
                    probes.push((k.clone(), n));
                }
            }
        }
        let bytes_per_machine = j.get("bytes_per_machine").and_then(Json::as_arr).map(|a| {
            a.iter().filter_map(Json::as_u64).collect()
        });
        Ok(RunRecord {
            schema: j.get("schema").and_then(Json::as_u64).unwrap_or(SCHEMA),
            config: str_of("config")?,
            cell: str_of("cell")?,
            rep: j.get("rep").and_then(Json::as_u64).unwrap_or(0) as usize,
            kind: str_of("kind")?,
            app: str_of("app")?,
            outcome: Outcome::parse(&outcome_s)
                .ok_or_else(|| format!("unknown outcome '{outcome_s}'"))?,
            elapsed_s: j.get("elapsed_s").and_then(Json::as_f64).unwrap_or(0.0),
            error: j.get("error").and_then(Json::as_str).map(str::to_string),
            metrics,
            probes,
            bytes_per_machine,
        })
    }
}

/// Handle on a JSONL run database file.
#[derive(Debug, Clone)]
pub struct RunDb {
    /// Path of the JSONL file.
    pub path: PathBuf,
}

impl RunDb {
    /// Open (without touching the filesystem yet) a database at `path`.
    pub fn at(path: impl Into<PathBuf>) -> Self {
        RunDb { path: path.into() }
    }

    /// Append one record as one line, creating parent directories and
    /// the file on first use.
    pub fn append(&self, rec: &RunRecord) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| format!("opening run db {}", self.path.display()))?;
        let mut line = rec.to_json().to_string();
        line.push('\n');
        f.write_all(line.as_bytes())
            .with_context(|| format!("appending to {}", self.path.display()))?;
        Ok(())
    }

    /// Load every well-formed record. Torn, corrupt, or non-record lines
    /// come back as human-readable issues, not errors — killing a writer
    /// mid-append must not brick the database.
    pub fn load(&self) -> Result<(Vec<RunRecord>, Vec<String>)> {
        let text = fs::read_to_string(&self.path)
            .with_context(|| format!("reading run db {}", self.path.display()))?;
        Ok(Self::parse_lines(&text))
    }

    /// Parse JSONL text into records + issues (see [`RunDb::load`]).
    pub fn parse_lines(text: &str) -> (Vec<RunRecord>, Vec<String>) {
        let mut records = Vec::new();
        let mut issues = Vec::new();
        for (idx, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match Json::parse(line) {
                Ok(j) => match RunRecord::from_json(&j) {
                    Some(Ok(rec)) => records.push(rec),
                    Some(Err(msg)) => issues.push(format!("line {}: {msg}", idx + 1)),
                    None => {} // header/comment row — fine, skip silently
                },
                Err(e) => issues.push(format!("line {}: {e}", idx + 1)),
            }
        }
        (records, issues)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::config::SweepConfig;
    use crate::lab::ingest::parse_run_output;

    fn sample_record() -> RunRecord {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"t","apps":["pagerank"],"engines":["chromatic"],
                "transports":["inproc"],"scales":[1000]}"#,
            false,
        )
        .unwrap();
        let cell = &cfg.expand()[0];
        let parsed = parse_run_output(
            "lab-metric updates=100 seconds=0.25 updates_per_sec=400 bytes_per_machine=5;7\n\
             bytes sent per machine: [5, 7]\nprobe total_rank=1.5\n",
        )
        .unwrap();
        RunRecord::new("t", cell, 0, Outcome::Ok, 0.3, None, parsed)
    }

    #[test]
    fn record_round_trips_through_jsonl() {
        let rec = sample_record();
        let line = rec.to_json().to_string();
        let back = RunRecord::from_json(&Json::parse(&line).unwrap())
            .expect("is a record")
            .expect("parses");
        assert_eq!(back.cell, rec.cell);
        assert_eq!(back.outcome, Outcome::Ok);
        assert_eq!(back.num("updates"), Some(100.0));
        assert_eq!(back.bytes_per_machine, Some(vec![5, 7]));
        assert_eq!(back.probes, vec![("total_rank".to_string(), 1.5)]);
    }

    #[test]
    fn append_then_load_survives_torn_tail() {
        let dir = std::env::temp_dir().join(format!("lab-db-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let db = RunDb::at(dir.join("runs.jsonl"));
        let _ = std::fs::remove_file(&db.path);
        let rec = sample_record();
        db.append(&rec).unwrap();
        db.append(&rec).unwrap();
        // Simulate a writer killed mid-append: torn half-line at EOF.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&db.path).unwrap();
            f.write_all(b"{\"schema\":1,\"cell\":\"half").unwrap();
        }
        let (records, issues) = db.load().unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(issues.len(), 1, "torn line must surface as an issue: {issues:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_rows_are_skipped_silently() {
        let text = "{\"note\":\"baseline for PR 7\",\"schema\":1}\n";
        let (records, issues) = RunDb::parse_lines(text);
        assert!(records.is_empty());
        assert!(issues.is_empty());
    }

    #[test]
    fn error_rows_round_trip() {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"t","apps":["pagerank"],"engines":["locking"],
                "transports":["tcp"],"scales":[500]}"#,
            false,
        )
        .unwrap();
        let cell = &cfg.expand()[0];
        let rec = RunRecord::new(
            "t",
            cell,
            1,
            Outcome::Timeout,
            30.0,
            Some("killed at 30s timeout".into()),
            Default::default(),
        );
        let line = rec.to_json().to_string();
        let back = RunRecord::from_json(&Json::parse(&line).unwrap()).unwrap().unwrap();
        assert_eq!(back.outcome, Outcome::Timeout);
        assert_eq!(back.error.as_deref(), Some("killed at 30s timeout"));
        assert_eq!(back.rep, 1);
    }
}
