//! Reporting: per-cell medians over the run database, and regression
//! deltas of the latest runs against a committed baseline.
//!
//! The report groups rows by cell id, takes the median of each cell's
//! primary metric across its `ok` runs (medians shrug off one noisy
//! neighbour-induced outlier; means do not), and — when a baseline file
//! has rows for the same cell — prints the percentage delta with the
//! metric's direction taken into account (`updates_per_sec` up is good;
//! `round_trip_us` up is a regression).

use std::fmt::Write as _;

use anyhow::Result;

use super::store::{Outcome, RunDb, RunRecord};

/// Per-cell aggregate over one database.
#[derive(Debug, Clone)]
pub struct CellStats {
    /// Cell id.
    pub cell: String,
    /// Total rows observed for the cell.
    pub runs: usize,
    /// Rows that ended `ok`.
    pub ok: usize,
    /// The primary metric's name (see [`primary_metric`]).
    pub metric: &'static str,
    /// Median of the primary metric across `ok` rows (None if no row
    /// carried it).
    pub median: Option<f64>,
    /// Median wall-clock seconds across `ok` rows.
    pub median_elapsed_s: Option<f64>,
}

/// The headline metric for a cell's rows, chosen from what the runs
/// actually reported: throughput first, then bandwidth, then latency,
/// falling back to wall clock.
pub fn primary_metric(rows: &[&RunRecord]) -> &'static str {
    for key in ["updates_per_sec", "mb_per_sec", "round_trip_us"] {
        if rows.iter().any(|r| r.num(key).is_some()) {
            return key;
        }
    }
    "elapsed_s"
}

/// Is a higher value of `metric` better?
pub fn higher_is_better(metric: &str) -> bool {
    // Latencies and durations regress upward; rates regress downward.
    !(metric.ends_with("_us") || metric.contains("seconds") || metric == "elapsed_s")
}

fn median(mut vals: Vec<f64>) -> Option<f64> {
    if vals.is_empty() {
        return None;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let mid = vals.len() / 2;
    Some(if vals.len() % 2 == 1 { vals[mid] } else { (vals[mid - 1] + vals[mid]) / 2.0 })
}

/// Group records by cell id (first-appearance order) and aggregate.
pub fn cell_stats(records: &[RunRecord]) -> Vec<CellStats> {
    let mut order: Vec<&str> = Vec::new();
    for r in records {
        if !order.contains(&r.cell.as_str()) {
            order.push(&r.cell);
        }
    }
    order
        .iter()
        .map(|cell| {
            let rows: Vec<&RunRecord> =
                records.iter().filter(|r| &r.cell == cell).collect();
            let ok_rows: Vec<&RunRecord> =
                rows.iter().copied().filter(|r| r.outcome == Outcome::Ok).collect();
            let metric = primary_metric(&ok_rows);
            let vals: Vec<f64> = ok_rows
                .iter()
                .filter_map(|r| {
                    if metric == "elapsed_s" { Some(r.elapsed_s) } else { r.num(metric) }
                })
                .collect();
            let elapsed: Vec<f64> = ok_rows.iter().map(|r| r.elapsed_s).collect();
            CellStats {
                cell: cell.to_string(),
                runs: rows.len(),
                ok: ok_rows.len(),
                metric,
                median: median(vals),
                median_elapsed_s: median(elapsed),
            }
        })
        .collect()
}

/// Render the report text: one line per cell, with a baseline delta
/// column when `baseline` has matching cells.
pub fn render(records: &[RunRecord], baseline: Option<&[RunRecord]>) -> String {
    let stats = cell_stats(records);
    let base_stats: Vec<CellStats> = baseline.map(cell_stats).unwrap_or_default();
    let mut out = String::new();
    if stats.is_empty() {
        out.push_str("run database has no rows yet — run `graphlab lab --quick` first\n");
        return out;
    }
    let width = stats.iter().map(|s| s.cell.len()).max().unwrap_or(4).max(4);
    let _ = writeln!(
        out,
        "{:<width$}  {:>4} {:>3}  {:<16} {:>14}  {:>10}  {}",
        "cell", "runs", "ok", "metric", "median", "elapsed_s", "vs baseline"
    );
    for s in &stats {
        let median_s = match s.median {
            Some(v) => format_sig(v),
            None => "-".into(),
        };
        let elapsed_s = match s.median_elapsed_s {
            Some(v) => format!("{v:.3}"),
            None => "-".into(),
        };
        let delta = match (&s.median, base_stats.iter().find(|b| b.cell == s.cell)) {
            (Some(now), Some(base)) => match base.median {
                Some(then) if then != 0.0 && base.metric == s.metric => {
                    let pct = (now - then) / then * 100.0;
                    let good = if higher_is_better(s.metric) { pct >= 0.0 } else { pct <= 0.0 };
                    format!("{pct:+.1}% {}", if good { "(ok)" } else { "(REGRESSION)" })
                }
                _ => "baseline metric mismatch".into(),
            },
            (_, None) => "no baseline".into(),
            (None, _) => "-".into(),
        };
        let _ = writeln!(
            out,
            "{:<width$}  {:>4} {:>3}  {:<16} {:>14}  {:>10}  {}",
            s.cell, s.runs, s.ok, s.metric, median_s, elapsed_s, delta
        );
    }
    let failed: usize = stats.iter().map(|s| s.runs - s.ok).sum();
    if failed > 0 {
        let _ = writeln!(out, "\n{failed} run(s) did not finish ok (see outcome/error fields)");
    }
    out
}

/// Load the databases and render (the CLI entry point's worker).
pub fn report(db: &RunDb, baseline: Option<&RunDb>) -> Result<String> {
    let (records, issues) = db.load()?;
    let base = match baseline {
        Some(b) if b.path.exists() => Some(b.load()?.0),
        _ => None,
    };
    let mut out = render(&records, base.as_deref());
    if baseline.is_some() && base.is_none() {
        let _ = writeln!(out, "(no baseline file — deltas omitted)");
    }
    for issue in &issues {
        let _ = writeln!(out, "warning: {} {issue}", db.path.display());
    }
    Ok(out)
}

fn format_sig(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::config::SweepConfig;
    use crate::lab::ingest::parse_run_output;
    use crate::lab::store::RunRecord;

    fn rec(cell_idx: usize, rep: usize, ups: f64) -> RunRecord {
        let cfg = SweepConfig::from_json_text(
            r#"{"name":"t","apps":["pagerank"],"engines":["chromatic","locking"],
                "transports":["inproc"],"scales":[1000]}"#,
            false,
        )
        .unwrap();
        let cells = cfg.expand();
        let parsed = parse_run_output(&format!(
            "lab-metric updates=100 seconds=0.5 updates_per_sec={ups}\n"
        ))
        .unwrap();
        RunRecord::new("t", &cells[cell_idx], rep, Outcome::Ok, 0.6, None, parsed)
    }

    #[test]
    fn medians_are_per_cell() {
        let records = vec![rec(0, 0, 100.0), rec(0, 1, 300.0), rec(0, 2, 200.0), rec(1, 0, 50.0)];
        let stats = cell_stats(&records);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].median, Some(200.0)); // odd count → middle
        assert_eq!(stats[1].median, Some(50.0));
        assert_eq!(stats[0].metric, "updates_per_sec");
        let even = cell_stats(&records[..2]);
        assert_eq!(even[0].median, Some(200.0)); // (100+300)/2
    }

    #[test]
    fn regression_delta_has_direction() {
        let now = vec![rec(0, 0, 90.0)];
        let base = vec![rec(0, 0, 100.0)];
        let text = render(&now, Some(&base));
        assert!(text.contains("-10.0% (REGRESSION)"), "{text}");
        // Higher throughput is an improvement, not a regression.
        let better = vec![rec(0, 0, 150.0)];
        let text = render(&better, Some(&base));
        assert!(text.contains("+50.0% (ok)"), "{text}");
    }

    #[test]
    fn lower_is_better_for_latency_metrics() {
        assert!(higher_is_better("updates_per_sec"));
        assert!(higher_is_better("mb_per_sec"));
        assert!(!higher_is_better("round_trip_us"));
        assert!(!higher_is_better("elapsed_s"));
        assert!(!higher_is_better("engine_seconds"));
    }

    #[test]
    fn missing_baseline_is_graceful() {
        let now = vec![rec(0, 0, 90.0)];
        let text = render(&now, None);
        assert!(text.contains("no baseline"), "{text}");
        let empty = render(&[], None);
        assert!(empty.contains("no rows"), "{empty}");
    }
}
