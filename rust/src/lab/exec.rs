//! Executor: supervise each cell of a sweep and record what happened.
//!
//! The default mode runs every cell as a **child process** of the
//! `graphlab` binary itself (`current_exe`, overridable via `--bin` or
//! `GRAPHLAB_BIN`): a crashed or wedged run takes down one cell, not the
//! sweep, and timing is not polluted by the collector's own allocator
//! state. Supervision per run:
//!
//! * **timeout** — the child is killed at the config's `timeout_secs`
//!   and the cell recorded as `timeout` (a wedged distributed run must
//!   not wedge the sweep);
//! * **retry on port conflict** — a run whose output carries the
//!   [`crate::distributed::PORT_CONFLICT_MARKER`] tag (or the OS's
//!   "Address already in use") lost a bind race with another process and
//!   is retried up to `retries` times; any other failure is recorded,
//!   not retried;
//! * **CPU pinning** (`pin_cpus`) — each run is prefixed with
//!   `taskset -c 0-(P-1)` where `P` is the cell's parallelism, so cells
//!   with different thread counts don't float across a loaded host. If
//!   `taskset` is missing the run proceeds unpinned with a warning.
//!
//! Every attempt's outcome — ok, timeout, or error, with whatever the
//! ingestor salvaged — is appended to the run database. `--inproc` mode
//! runs cells inside the collector process instead (no spawn, no
//! pinning, no timeout enforcement): it exists for environments where
//! spawning is unavailable (sandboxed tests) and synthesizes the same
//! stdout text, so records still flow through the one ingest path.

use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::distributed::PORT_CONFLICT_MARKER;

use super::config::{Cell, CellKind, SweepConfig};
use super::ingest;
use super::store::{Outcome, RunDb, RunRecord};

/// Executor options (from the `graphlab lab` CLI flags).
#[derive(Debug, Clone)]
pub struct ExecOpts {
    /// The run database to append to.
    pub db: RunDb,
    /// Child binary; `None` = `GRAPHLAB_BIN` or the current executable.
    pub bin: Option<PathBuf>,
    /// Run cells in-process instead of spawning children.
    pub inproc: bool,
    /// Echo child output to our own stdout (verbose mode).
    pub echo: bool,
}

/// What a sweep did, in aggregate.
#[derive(Debug, Clone, Default)]
pub struct SweepSummary {
    /// Cells in the expanded matrix.
    pub cells: usize,
    /// Run attempts recorded (cells × reps, plus retries).
    pub runs: usize,
    /// Runs that ended `ok`.
    pub ok: usize,
    /// Runs that timed out.
    pub timeouts: usize,
    /// Runs that failed.
    pub errors: usize,
}

/// Execute every cell of `cfg` (× reps), appending one record per run
/// attempt to the database. Errors only if *nothing* succeeded — partial
/// failure is data, not an excuse to lose the rest of the sweep.
pub fn run_sweep(cfg: &SweepConfig, opts: &ExecOpts) -> Result<SweepSummary> {
    let cells = cfg.expand();
    let mut summary = SweepSummary { cells: cells.len(), ..Default::default() };
    println!(
        "lab: sweep '{}': {} cells x {} rep(s) -> {}",
        cfg.name,
        cells.len(),
        cfg.reps,
        opts.db.path.display()
    );
    for (idx, cell) in cells.iter().enumerate() {
        for rep in 0..cfg.reps {
            let (outcome, elapsed_s, error, output) = if opts.inproc {
                run_inproc(cell)
            } else {
                run_child(cell, cfg, opts)
            };
            // Ingest whatever the run produced; a clean exit with
            // unparseable output downgrades to an error record.
            let (outcome, error, parsed) = match ingest::parse_lenient(&output) {
                Ok(parsed) if outcome == Outcome::Ok && parsed.metrics.is_empty() => (
                    Outcome::Error,
                    Some(ingest::IngestError::NoMetrics.to_string()),
                    parsed,
                ),
                Ok(parsed) => (outcome, error, parsed),
                Err(e) if outcome == Outcome::Ok => {
                    (Outcome::Error, Some(e.to_string()), Default::default())
                }
                // The run already failed; keep its error, salvage nothing.
                Err(_) => (outcome, error, Default::default()),
            };
            match outcome {
                Outcome::Ok => summary.ok += 1,
                Outcome::Timeout => summary.timeouts += 1,
                Outcome::Error => summary.errors += 1,
            }
            summary.runs += 1;
            let rec =
                RunRecord::new(&cfg.name, cell, rep, outcome, elapsed_s, error.clone(), parsed);
            opts.db.append(&rec)?;
            println!(
                "lab: [{}/{}] {} rep {}: {} ({:.3}s){}",
                idx + 1,
                cells.len(),
                cell.id(),
                rep,
                outcome.name(),
                elapsed_s,
                match &error {
                    Some(e) => format!(" — {e}"),
                    None => String::new(),
                }
            );
        }
    }
    if summary.ok == 0 {
        bail!(
            "sweep '{}': all {} run(s) failed — see {}",
            cfg.name,
            summary.runs,
            opts.db.path.display()
        );
    }
    Ok(summary)
}

/// Supervise one cell as a child process: spawn, drain output, enforce
/// the timeout, retry on port conflicts. Infallible by design — every
/// failure becomes an outcome, not an `Err`.
fn run_child(cell: &Cell, cfg: &SweepConfig, opts: &ExecOpts) -> (Outcome, f64, Option<String>, String) {
    let bin = match &opts.bin {
        Some(p) => p.clone(),
        None => match std::env::var_os("GRAPHLAB_BIN") {
            Some(p) => PathBuf::from(p),
            None => match std::env::current_exe() {
                Ok(p) => p,
                Err(e) => {
                    return (
                        Outcome::Error,
                        0.0,
                        Some(format!("cannot locate own binary: {e}")),
                        String::new(),
                    )
                }
            },
        },
    };
    let mut last = (Outcome::Error, 0.0, Some("never ran".to_string()), String::new());
    for attempt in 0..=cfg.retries {
        last = run_child_once(&bin, cell, cfg, opts);
        let retryable = last.0 == Outcome::Error
            && (last.3.contains(PORT_CONFLICT_MARKER)
                || last.3.contains("Address already in use"));
        if !retryable || attempt == cfg.retries {
            break;
        }
        eprintln!(
            "lab: {}: port conflict (attempt {}/{}), retrying",
            cell.id(),
            attempt + 1,
            cfg.retries + 1
        );
        // Losing a bind race means another process holds the port right
        // now; a beat of backoff makes the retry worth taking.
        std::thread::sleep(Duration::from_millis(200 * (attempt as u64 + 1)));
    }
    last
}

fn run_child_once(
    bin: &std::path::Path,
    cell: &Cell,
    cfg: &SweepConfig,
    opts: &ExecOpts,
) -> (Outcome, f64, Option<String>, String) {
    let argv = cell.argv();
    let mut cmd;
    let mut pinned = false;
    if cfg.pin_cpus {
        let cpus = cell.parallelism().max(1);
        cmd = Command::new("taskset");
        cmd.arg("-c").arg(format!("0-{}", cpus - 1)).arg(bin).args(&argv);
        pinned = true;
    } else {
        cmd = Command::new(bin);
        cmd.args(&argv);
    }
    // Supervision hook for the distributed layer: children must not
    // outlive the sweep's own per-run budget waiting for lost peers.
    cmd.env("GRAPHLAB_PEER_GRACE_SECS", cfg.timeout_secs.to_string());
    cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::piped());
    let start = Instant::now();
    let mut child = match cmd.spawn() {
        Ok(c) => c,
        Err(e) if pinned => {
            // No taskset on this host: warn once per process, run unpinned.
            eprintln!("lab: taskset unavailable ({e}); running unpinned");
            let mut cmd = Command::new(bin);
            cmd.args(&argv)
                .env("GRAPHLAB_PEER_GRACE_SECS", cfg.timeout_secs.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::piped())
                .stderr(Stdio::piped());
            match cmd.spawn() {
                Ok(c) => c,
                Err(e) => {
                    return (Outcome::Error, 0.0, Some(format!("spawn failed: {e}")), String::new())
                }
            }
        }
        Err(e) => {
            return (Outcome::Error, 0.0, Some(format!("spawn failed: {e}")), String::new())
        }
    };
    // Drain both pipes on threads — a child that fills a pipe while we
    // only poll `try_wait` would deadlock against us.
    let stdout = child.stdout.take().map(reader_thread);
    let stderr = child.stderr.take().map(reader_thread);
    let timeout = Duration::from_secs(cfg.timeout_secs.max(1));
    let status = loop {
        match child.try_wait() {
            Ok(Some(status)) => break Some(status),
            Ok(None) if start.elapsed() >= timeout => {
                let _ = child.kill();
                let _ = child.wait();
                break None;
            }
            Ok(None) => std::thread::sleep(Duration::from_millis(20)),
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                let out = join_reader(stdout);
                let err_text = join_reader(stderr);
                return (
                    Outcome::Error,
                    start.elapsed().as_secs_f64(),
                    Some(format!("wait failed: {e}")),
                    format!("{out}{err_text}"),
                );
            }
        }
    };
    let elapsed = start.elapsed().as_secs_f64();
    let out = join_reader(stdout);
    let err_text = join_reader(stderr);
    if opts.echo {
        print!("{out}");
        eprint!("{err_text}");
    }
    let combined = format!("{out}{err_text}");
    match status {
        None => (
            Outcome::Timeout,
            elapsed,
            Some(format!("killed at {}s timeout", cfg.timeout_secs)),
            combined,
        ),
        Some(s) if s.success() => (Outcome::Ok, elapsed, None, combined),
        Some(s) => {
            let tail: String = err_text.lines().last().unwrap_or("").chars().take(200).collect();
            (Outcome::Error, elapsed, Some(format!("exit {s}: {tail}")), combined)
        }
    }
}

fn reader_thread(
    mut pipe: impl std::io::Read + Send + 'static,
) -> std::thread::JoinHandle<String> {
    std::thread::spawn(move || {
        let mut buf = Vec::new();
        let _ = pipe.read_to_end(&mut buf);
        String::from_utf8_lossy(&buf).into_owned()
    })
}

fn join_reader(h: Option<std::thread::JoinHandle<String>>) -> String {
    h.and_then(|h| h.join().ok()).unwrap_or_default()
}

/// Run one cell inside this process and synthesize the same stdout text
/// a child would have printed, so ingestion is identical. Supports micro
/// cells, serve cells, and PageRank engine cells (the quick matrix);
/// anything else reports an error record directing the caller at child
/// mode.
fn run_inproc(cell: &Cell) -> (Outcome, f64, Option<String>, String) {
    let start = Instant::now();
    let result = run_inproc_inner(cell);
    let elapsed = start.elapsed().as_secs_f64();
    match result {
        Ok(text) => (Outcome::Ok, elapsed, None, text),
        Err(e) => (Outcome::Error, elapsed, Some(format!("{e:#}")), String::new()),
    }
}

fn run_inproc_inner(cell: &Cell) -> Result<String> {
    use crate::apps::{self, pagerank};
    use crate::distributed::{NetworkModel, TransportKind};
    use crate::engine::{Engine, EngineKind};
    use crate::scheduler::SchedSpec;

    if cell.kind == CellKind::Micro {
        let line = super::micro::micro_line(&cell.app, cell.scale, cell.seed)?;
        return Ok(format!("{line}\n"));
    }
    if cell.kind == CellKind::Serve {
        let line = crate::serve::bench::run_bench(&crate::serve::bench::BenchOpts {
            n: cell.scale as usize,
            machines: cell.machines,
            transport: TransportKind::parse(&cell.transport)?,
            mutrate: cell.mutrate as usize,
            batches: cell.sweeps.max(1) as usize,
            eps: cell.eps.map_or(1e-7, |e| e as f32),
            seed: cell.seed,
            ..Default::default()
        })?;
        return Ok(format!("{line}\n"));
    }
    if cell.app != "pagerank" {
        bail!("in-proc mode runs pagerank cells only (got '{}'); drop --inproc", cell.app);
    }
    let n = cell.scale as usize;
    let edges = crate::datagen::web_graph(n, 8, cell.seed);
    let g = pagerank::build(n, &edges, 0.15);
    let prog = pagerank::PageRank {
        alpha: 0.15,
        eps: cell.eps.unwrap_or(0.0) as f32,
        n,
        use_pjrt: false,
    };
    let kind = EngineKind::parse(&cell.engine)?;
    // Cap in both updates and sweeps, like `graphlab run` does — with
    // eps=0 nothing converges, so the caps ARE the workload definition.
    let mut eng = Engine::new(kind)
        .workers(cell.threads)
        .max_updates(cell.scale.saturating_mul(cell.sweeps.max(1)))
        .max_sweeps(cell.sweeps)
        .seed(cell.seed)
        .sync(pagerank::total_rank_sync());
    if kind.is_distributed() {
        eng = eng.machines(cell.machines).transport(TransportKind::parse(&cell.transport)?);
    }
    if cell.maxpending > 0 {
        eng = eng.maxpending(cell.maxpending);
    }
    if cell.scheduler != "default" && cell.scheduler != "-" {
        eng = eng.scheduler(SchedSpec::parse(&cell.scheduler, cell.seed)?);
    }
    if let Some(us) = cell.latency_us {
        eng = eng.network(NetworkModel { latency: Duration::from_micros(us) });
    }
    let exec = eng.run(g, &prog, apps::all_vertices(n))?;
    let total: f64 = exec
        .graph
        .vertex_ids()
        .map(|v| exec.graph.vertex_data(v).rank as f64)
        .sum();
    Ok(format!(
        "{}\nbytes sent per machine: {:?}\nprobe total_rank={total:.9}\n",
        exec.stats.lab_metric_line(),
        exec.stats.bytes_sent,
    ))
}
