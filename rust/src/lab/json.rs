//! Minimal JSON value + parser + writer (no `serde` offline).
//!
//! The experiment lab's surface formats — sweep configs (`configs/*.json`)
//! and the append-only JSONL run database (`artifacts/lab/runs.jsonl`) —
//! are JSON so that external tooling (jq, Python, spreadsheets) can consume
//! them directly. The vendor set ships no `serde`, so this module provides
//! the same kind of hand-rolled, total codec the repo already uses for
//! wire bytes: parsing never panics, every malformed input surfaces as a
//! typed [`JsonError`] carrying the byte offset, and nesting depth is
//! capped so hostile input cannot blow the stack.

use std::fmt;

/// Maximum nesting depth accepted by the parser (defense against
/// `[[[[…`-style stack exhaustion on hostile input).
const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Objects preserve key order (they are written back
/// in insertion order, so configs and run records stay human-diffable).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (integers up to 2^53 survive the f64 round trip).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, as ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

/// A parse failure: what went wrong and where.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input where the error was detected.
    pub offset: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse one JSON document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after JSON value"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The object's keys, in order (empty for non-objects).
    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
            _ => Vec::new(),
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer accessor (rejects fractional and negative).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Append this value's serialization to `out`.
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Numbers: integers print without a fraction; non-finite values (which
/// JSON cannot represent) degrade to `null` rather than emitting garbage.
fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        let n: f64 = text
            .parse()
            .map_err(|_| JsonError { offset: start, msg: format!("bad number '{text}'") })?;
        if !n.is_finite() {
            return Err(JsonError { offset: start, msg: format!("non-finite number '{text}'") });
        }
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require \uXXXX low half.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid unicode escape"))?,
                            );
                        }
                        c => {
                            return Err(self.err(&format!("bad escape '\\{}'", c as char)));
                        }
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so byte
                    // boundaries are valid; walk to the next char start).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid UTF-8 in string"))?;
                    if chunk.chars().any(|c| (c as u32) < 0x20) {
                        return Err(self.err("unescaped control character"));
                    }
                    out.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("non-hex digit in \\u escape"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }
}

/// Serialization is compact single-line JSON — the JSONL row format
/// (`json.to_string()` via `Display`).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Convenience: build an object from pairs.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips() {
        let src = r#"{"name":"quick","scales":[1000,2500],"eps":0.5,"ok":true,"none":null,"s":"a\"b\\c\nd"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("quick"));
        assert_eq!(v.get("scales").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("scales").unwrap().as_arr().unwrap()[0].as_u64(), Some(1000));
        assert_eq!(v.get("eps").unwrap().as_f64(), Some(0.5));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        assert_eq!(v.get("s").unwrap().as_str(), Some("a\"b\\c\nd"));
        // Serialize → reparse → identical value.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn object_key_order_is_preserved() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.keys(), vec!["z", "a", "m"]);
        assert_eq!(v.to_string(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn garbage_is_typed_error_not_panic() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\" 1}",
            "{\"a\":}",
            "tru",
            "\"unterminated",
            "[1] trailing",
            "{\"a\":1,}",
            "nul",
            "--5",
            "\"\\u12",
            "\"\\uD800\"",
            "\x01",
        ] {
            let err = Json::parse(bad).unwrap_err();
            assert!(!err.msg.is_empty(), "no message for {bad:?}");
        }
    }

    #[test]
    fn depth_is_capped() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.msg.contains("deep"));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""\u0041\u00e9\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé😀"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert!(Json::parse("1e999").is_err()); // overflows to inf → rejected
        let mut s = String::new();
        write_num(3.0, &mut s);
        assert_eq!(s, "3");
    }
}
