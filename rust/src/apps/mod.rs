//! The paper's applications (Sec. 5), each a [`crate::engine::VertexProgram`]:
//!
//! * [`pagerank`] — the running example (Sec. 3, Alg. 1),
//! * [`als`] — Netflix movie recommendation via Alternating Least Squares
//!   (Sec. 5.1; chromatic engine, bipartite 2-coloring),
//! * [`coseg`] — video cosegmentation via Loopy BP + GMM sync (Sec. 5.2;
//!   locking engine, residual-priority scheduling),
//! * [`ner`] — Named Entity Recognition via CoEM (Sec. 5.3; chromatic),
//! * [`gibbs`] — Gibbs sampling on an MRF (Sec. 5.4; strict sequential
//!   consistency).
//!
//! Every app has two numeric paths with identical semantics: a *native*
//! Rust path (`util::matrix`) and a *PJRT* path that gathers update
//! batches into the padded tiles expected by the AOT-compiled Pallas
//! kernels (`runtime::exec`). `use_pjrt: true` requires `make artifacts`.

pub mod als;
pub mod coseg;
pub mod gibbs;
pub mod ner;
pub mod pagerank;

use crate::graph::VertexId;
use crate::scheduler::Task;

/// Initial task set touching every vertex once (the standard kickoff).
pub fn all_vertices(n: usize) -> Vec<Task> {
    (0..n as VertexId)
        .map(|vertex| Task {
            vertex,
            priority: 1.0,
        })
        .collect()
}
