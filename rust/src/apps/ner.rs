//! Named Entity Recognition via CoEM (paper Sec. 5.3).
//!
//! Bipartite graph: noun-phrases on one side, contexts on the other, edge
//! weight = co-occurrence count. Each vertex stores a distribution over
//! entity types; an update replaces it with the normalized count-weighted
//! average of its neighbors' distributions (seeds stay clamped). This is
//! the paper's light-weight, network-stressing workload: O(deg) float
//! work against `4K + small` bytes of vertex data.

use crate::engine::sync::FnSync;
use crate::engine::{Consistency, Ctx, Scope, VertexProgram};
use crate::graph::{Graph, GraphBuilder};
use crate::runtime::{self, Input};
use crate::util::matrix;
use crate::wire::{self, Wire};

/// Vertex data: type distribution + evaluation bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct NerVertex {
    /// Distribution over entity types (sums to 1).
    pub dist: Vec<f32>,
    /// Noun-phrase side of the bipartition?
    pub is_np: bool,
    /// Clamped seed type (the pre-labeled set), if any.
    pub seed: Option<u8>,
    /// Ground-truth type for accuracy eval (noun-phrases only).
    pub truth: Option<u8>,
}

/// Paper Table 2 lists 816-byte NER vertex data; ours encodes the
/// length-prefixed distribution plus three tag bytes.
impl Wire for NerVertex {
    fn encode(&self, out: &mut Vec<u8>) {
        self.dist.encode(out);
        self.is_np.encode(out);
        self.seed.encode(out);
        self.truth.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(NerVertex {
            dist: Vec::<f32>::decode(input)?,
            is_np: bool::decode(input)?,
            seed: Option::<u8>::decode(input)?,
            truth: Option::<u8>::decode(input)?,
        })
    }
}

/// Edge data: co-occurrence count (paper: 4 bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct NerEdge {
    /// Number of times the noun-phrase occurred in the context.
    pub count: f32,
}

/// 4 bytes on the wire (one f32 count).
impl Wire for NerEdge {
    fn encode(&self, out: &mut Vec<u8>) {
        self.count.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(NerEdge {
            count: f32::decode(input)?,
        })
    }
}

/// The CoEM vertex program.
pub struct Coem {
    /// Entity type count K.
    pub k: usize,
    /// Additive smoothing on the aggregated counts.
    pub smoothing: f32,
    /// Reschedule threshold on the L1 residual (dynamic mode); the
    /// chromatic sweeps ignore priorities but the self-schedule keeps the
    /// vertex live.
    pub eps: f32,
    /// Use the AOT PJRT kernel path (requires k == 8).
    pub use_pjrt: bool,
}

impl Coem {
    fn finish(&self, scope: &mut Scope<NerVertex, NerEdge>, ctx: &mut Ctx, mut new: Vec<f32>) {
        if let Some(seed) = scope.center().seed {
            new.fill(0.0);
            new[seed as usize] = 1.0;
        }
        let residual = matrix::l1_dist(&new, &scope.center().dist);
        scope.center_mut().dist = new;
        if residual > self.eps {
            // Adaptive CoEM: a changed distribution invalidates the
            // neighbors' estimates, so reschedule them (paper Sec. 3.2:
            // "reschedule its neighbors only when it has made a
            // substantial change to its local data").
            for i in 0..scope.degree() {
                ctx.schedule(scope.nbr_id(i), residual as f64);
            }
        }
    }
}

impl VertexProgram<NerVertex, NerEdge> for Coem {
    fn consistency(&self) -> Consistency {
        Consistency::Edge
    }

    fn update(&self, scope: &mut Scope<NerVertex, NerEdge>, ctx: &mut Ctx) {
        let mut agg = vec![self.smoothing; self.k];
        for i in 0..scope.degree() {
            let c = scope.edge(i).count;
            matrix::axpy(&mut agg, &scope.nbr(i).dist, c);
        }
        matrix::normalize(&mut agg);
        self.finish(scope, ctx, agg);
    }

    fn batch_width(&self) -> usize {
        if self.use_pjrt {
            64
        } else {
            1
        }
    }

    fn update_batch(&self, scopes: &mut [&mut Scope<NerVertex, NerEdge>], ctx: &mut Ctx) {
        if !self.use_pjrt || self.k != 8 {
            for s in scopes {
                self.update(s, ctx);
            }
            return;
        }
        let (bt, nt, k) = (64usize, 64usize, 8usize);
        debug_assert!(scopes.len() <= bt);
        let chunks = scopes
            .iter()
            .map(|s| s.degree().div_ceil(nt))
            .max()
            .unwrap_or(1)
            .max(1);
        let mut agg = vec![0.0f32; bt * k];
        let mut nbr = vec![0.0f32; bt * nt * k];
        let mut cnt = vec![0.0f32; bt * nt];
        for c in 0..chunks {
            nbr.fill(0.0);
            cnt.fill(0.0);
            for (b, s) in scopes.iter().enumerate() {
                let lo = c * nt;
                let hi = ((c + 1) * nt).min(s.degree());
                if lo >= hi {
                    continue;
                }
                for (j, i) in (lo..hi).enumerate() {
                    nbr[(b * nt + j) * k..(b * nt + j + 1) * k]
                        .copy_from_slice(&s.nbr(i).dist);
                    cnt[b * nt + j] = s.edge(i).count;
                }
            }
            let out = runtime::exec(
                "coem_accum_b64_n64_k8",
                &[
                    Input::new(&nbr, &[bt as i64, nt as i64, k as i64]),
                    Input::new(&cnt, &[bt as i64, nt as i64]),
                ],
            )
            .expect("coem_accum artifact");
            for (a, x) in agg.iter_mut().zip(&out[0]) {
                *a += x;
            }
        }
        for (b, s) in scopes.iter_mut().enumerate() {
            let mut new: Vec<f32> = agg[b * k..(b + 1) * k]
                .iter()
                .map(|x| x + self.smoothing)
                .collect();
            matrix::normalize(&mut new);
            self.finish(s, ctx, new);
        }
    }
}

/// Build the CoEM bipartite graph from synthetic NER data. Noun-phrases
/// are vertices `0..nps`, contexts `nps..nps+contexts`.
pub fn build(data: &crate::datagen::NerData) -> Graph<NerVertex, NerEdge> {
    let k = data.types;
    let uniform = vec![1.0 / k as f32; k];
    let mut seed_of = vec![None; data.nps];
    for &(np, t) in &data.seeds {
        seed_of[np as usize] = Some(t);
    }
    let n = data.nps + data.contexts;
    let mut b = GraphBuilder::with_capacity(n, data.cooccur.len());
    b.add_vertices(n, |i| {
        let is_np = i < data.nps;
        let seed = if is_np { seed_of[i] } else { None };
        let mut dist = uniform.clone();
        if let Some(t) = seed {
            dist.fill(0.0);
            dist[t as usize] = 1.0;
        }
        NerVertex {
            dist,
            is_np,
            seed,
            truth: if is_np { Some(data.np_truth[i]) } else { None },
        }
    });
    for &(np, c, count) in &data.cooccur {
        b.add_edge(np, data.nps as u32 + c, NerEdge { count });
    }
    b.build()
}

/// Accuracy sync: fraction of (non-seed) noun-phrases whose argmax type
/// matches the planted truth.
pub fn accuracy_sync() -> FnSync<NerVertex> {
    FnSync::new(
        "accuracy",
        vec![0.0, 0.0],
        0,
        |acc, _v, d: &NerVertex| {
            if let (true, Some(t), None) = (d.is_np, d.truth, d.seed) {
                let argmax = d
                    .dist
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as u8)
                    .unwrap_or(0);
                acc[0] += (argmax == t) as u8 as f64;
                acc[1] += 1.0;
            }
        },
        |acc| vec![acc[0] / acc[1].max(1.0)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Engine, EngineKind};
    use crate::partition::{Coloring, Partition};

    #[test]
    fn coem_recovers_planted_types() {
        let data = crate::datagen::ner(300, 150, 15, 4, 0.15, 9);
        let g = build(&data);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).expect("bipartite");
        let partition = Partition::random(n, 2, 1);
        let prog = Coem {
            k: 4,
            smoothing: 0.01,
            eps: 1e-4,
            use_pjrt: false,
        };
        let probe = std::sync::Arc::new(std::sync::Mutex::new(0.0f64));
        let probe2 = probe.clone();
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .max_sweeps(12)
            .with_coloring(coloring)
            .with_partition(partition)
            .sync(accuracy_sync())
            .on_progress(move |_s, _u, g| {
                *probe2.lock().unwrap() = g.get("accuracy").unwrap()[0];
            })
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let acc = *probe.lock().unwrap();
        assert!(exec.stats.updates > 0);
        assert!(acc > 0.6, "CoEM should beat 0.25 chance level clearly: {acc}");
    }

    #[test]
    fn seeds_stay_clamped() {
        let data = crate::datagen::ner(100, 60, 10, 4, 0.3, 2);
        let g = build(&data);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).unwrap();
        let partition = Partition::random(n, 2, 1);
        let prog = Coem {
            k: 4,
            smoothing: 0.01,
            eps: 1e-4,
            use_pjrt: false,
        };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .max_sweeps(5)
            .with_coloring(coloring)
            .with_partition(partition)
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let g = exec.graph;
        for v in g.vertex_ids() {
            if let Some(seed) = g.vertex_data(v).seed {
                let dist = &g.vertex_data(v).dist;
                assert_eq!(dist[seed as usize], 1.0, "seed {v} must stay one-hot");
            }
        }
    }
}
