//! PageRank — the paper's running example (Ex. 3.1, Alg. 1).
//!
//! The data graph mirrors the web graph: vertex data is the rank estimate,
//! edge data the directed link weights (an undirected edge carries both
//! directions, disambiguated by endpoint order, the scheme the paper
//! sketches in Sec. 3.1). The update is adaptive: neighbors are
//! rescheduled only when the rank moved by more than `eps` — exactly
//! Alg. 1.
//!
//! The PJRT path gathers update batches into the `pagerank_b256_n32`
//! artifact's `[256, 32]` tiles; degrees above 32 are handled by chunk
//! rounds feeding the previous partial sum back through `base` (the
//! reduction is linear).

use crate::engine::{Consistency, Ctx, Scope, VertexProgram};
use crate::graph::{Graph, GraphBuilder};
use crate::runtime::{self, Input};
use crate::wire::{self, Wire};

/// Vertex data: current rank estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct PrVertex {
    /// Current PageRank estimate R(v).
    pub rank: f32,
}

/// 4 bytes on the wire (one f32 rank).
impl Wire for PrVertex {
    fn encode(&self, out: &mut Vec<u8>) {
        self.rank.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(PrVertex {
            rank: f32::decode(input)?,
        })
    }
}

/// Edge data: both directed weights, keyed by endpoint order
/// (`to_lo` = weight of the link pointing at the smaller vertex id).
#[derive(Debug, Clone, PartialEq)]
pub struct PrEdge {
    /// Weight of the link toward the smaller endpoint id (damping folded).
    pub to_lo: f32,
    /// Weight of the link toward the larger endpoint id (damping folded).
    pub to_hi: f32,
}

/// 8 bytes on the wire (two directed f32 weights).
impl Wire for PrEdge {
    fn encode(&self, out: &mut Vec<u8>) {
        self.to_lo.encode(out);
        self.to_hi.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(PrEdge {
            to_lo: f32::decode(input)?,
            to_hi: f32::decode(input)?,
        })
    }
}

/// The PageRank vertex program.
pub struct PageRank {
    /// Jump probability alpha.
    pub alpha: f32,
    /// Reschedule threshold epsilon (Alg. 1).
    pub eps: f32,
    /// Vertex count (for the alpha/n base term).
    pub n: usize,
    /// Use the AOT PJRT kernel path.
    pub use_pjrt: bool,
}

impl PageRank {
    /// Weight of the link from scope-neighbor slot `i` into the center.
    #[inline]
    fn weight_in(scope: &Scope<PrVertex, PrEdge>, i: usize) -> f32 {
        if scope.vertex() < scope.nbr_id(i) {
            scope.edge(i).to_lo
        } else {
            scope.edge(i).to_hi
        }
    }

    fn base(&self) -> f32 {
        self.alpha / self.n as f32
    }

    fn finish(&self, scope: &mut Scope<PrVertex, PrEdge>, ctx: &mut Ctx, new_rank: f32) {
        let old = scope.center().rank;
        scope.center_mut().rank = new_rank;
        let delta = (new_rank - old).abs();
        if delta > self.eps {
            for i in 0..scope.degree() {
                ctx.schedule(scope.nbr_id(i), delta as f64);
            }
        }
    }
}

impl VertexProgram<PrVertex, PrEdge> for PageRank {
    fn consistency(&self) -> Consistency {
        Consistency::Edge
    }

    fn update(&self, scope: &mut Scope<PrVertex, PrEdge>, ctx: &mut Ctx) {
        // R(v) = alpha/n + (1-alpha) * sum w_uv R(u)   [damping in weights]
        let mut acc = self.base();
        for i in 0..scope.degree() {
            acc += Self::weight_in(scope, i) * scope.nbr(i).rank;
        }
        self.finish(scope, ctx, acc);
    }

    fn batch_width(&self) -> usize {
        if self.use_pjrt {
            256
        } else {
            1
        }
    }

    fn update_batch(&self, scopes: &mut [&mut Scope<PrVertex, PrEdge>], ctx: &mut Ctx) {
        if !self.use_pjrt {
            for s in scopes {
                self.update(s, ctx);
            }
            return;
        }
        let (bt, nt) = (256usize, 32usize);
        debug_assert!(scopes.len() <= bt);
        let chunks = scopes
            .iter()
            .map(|s| s.degree().div_ceil(nt))
            .max()
            .unwrap_or(1)
            .max(1);
        let mut base: Vec<f32> = vec![0.0; bt];
        for (b, s) in scopes.iter().enumerate() {
            let _ = s;
            base[b] = self.base();
        }
        let mut ranks = vec![0.0f32; bt * nt];
        let mut weights = vec![0.0f32; bt * nt];
        for c in 0..chunks {
            ranks.fill(0.0);
            weights.fill(0.0);
            for (b, s) in scopes.iter().enumerate() {
                let lo = c * nt;
                let hi = ((c + 1) * nt).min(s.degree());
                if lo >= hi {
                    continue;
                }
                for (j, i) in (lo..hi).enumerate() {
                    ranks[b * nt + j] = s.nbr(i).rank;
                    weights[b * nt + j] = Self::weight_in(s, i);
                }
            }
            let out = runtime::exec(
                "pagerank_b256_n32",
                &[
                    Input::new(&ranks, &[bt as i64, nt as i64]),
                    Input::new(&weights, &[bt as i64, nt as i64]),
                    Input::new(&base, &[bt as i64]),
                ],
            )
            .expect("pagerank artifact");
            base[..].copy_from_slice(&out[0]);
        }
        for (b, s) in scopes.iter_mut().enumerate() {
            self.finish(s, ctx, base[b]);
        }
    }
}

/// Build the PageRank data graph from an undirected edge list: every edge
/// is a bidirectional link; the weight of `u -> v` is `(1-alpha)/deg(u)`.
/// Initial ranks are uniform `1/n`.
pub fn build(n: usize, edges: &[(u32, u32)], alpha: f32) -> Graph<PrVertex, PrEdge> {
    let mut deg = vec![0u32; n];
    for &(u, v) in edges {
        deg[u as usize] += 1;
        deg[v as usize] += 1;
    }
    let mut b = GraphBuilder::with_capacity(n, edges.len());
    b.add_vertices(n, |_| PrVertex { rank: 1.0 / n as f32 });
    for &(u, v) in edges {
        let (lo, hi) = (u.min(v), u.max(v));
        b.add_edge(
            lo,
            hi,
            PrEdge {
                // link hi -> lo weighted by hi's out-degree, and vice versa
                to_lo: (1.0 - alpha) / deg[hi as usize] as f32,
                to_hi: (1.0 - alpha) / deg[lo as usize] as f32,
            },
        );
    }
    b.build()
}

/// Total-rank sync (should converge to ~1.0 — a paper-style global probe).
pub fn total_rank_sync() -> crate::engine::sync::FnSync<PrVertex> {
    crate::engine::sync::FnSync::new(
        "total_rank",
        vec![0.0],
        0,
        |acc, _v, d: &PrVertex| acc[0] += d.rank as f64,
        |acc| acc,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Engine, EngineKind};
    use crate::scheduler::{Policy, SchedSpec};

    fn tiny() -> Graph<PrVertex, PrEdge> {
        // 0 -- 1 -- 2 triangle-ish chain with a hub.
        let edges = vec![(0, 1), (1, 2), (2, 0), (0, 3)];
        build(4, &edges, 0.15)
    }

    #[test]
    fn ranks_converge_and_sum_to_one() {
        let g = tiny();
        let n = g.num_vertices();
        let prog = PageRank {
            alpha: 0.15,
            eps: 1e-7,
            n,
            use_pjrt: false,
        };
        let exec = Engine::new(EngineKind::Shared)
            .workers(2)
            .scheduler(SchedSpec::ws(Policy::Fifo, 1))
            .max_updates(200_000)
            .sync(total_rank_sync())
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let (g, stats) = (exec.graph, exec.stats);
        assert!(stats.updates > 4, "should iterate: {}", stats.updates);
        let total: f32 = g.vertex_ids().map(|v| g.vertex_data(v).rank).sum();
        assert!((total - 1.0).abs() < 1e-3, "total={total}");
        // Hub (vertex 0) outranks the leaf (vertex 3).
        assert!(g.vertex_data(0).rank > g.vertex_data(3).rank);
    }

    #[test]
    fn pjrt_batch_matches_native_under_chromatic() {
        if !runtime::available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        use crate::partition::{Coloring, Partition};
        let n = 400;
        let edges = crate::datagen::web_graph(n, 6, 11);
        let run = |use_pjrt: bool| {
            let g = build(n, &edges, 0.15);
            let coloring = Coloring::greedy(&g);
            let partition = Partition::random(n, 2, 5);
            let prog = PageRank {
                alpha: 0.15,
                eps: 1e-6,
                n,
                use_pjrt,
            };
            let exec = Engine::new(EngineKind::Chromatic)
                .machines(2)
                .max_sweeps(10)
                .with_coloring(coloring)
                .with_partition(partition)
                .run(g, &prog, crate::apps::all_vertices(n))
                .unwrap();
            assert!(exec.stats.updates > 0);
            let g = exec.graph;
            g.vertex_ids().map(|v| g.vertex_data(v).rank).collect::<Vec<f32>>()
        };
        let native = run(false);
        let pjrt = run(true);
        for (i, (a, b)) in native.iter().zip(&pjrt).enumerate() {
            assert!((a - b).abs() < 1e-4, "v{i}: native={a} pjrt={b}");
        }
    }
}
