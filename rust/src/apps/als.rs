//! Netflix movie recommendation via Alternating Least Squares (paper Sec.
//! 5.1).
//!
//! The sparse ratings matrix defines a bipartite user/movie graph: vertex
//! data holds the rank-`d` latent factor (the row of U or column of V),
//! edge data the rating. An update recomputes the ridge-regularized
//! least-squares solution for the center given its neighbors' factors —
//! `O(d^3 + deg)`, the paper's Table 2 entry — and records the local
//! squared prediction error so a sync operation can publish the running
//! RMSE ("A sync operation is used to compute the prediction error during
//! the run").
//!
//! The PJRT path implements the chunked-accumulation contract from
//! DESIGN.md §Hardware-Adaptation: `als_accum` tiles of 32 neighbors are
//! reduced host-side (the contraction is linear) and a single batched
//! `als_solve` performs the Cholesky solves.

use crate::engine::sync::FnSync;
use crate::engine::{Consistency, Ctx, Scope, VertexProgram};
use crate::graph::{Graph, GraphBuilder};
use crate::runtime::{self, Input};
use crate::util::matrix::{self, Mat};
use crate::util::Rng;
use crate::wire::{self, Wire};

/// Vertex data: latent factor plus local-error bookkeeping for the RMSE
/// sync (paper Table 2: vertex data `8d + 13` bytes — ours encodes
/// `4d + 13`, f32 instead of f64).
#[derive(Debug, Clone, PartialEq)]
pub struct AlsVertex {
    /// Latent factor (row of U for users, column of V for movies).
    pub factor: Vec<f32>,
    /// Sum of squared prediction errors over incident ratings (as of this
    /// vertex's last update).
    pub sse: f32,
    /// Incident rating count.
    pub cnt: f32,
    /// User side of the bipartition?
    pub is_user: bool,
}

/// `4d + 13` bytes on the wire: length-prefixed factor + sse + cnt + flag.
impl Wire for AlsVertex {
    fn encode(&self, out: &mut Vec<u8>) {
        self.factor.encode(out);
        self.sse.encode(out);
        self.cnt.encode(out);
        self.is_user.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(AlsVertex {
            factor: Vec::<f32>::decode(input)?,
            sse: f32::decode(input)?,
            cnt: f32::decode(input)?,
            is_user: bool::decode(input)?,
        })
    }
}

/// Edge data: the rating (Table 2: 16 bytes; ours encodes 4).
#[derive(Debug, Clone, PartialEq)]
pub struct AlsEdge {
    /// Observed rating.
    pub rating: f32,
}

/// 4 bytes on the wire (one f32 rating).
impl Wire for AlsEdge {
    fn encode(&self, out: &mut Vec<u8>) {
        self.rating.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(AlsEdge {
            rating: f32::decode(input)?,
        })
    }
}

/// The ALS vertex program.
pub struct Als {
    /// Latent dimension d.
    pub d: usize,
    /// Ridge regularization lambda.
    pub lambda: f32,
    /// Use the AOT PJRT kernel path (requires d in {5, 10, 20}).
    pub use_pjrt: bool,
}

impl Als {
    fn solve_native(&self, scope: &Scope<AlsVertex, AlsEdge>) -> Vec<f32> {
        let d = self.d;
        let mut a = Mat::zeros(d, d);
        let mut y = vec![0.0f32; d];
        for i in 0..scope.degree() {
            let v = &scope.nbr(i).factor;
            a.rank1_update(v, 1.0);
            matrix::axpy(&mut y, v, scope.edge(i).rating);
        }
        matrix::solve_psd(&a, &y, self.lambda)
    }

    /// Post-solve bookkeeping shared by both numeric paths.
    fn finish(&self, scope: &mut Scope<AlsVertex, AlsEdge>, ctx: &mut Ctx, x: Vec<f32>) {
        let mut sse = 0.0f32;
        for i in 0..scope.degree() {
            let pred = matrix::dot(&x, &scope.nbr(i).factor);
            let err = scope.edge(i).rating - pred;
            sse += err * err;
        }
        let delta = matrix::l1_dist(&x, &scope.center().factor);
        let deg = scope.degree() as f32;
        {
            let c = scope.center_mut();
            c.factor = x;
            c.sse = sse;
            c.cnt = deg;
        }
        // ALS sweeps: keep the center live so the chromatic engine
        // revisits it every sweep; priority carries the factor delta for
        // the locking engine's (Fig. 1) runs.
        ctx.schedule(scope.vertex(), delta as f64);
    }
}

impl VertexProgram<AlsVertex, AlsEdge> for Als {
    fn consistency(&self) -> Consistency {
        Consistency::Edge
    }

    fn update(&self, scope: &mut Scope<AlsVertex, AlsEdge>, ctx: &mut Ctx) {
        let x = self.solve_native(scope);
        self.finish(scope, ctx, x);
    }

    fn batch_width(&self) -> usize {
        if self.use_pjrt {
            64
        } else {
            1
        }
    }

    fn update_batch(&self, scopes: &mut [&mut Scope<AlsVertex, AlsEdge>], ctx: &mut Ctx) {
        if !self.use_pjrt || !matches!(self.d, 5 | 10 | 20) {
            for s in scopes {
                self.update(s, ctx);
            }
            return;
        }
        let d = self.d;
        let (bt, nt) = (64usize, 32usize);
        debug_assert!(scopes.len() <= bt);
        let accum_name = format!("als_accum_b64_n32_d{d}");
        let solve_name = format!("als_solve_b64_d{d}");
        // Chunked normal-equation accumulation.
        let mut a_acc = vec![0.0f32; bt * d * d];
        let mut y_acc = vec![0.0f32; bt * d];
        let chunks = scopes
            .iter()
            .map(|s| s.degree().div_ceil(nt))
            .max()
            .unwrap_or(1)
            .max(1);
        let mut vt = vec![0.0f32; bt * nt * d];
        let mut rt = vec![0.0f32; bt * nt];
        let mut mt = vec![0.0f32; bt * nt];
        for c in 0..chunks {
            vt.fill(0.0);
            rt.fill(0.0);
            mt.fill(0.0);
            for (b, s) in scopes.iter().enumerate() {
                let lo = c * nt;
                let hi = ((c + 1) * nt).min(s.degree());
                if lo >= hi {
                    continue;
                }
                for (j, i) in (lo..hi).enumerate() {
                    let f = &s.nbr(i).factor;
                    vt[(b * nt + j) * d..(b * nt + j + 1) * d].copy_from_slice(f);
                    rt[b * nt + j] = s.edge(i).rating;
                    mt[b * nt + j] = 1.0;
                }
            }
            let out = runtime::exec(
                &accum_name,
                &[
                    Input::new(&vt, &[bt as i64, nt as i64, d as i64]),
                    Input::new(&rt, &[bt as i64, nt as i64]),
                    Input::new(&mt, &[bt as i64, nt as i64]),
                ],
            )
            .expect("als_accum artifact");
            for (acc, x) in a_acc.iter_mut().zip(&out[0]) {
                *acc += x;
            }
            for (acc, x) in y_acc.iter_mut().zip(&out[1]) {
                *acc += x;
            }
        }
        let lam = [self.lambda];
        let out = runtime::exec(
            &solve_name,
            &[
                Input::new(&a_acc, &[bt as i64, d as i64, d as i64]),
                Input::new(&y_acc, &[bt as i64, d as i64]),
                Input::new(&lam, &[1]),
            ],
        )
        .expect("als_solve artifact");
        for (b, s) in scopes.iter_mut().enumerate() {
            let x = out[0][b * d..(b + 1) * d].to_vec();
            self.finish(s, ctx, x);
        }
    }
}

/// Build the bipartite ALS graph: users `0..users`, movies
/// `users..users+movies`; factors initialized uniform-random in a seeded,
/// vertex-indexed way (identical across engines and machine counts).
pub fn build(data: &crate::datagen::NetflixData, d: usize, seed: u64) -> Graph<AlsVertex, AlsEdge> {
    let n = data.users + data.movies;
    let mut b = GraphBuilder::with_capacity(n, data.ratings.len());
    b.add_vertices(n, |i| {
        let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        AlsVertex {
            factor: (0..d).map(|_| rng.uniform(0.1, 1.0)).collect(),
            sse: 0.0,
            cnt: 0.0,
            is_user: i < data.users,
        }
    });
    for &(u, m, r) in &data.ratings {
        b.add_edge(u, data.users as u32 + m, AlsEdge { rating: r });
    }
    b.build()
}

/// The training-RMSE sync: aggregates per-vertex SSE over the user side
/// (avoiding double counting) and finalizes sqrt(sse / cnt).
pub fn rmse_sync() -> FnSync<AlsVertex> {
    FnSync::new(
        "rmse",
        vec![0.0, 0.0],
        0,
        |acc, _v, d: &AlsVertex| {
            if d.is_user {
                acc[0] += d.sse as f64;
                acc[1] += d.cnt as f64;
            }
        },
        |acc| vec![(acc[0] / acc[1].max(1.0)).sqrt()],
    )
}

/// Full-graph RMSE computed directly (test oracle; not a sync).
pub fn rmse_direct(g: &Graph<AlsVertex, AlsEdge>) -> f64 {
    let mut sse = 0.0f64;
    let m = g.num_edges();
    for e in 0..m as u32 {
        let (u, v) = g.endpoints(e);
        let pred = matrix::dot(&g.vertex_data(u).factor, &g.vertex_data(v).factor);
        let err = (g.edge_data(e).rating - pred) as f64;
        sse += err * err;
    }
    (sse / m.max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Engine, EngineKind};
    use crate::partition::{Coloring, Partition};

    fn small_data() -> crate::datagen::NetflixData {
        crate::datagen::netflix(60, 40, 12, 3, 0.05, 42)
    }

    #[test]
    fn als_drives_rmse_down_chromatic() {
        let data = small_data();
        let g = build(&data, 5, 1);
        let before = rmse_direct(&g);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).expect("bipartite");
        let partition = Partition::random(n, 2, 3);
        let prog = Als {
            d: 5,
            lambda: 0.1,
            use_pjrt: false,
        };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .max_sweeps(10)
            .with_coloring(coloring)
            .with_partition(partition)
            .sync(rmse_sync())
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let (g, stats) = (exec.graph, exec.stats);
        let after = rmse_direct(&g);
        assert!(stats.updates >= n as u64 * 5, "updates={}", stats.updates);
        assert!(
            after < before * 0.5,
            "RMSE should drop: before={before:.4} after={after:.4}"
        );
        assert!(after < 0.3, "planted rank-3 should fit well: {after:.4}");
    }

    #[test]
    fn rmse_sync_matches_direct() {
        // After one full sweep, every vertex's sse is up to date with the
        // final factors only for the *last* color; the sync RMSE is an
        // estimate. Check it is in the right ballpark (same order).
        let data = small_data();
        let g = build(&data, 5, 1);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).unwrap();
        let partition = Partition::random(n, 2, 3);
        let probe = std::sync::Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
        let probe2 = probe.clone();
        let prog = Als {
            d: 5,
            lambda: 0.1,
            use_pjrt: false,
        };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .max_sweeps(8)
            .with_coloring(coloring)
            .with_partition(partition)
            .sync(rmse_sync())
            .on_progress(move |_s, _u, g| {
                probe2.lock().unwrap().push(g.get("rmse").unwrap()[0]);
            })
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let g = exec.graph;
        let series = probe.lock().unwrap();
        assert_eq!(series.len(), 8);
        // Monotone-ish improvement and agreement with the direct measure.
        assert!(series.first().unwrap() > series.last().unwrap());
        let direct = rmse_direct(&g);
        assert!(
            (series.last().unwrap() - direct).abs() < 0.05,
            "sync={} direct={}",
            series.last().unwrap(),
            direct
        );
    }

    #[test]
    fn pjrt_als_matches_native() {
        if !crate::runtime::available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let data = small_data();
        let run = |use_pjrt: bool| {
            let g = build(&data, 5, 1);
            let n = g.num_vertices();
            let coloring = Coloring::bipartite(&g).unwrap();
            let partition = Partition::random(n, 2, 3);
            let prog = Als {
                d: 5,
                lambda: 0.1,
                use_pjrt,
            };
            let exec = Engine::new(EngineKind::Chromatic)
                .machines(2)
                .max_sweeps(5)
                .with_coloring(coloring)
                .with_partition(partition)
                .run(g, &prog, crate::apps::all_vertices(n))
                .unwrap();
            rmse_direct(&exec.graph)
        };
        let native = run(false);
        let pjrt = run(true);
        assert!(
            (native - pjrt).abs() < 5e-3,
            "native={native:.5} pjrt={pjrt:.5}"
        );
    }
}
