//! Gibbs sampling on a Markov Random Field (paper Sec. 5.4).
//!
//! Ising model: each vertex holds a binary spin; an update resamples the
//! spin conditioned on the neighbors. The paper's point: Gibbs sampling
//! *requires* sequential consistency for statistical correctness
//! ("Strict sequential consistency is necessary to preserve statistical
//! properties [22]") — so this app runs under the edge consistency model
//! and is the stress test for the engines' exclusion guarantees.
//!
//! Randomness is derived deterministically from (vertex, sample counter),
//! keeping the update function stateless as the abstraction demands.

use crate::engine::sync::FnSync;
use crate::engine::{Consistency, Ctx, Scope, VertexProgram};
use crate::graph::{Graph, GraphBuilder};
use crate::util::Rng;
use crate::wire::{self, Wire};

/// Vertex data: spin + external field + marginal bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct GibbsVertex {
    /// Current spin (0 or 1).
    pub spin: u8,
    /// External field (positive favors spin 1).
    pub field: f32,
    /// Count of spin-1 samples (for the running marginal).
    pub ones: u64,
    /// Total samples drawn at this vertex.
    pub samples: u64,
}

/// 21 bytes on the wire: spin + field + the two sample counters.
impl Wire for GibbsVertex {
    fn encode(&self, out: &mut Vec<u8>) {
        self.spin.encode(out);
        self.field.encode(out);
        self.ones.encode(out);
        self.samples.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(GibbsVertex {
            spin: u8::decode(input)?,
            field: f32::decode(input)?,
            ones: u64::decode(input)?,
            samples: u64::decode(input)?,
        })
    }
}

/// The Gibbs sampler program (Ising coupling on every edge).
pub struct Gibbs {
    /// Uniform coupling strength J.
    pub coupling: f32,
    /// Samples per vertex before the chain stops rescheduling itself.
    pub target_samples: u64,
    /// Seed mixed into the per-sample randomness.
    pub seed: u64,
}

impl VertexProgram<GibbsVertex, ()> for Gibbs {
    fn consistency(&self) -> Consistency {
        Consistency::Edge
    }

    fn update(&self, scope: &mut Scope<GibbsVertex, ()>, ctx: &mut Ctx) {
        // Conditional: P(s=1 | nbrs) = sigmoid(2*(field + J * sum(2s_u - 1)))
        let mut h = scope.center().field;
        for i in 0..scope.degree() {
            h += self.coupling * (2.0 * scope.nbr(i).spin as f32 - 1.0);
        }
        let p1 = 1.0 / (1.0 + (-2.0 * h).exp());
        let vid = scope.vertex() as u64;
        let c = scope.center_mut();
        // Deterministic per-(vertex, draw) randomness.
        let mut rng =
            Rng::new(self.seed ^ (vid << 32) ^ c.samples.wrapping_mul(0x2545F4914F6CDD1D));
        c.spin = (rng.f32() < p1) as u8;
        c.ones += c.spin as u64;
        c.samples += 1;
        if c.samples < self.target_samples {
            ctx.schedule(scope.vertex(), 1.0);
        }
    }
}

/// Build the Ising grid from synthetic MRF data (spins start 0).
pub fn build(data: &crate::datagen::MrfData) -> Graph<GibbsVertex, ()> {
    let n = data.side * data.side;
    let mut b = GraphBuilder::new();
    b.add_vertices(n, |i| GibbsVertex {
        spin: 0,
        field: data.field[i],
        ones: 0,
        samples: 0,
    });
    for &(u, v) in &crate::datagen::grid2d_edges(data.side) {
        b.add_edge(u, v, ());
    }
    b.build()
}

/// Mean-magnetization sync (diagnostic aggregate).
pub fn magnetization_sync() -> FnSync<GibbsVertex> {
    FnSync::new(
        "magnetization",
        vec![0.0, 0.0],
        0,
        |acc, _v, d: &GibbsVertex| {
            if d.samples > 0 {
                acc[0] += d.ones as f64 / d.samples as f64;
                acc[1] += 1.0;
            }
        },
        |acc| vec![acc[0] / acc[1].max(1.0)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Engine, EngineKind};
    use crate::scheduler::{Policy, SchedSpec};

    #[test]
    fn marginals_track_planted_field() {
        let data = crate::datagen::mrf(12, 0.4, 3);
        let g = build(&data);
        let n = g.num_vertices();
        let prog = Gibbs {
            coupling: 0.4,
            target_samples: 200,
            seed: 17,
        };
        let exec = Engine::new(EngineKind::Shared)
            .workers(4)
            .scheduler(SchedSpec::ws(Policy::Sweep, 1))
            .sync(magnetization_sync())
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let (g, stats) = (exec.graph, exec.stats);
        assert_eq!(stats.updates, n as u64 * 200);
        // The blob with positive field should have high marginals, the
        // negative blob low ones.
        let marg = |x: usize, y: usize| {
            let d = g.vertex_data((x * 12 + y) as u32);
            d.ones as f64 / d.samples as f64
        };
        let pos = marg(3, 3); // field ~ +
        let neg = marg(8, 8); // field ~ -
        assert!(pos > 0.7, "positive-field marginal {pos}");
        assert!(neg < 0.3, "negative-field marginal {neg}");
    }

    #[test]
    fn deterministic_given_single_worker() {
        let data = crate::datagen::mrf(8, 0.3, 1);
        let run = || {
            let g = build(&data);
            let n = g.num_vertices();
            let prog = Gibbs {
                coupling: 0.3,
                target_samples: 50,
                seed: 5,
            };
            let exec = Engine::new(EngineKind::Shared)
                .workers(1)
                .scheduler(SchedSpec::ws(Policy::Sweep, 1))
                .run(g, &prog, crate::apps::all_vertices(n))
                .unwrap();
            let g = exec.graph;
            g.vertex_ids().map(|v| g.vertex_data(v).ones).collect::<Vec<u64>>()
        };
        assert_eq!(run(), run());
    }
}
