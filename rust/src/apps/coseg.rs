//! Video cosegmentation (CoSeg, paper Sec. 5.2): Loopy Belief Propagation
//! on a 3-D spatio-temporal grid with a GMM appearance model maintained by
//! the sync operation.
//!
//! Vertex data holds the belief, node potential, and appearance features
//! of one super-pixel; edge data holds the two directed LBP messages plus
//! the Potts smoothing. The update is the residual-BP step of [Elidan et
//! al. 2006] referenced by the paper: recompute belief and outgoing
//! messages, then reschedule neighbors with priority = message residual —
//! which is why this application requires the Locking engine's priority
//! scheduler (paper Sec. 6.3).
//!
//! The GMM is the paper's "parameters maintained using the sync
//! operation": the sync folds belief-weighted appearance means per label;
//! updates read them back through `Ctx::global("gmm")` to refresh node
//! potentials.

use crate::engine::sync::FnSync;
use crate::engine::{Consistency, Ctx, Scope, VertexProgram};
use crate::graph::{Graph, GraphBuilder};
use crate::runtime::{self, Input};
use crate::util::matrix;
use crate::wire::{self, Wire};

/// Vertex data: one super-pixel.
#[derive(Debug, Clone, PartialEq)]
pub struct CosegVertex {
    /// Current belief over labels (sums to 1).
    pub belief: Vec<f32>,
    /// Node potential (appearance likelihood under the current GMM).
    pub npot: Vec<f32>,
    /// Appearance feature (one bank per label in the synthetic data).
    pub appearance: Vec<f32>,
    /// Ground-truth label (synthetic data) for accuracy eval.
    pub truth: u8,
}

/// Paper Table 2: 392 bytes. Ours encodes three length-prefixed f32 banks
/// plus the truth byte.
impl Wire for CosegVertex {
    fn encode(&self, out: &mut Vec<u8>) {
        self.belief.encode(out);
        self.npot.encode(out);
        self.appearance.encode(out);
        self.truth.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(CosegVertex {
            belief: Vec::<f32>::decode(input)?,
            npot: Vec::<f32>::decode(input)?,
            appearance: Vec::<f32>::decode(input)?,
            truth: u8::decode(input)?,
        })
    }
}

/// Edge data: the two directed messages + Potts smoothing.
#[derive(Debug, Clone, PartialEq)]
pub struct CosegEdge {
    /// Message toward the smaller endpoint id.
    pub msg_to_lo: Vec<f32>,
    /// Message toward the larger endpoint id.
    pub msg_to_hi: Vec<f32>,
    /// Potts smoothing strength (psi = exp(-lam) off-diagonal).
    pub lam: f32,
}

/// Paper Table 2: 80 bytes. Ours encodes both directed messages + lam.
impl Wire for CosegEdge {
    fn encode(&self, out: &mut Vec<u8>) {
        self.msg_to_lo.encode(out);
        self.msg_to_hi.encode(out);
        self.lam.encode(out);
    }
    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(CosegEdge {
            msg_to_lo: Vec::<f32>::decode(input)?,
            msg_to_hi: Vec::<f32>::decode(input)?,
            lam: f32::decode(input)?,
        })
    }
}

/// The CoSeg (residual LBP) vertex program.
pub struct Coseg {
    /// Label count L.
    pub labels: usize,
    /// Reschedule threshold on belief residual.
    pub eps: f32,
    /// GMM variance (appearance likelihood bandwidth).
    pub sigma2: f32,
    /// Use the AOT PJRT kernel path (requires labels == 5).
    pub use_pjrt: bool,
}

impl Coseg {
    /// Refresh the node potential from the GMM means published by the
    /// sync operation (if available).
    fn refresh_npot(&self, scope: &mut Scope<CosegVertex, CosegEdge>, ctx: &Ctx) {
        let l = self.labels;
        if let Some(gmm) = ctx.global("gmm") {
            if gmm.len() == l * l {
                let app = scope.center().appearance.clone();
                let mut npot = vec![0.0f32; l];
                for (lab, np) in npot.iter_mut().enumerate() {
                    let mean = &gmm[lab * l..(lab + 1) * l];
                    let d2: f32 = app
                        .iter()
                        .zip(mean)
                        .map(|(a, m)| (a - *m as f32) * (a - *m as f32))
                        .sum();
                    *np = (-d2 / (2.0 * self.sigma2)).exp().max(1e-6);
                }
                matrix::normalize(&mut npot);
                scope.center_mut().npot = npot;
            }
        }
    }

    /// Incoming message from neighbor slot `i` (toward the center).
    fn msg_in(scope: &Scope<CosegVertex, CosegEdge>, i: usize) -> &[f32] {
        if scope.vertex() < scope.nbr_id(i) {
            &scope.edge(i).msg_to_lo
        } else {
            &scope.edge(i).msg_to_hi
        }
    }

    fn finish(
        &self,
        scope: &mut Scope<CosegVertex, CosegEdge>,
        ctx: &mut Ctx,
        belief: Vec<f32>,
        out_msgs: Vec<Vec<f32>>,
        residual: f32,
    ) {
        for (i, m) in out_msgs.into_iter().enumerate() {
            let center_is_lo = scope.vertex() < scope.nbr_id(i);
            let e = scope.edge_mut(i);
            if center_is_lo {
                e.msg_to_hi = m;
            } else {
                e.msg_to_lo = m;
            }
        }
        scope.center_mut().belief = belief;
        if residual > self.eps {
            for i in 0..scope.degree() {
                ctx.schedule(scope.nbr_id(i), residual as f64);
            }
        }
    }
}

impl VertexProgram<CosegVertex, CosegEdge> for Coseg {
    fn consistency(&self) -> Consistency {
        // Messages live on edges; neighbors' vertex data is not read, but
        // edge writes require the edge model.
        Consistency::Edge
    }

    fn update(&self, scope: &mut Scope<CosegVertex, CosegEdge>, ctx: &mut Ctx) {
        self.refresh_npot(scope, ctx);
        let l = self.labels;
        let deg = scope.degree();
        // Unnormalized belief = npot * prod of incoming messages.
        let mut prod: Vec<f32> = scope.center().npot.clone();
        for i in 0..deg {
            let m = Self::msg_in(scope, i);
            for (p, &mi) in prod.iter_mut().zip(m) {
                *p *= mi.max(1e-30);
            }
        }
        let mut belief = prod.clone();
        matrix::normalize(&mut belief);
        // Outgoing messages via the cavity trick.
        let mut out_msgs = Vec::with_capacity(deg);
        for i in 0..deg {
            let m_in = Self::msg_in(scope, i);
            let rho = (-scope.edge(i).lam).exp();
            let mut cav: Vec<f32> = prod
                .iter()
                .zip(m_in)
                .map(|(p, &mi)| p / mi.max(1e-30))
                .collect();
            let s: f32 = cav.iter().sum();
            for c in cav.iter_mut() {
                *c = rho * s + (1.0 - rho) * *c;
            }
            matrix::normalize(&mut cav);
            out_msgs.push(cav);
        }
        let residual = matrix::l1_dist(&belief, &scope.center().belief);
        let _ = l;
        self.finish(scope, ctx, belief, out_msgs, residual);
    }

    fn batch_width(&self) -> usize {
        if self.use_pjrt {
            128
        } else {
            1
        }
    }

    fn update_batch(&self, scopes: &mut [&mut Scope<CosegVertex, CosegEdge>], ctx: &mut Ctx) {
        if !self.use_pjrt || self.labels != 5 {
            for s in scopes {
                self.update(s, ctx);
            }
            return;
        }
        let (bt, nb, l) = (128usize, 6usize, 5usize);
        debug_assert!(scopes.len() <= bt);
        let mut msgs = vec![0.0f32; bt * nb * l];
        let mut mask = vec![0.0f32; bt * nb];
        let mut npot = vec![0.0f32; bt * l];
        let mut lam = vec![0.0f32; bt * nb];
        let mut oldb = vec![0.0f32; bt * l];
        for (b, s) in scopes.iter_mut().enumerate() {
            self.refresh_npot(s, ctx);
            debug_assert!(s.degree() <= nb, "grid degree exceeds 6");
            for i in 0..s.degree() {
                msgs[(b * nb + i) * l..(b * nb + i + 1) * l]
                    .copy_from_slice(Self::msg_in(s, i));
                mask[b * nb + i] = 1.0;
                lam[b * nb + i] = s.edge(i).lam;
            }
            npot[b * l..(b + 1) * l].copy_from_slice(&s.center().npot);
            oldb[b * l..(b + 1) * l].copy_from_slice(&s.center().belief);
        }
        let out = runtime::exec(
            "lbp_b128_l5",
            &[
                Input::new(&msgs, &[bt as i64, nb as i64, l as i64]),
                Input::new(&mask, &[bt as i64, nb as i64]),
                Input::new(&npot, &[bt as i64, l as i64]),
                Input::new(&lam, &[bt as i64, nb as i64]),
                Input::new(&oldb, &[bt as i64, l as i64]),
            ],
        )
        .expect("lbp artifact");
        for (b, s) in scopes.iter_mut().enumerate() {
            let belief = out[1][b * l..(b + 1) * l].to_vec();
            let out_msgs: Vec<Vec<f32>> = (0..s.degree())
                .map(|i| out[0][(b * nb + i) * l..(b * nb + i + 1) * l].to_vec())
                .collect();
            let residual = out[2][b];
            self.finish(s, ctx, belief, out_msgs, residual);
        }
    }
}

/// Build the CoSeg grid graph from synthetic video data.
pub fn build(data: &crate::datagen::VideoData, lam: f32) -> Graph<CosegVertex, CosegEdge> {
    let l = data.labels;
    let n = data.frames * data.width * data.height;
    let uniform = vec![1.0 / l as f32; l];
    let mut b = GraphBuilder::new();
    b.add_vertices(n, |i| {
        // Initial node potential straight from (normalized) appearance.
        let mut npot: Vec<f32> = data.appearance[i].iter().map(|x| x.max(0.05)).collect();
        matrix::normalize(&mut npot);
        CosegVertex {
            belief: uniform.clone(),
            npot,
            appearance: data.appearance[i].clone(),
            truth: data.truth[i],
        }
    });
    for &(u, v) in &crate::datagen::video_edges(data.frames, data.width, data.height) {
        b.add_edge(
            u,
            v,
            CosegEdge {
                msg_to_lo: uniform.clone(),
                msg_to_hi: uniform.clone(),
                lam,
            },
        );
    }
    b.build()
}

/// GMM sync: belief-weighted appearance mean per label, flattened row-major
/// `[label][feature]` with the weights appended for the finalize division.
pub fn gmm_sync(labels: usize) -> FnSync<CosegVertex> {
    let l = labels;
    FnSync::new(
        "gmm",
        vec![0.0; l * l + l],
        0,
        move |acc, _v, d: &CosegVertex| {
            for lab in 0..l {
                let w = d.belief[lab] as f64;
                for f in 0..l {
                    acc[lab * l + f] += w * d.appearance[f] as f64;
                }
                acc[l * l + lab] += w;
            }
        },
        move |mut acc| {
            for lab in 0..l {
                let w = acc[l * l + lab].max(1e-9);
                for f in 0..l {
                    acc[lab * l + f] /= w;
                }
            }
            acc.truncate(l * l);
            acc
        },
    )
}

/// Label accuracy sync (argmax belief vs planted truth).
pub fn accuracy_sync() -> FnSync<CosegVertex> {
    FnSync::new(
        "accuracy",
        vec![0.0, 0.0],
        0,
        |acc, _v, d: &CosegVertex| {
            let argmax = d
                .belief
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as u8)
                .unwrap_or(0);
            acc[0] += (argmax == d.truth) as u8 as f64;
            acc[1] += 1.0;
        },
        |acc| vec![acc[0] / acc[1].max(1.0)],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Engine, EngineKind};
    use crate::partition::Partition;
    use crate::scheduler::{Policy, SchedSpec};

    fn accuracy(g: &Graph<CosegVertex, CosegEdge>) -> f64 {
        let mut ok = 0usize;
        for v in g.vertex_ids() {
            let d = g.vertex_data(v);
            let argmax = d
                .belief
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u8;
            ok += (argmax == d.truth) as usize;
        }
        ok as f64 / g.num_vertices() as f64
    }

    #[test]
    fn lbp_smooths_noisy_labels_locking_engine() {
        let data = crate::datagen::video(3, 8, 10, 5, 0.45, 7);
        let g = build(&data, 0.8);
        let n = g.num_vertices();
        // Frame-sliced partition (the paper's natural CoSeg cut).
        let partition = Partition::blocked(n, 2);
        let prog = Coseg {
            labels: 5,
            eps: 1e-3,
            sigma2: 0.5,
            use_pjrt: false,
        };
        let before = {
            // Accuracy of raw appearance argmax (pre-smoothing).
            let mut ok = 0usize;
            for v in g.vertex_ids() {
                let d = g.vertex_data(v);
                let am = d
                    .appearance
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as u8;
                ok += (am == d.truth) as usize;
            }
            ok as f64 / n as f64
        };
        let exec = Engine::new(EngineKind::Locking)
            .machines(2)
            .maxpending(32)
            .scheduler(SchedSpec::ws(Policy::Priority, 1))
            .sync_period(std::time::Duration::from_millis(40))
            .max_updates(80_000)
            .with_partition(partition)
            .sync(gmm_sync(5))
            .sync(accuracy_sync())
            .run(g, &prog, crate::apps::all_vertices(n))
            .unwrap();
        let (g, stats) = (exec.graph, exec.stats);
        let after = accuracy(&g);
        assert!(stats.updates > n as u64 / 2, "updates={}", stats.updates);
        assert!(
            after > before + 0.05,
            "LBP should beat raw appearance: before={before:.3} after={after:.3}"
        );
        assert!(after > 0.75, "smoothing should clean most noise: {after:.3}");
    }
}
