//! Two-phase partitioning: atoms + meta-graph (paper Sec. 4.1, Fig. 4).
//!
//! Phase 1 (offline, expensive): over-partition the data graph into
//! `k >> #machines` **atoms** with a BFS region-grower (our stand-in for
//! Metis — DESIGN.md §Substitutions). Each atom corresponds to one "file"
//! in the paper's scheme.
//!
//! Phase 2 (load time, cheap): build the **meta-graph** — one vertex per
//! atom weighted by its data size, one edge per atom pair weighted by the
//! number of crossing edges — and run a fast balanced greedy partition of
//! the meta-graph onto the actual machine count. The same atom set serves
//! any cluster size without re-partitioning the full graph.
//!
//! **On disk** (Distributed GraphLab, arXiv 1204.6078): the paper stores
//! "each atom as a separate file" — a journal of graph-construction
//! commands replayed at load time. [`AtomSet::save_atoms`] writes exactly
//! that: one [`crate::wire`]-encoded journal per atom (interior vertices
//! with their adjacency, ghost-vertex data snapshots, incident edges)
//! plus a `meta.bin` holding the vertex→atom assignment and the
//! meta-graph, so phase 2 runs at load time without touching the data
//! graph. [`crate::distributed::LocalGraph::from_atom_files`] rebuilds a
//! machine's partition + ghosts by replaying only that machine's atoms;
//! [`load_graph`] replays everything (driver-side reassembly and the
//! shared engine's load path).

use super::{MachineId, Partition};
use crate::graph::{EdgeId, Graph, GraphBuilder, VertexId};
use crate::util::Rng;
use crate::wire::{self, Wire, WIRE_VERSION};
use anyhow::{bail, Context as _};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};

/// Atom id (phase-1 part index).
pub type AtomId = usize;

/// A phase-1 over-partition: vertex → atom.
#[derive(Debug, Clone)]
pub struct AtomSet {
    assignment: Vec<AtomId>,
    num_atoms: usize,
}

impl AtomSet {
    /// BFS region-growing over-partition into `k` atoms of roughly equal
    /// vertex count. Deterministic given the seed (seeds pick BFS sources).
    pub fn grow_bfs<V, E>(g: &Graph<V, E>, k: usize, seed: u64) -> Self {
        let n = g.num_vertices();
        let k = k.max(1).min(n.max(1));
        let target = n.div_ceil(k);
        let mut assignment = vec![usize::MAX; n];
        let mut rng = Rng::new(seed);
        let mut atom = 0usize;
        let mut unvisited: Vec<VertexId> = (0..n as VertexId).collect();
        rng.shuffle(&mut unvisited);
        let mut cursor = 0usize;
        let mut queue = VecDeque::new();
        let mut size = 0usize;
        while cursor < unvisited.len() {
            // Find a fresh BFS source.
            while cursor < unvisited.len() && assignment[unvisited[cursor] as usize] != usize::MAX
            {
                cursor += 1;
            }
            if cursor >= unvisited.len() {
                break;
            }
            queue.push_back(unvisited[cursor]);
            while let Some(v) = queue.pop_front() {
                if assignment[v as usize] != usize::MAX {
                    continue;
                }
                assignment[v as usize] = atom;
                size += 1;
                if size >= target && atom + 1 < k {
                    atom += 1;
                    size = 0;
                    queue.clear();
                    break;
                }
                for &(u, _) in g.neighbors(v) {
                    if assignment[u as usize] == usize::MAX {
                        queue.push_back(u);
                    }
                }
            }
        }
        // Any leftovers (disconnected tails after a clear) go to the
        // smallest atom.
        let mut sizes = vec![0usize; k];
        for &a in assignment.iter().filter(|&&a| a != usize::MAX) {
            sizes[a] += 1;
        }
        for a in assignment.iter_mut().filter(|a| **a == usize::MAX) {
            let m = (0..k).min_by_key(|&i| sizes[i]).unwrap();
            *a = m;
            sizes[m] += 1;
        }
        AtomSet {
            assignment,
            num_atoms: k,
        }
    }

    /// Hash over-partition (the "random" baseline for dense graphs).
    pub fn hashed(num_vertices: usize, k: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        AtomSet {
            assignment: (0..num_vertices).map(|_| rng.gen_range(k)).collect(),
            num_atoms: k,
        }
    }

    /// Atom of vertex `v`.
    pub fn atom(&self, v: VertexId) -> AtomId {
        self.assignment[v as usize]
    }

    /// Number of atoms.
    pub fn num_atoms(&self) -> usize {
        self.num_atoms
    }

    /// Atom sizes (vertex counts).
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_atoms];
        for &a in &self.assignment {
            s[a] += 1;
        }
        s
    }
}

/// The weighted atom-connectivity graph (paper Fig. 4(c)).
#[derive(Debug, Clone)]
pub struct MetaGraph {
    /// Vertex weight of each atom: bytes (here: vertex count as proxy).
    pub atom_weight: Vec<u64>,
    /// `edge_weight[a]` = list of `(b, crossing_edges)` for b adjacent to a.
    pub adjacency: Vec<Vec<(AtomId, u64)>>,
}

impl MetaGraph {
    /// Build the meta-graph of an atom set over a data graph.
    pub fn build<V, E>(g: &Graph<V, E>, atoms: &AtomSet) -> Self {
        let k = atoms.num_atoms();
        let mut atom_weight = vec![0u64; k];
        for v in 0..g.num_vertices() as VertexId {
            atom_weight[atoms.atom(v)] += 1;
        }
        let mut pair_counts: std::collections::HashMap<(AtomId, AtomId), u64> =
            std::collections::HashMap::new();
        for e in 0..g.num_edges() as u32 {
            let (u, v) = g.endpoints(e);
            let (a, b) = (atoms.atom(u), atoms.atom(v));
            if a != b {
                let key = (a.min(b), a.max(b));
                *pair_counts.entry(key).or_insert(0) += 1;
            }
        }
        let mut adjacency = vec![Vec::new(); k];
        for (&(a, b), &w) in &pair_counts {
            adjacency[a].push((b, w));
            adjacency[b].push((a, w));
        }
        for adj in &mut adjacency {
            adj.sort_unstable();
        }
        MetaGraph {
            atom_weight,
            adjacency,
        }
    }

    /// Fast balanced greedy partition of the meta-graph onto `machines`
    /// parts (phase 2). Atoms are placed heaviest-first onto the machine
    /// maximizing (edge affinity − balance penalty), an LDG-style
    /// streaming heuristic.
    pub fn partition(&self, machines: usize) -> Vec<MachineId> {
        let k = self.atom_weight.len();
        let machines = machines.max(1);
        let total: u64 = self.atom_weight.iter().sum();
        let capacity = (total as f64 / machines as f64) * 1.1 + 1.0;
        let mut order: Vec<AtomId> = (0..k).collect();
        order.sort_by_key(|&a| std::cmp::Reverse(self.atom_weight[a]));
        let mut assignment = vec![usize::MAX; k];
        let mut load = vec![0u64; machines];
        for a in order {
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for m in 0..machines {
                if load[m] as f64 + self.atom_weight[a] as f64 > capacity && load[m] > 0 {
                    continue;
                }
                let affinity: u64 = self.adjacency[a]
                    .iter()
                    .filter(|&&(b, _)| assignment[b] == m)
                    .map(|&(_, w)| w)
                    .sum();
                let balance = 1.0 - load[m] as f64 / capacity;
                let score = affinity as f64 * balance.max(0.01);
                if score > best_score {
                    best_score = score;
                    best = m;
                }
            }
            assignment[a] = best;
            load[best] += self.atom_weight[a];
        }
        assignment
    }
}

// ---------------------------------------------------------------------------
// the on-disk atom store
// ---------------------------------------------------------------------------

/// File magics (little-endian u32) for the two file kinds.
const META_MAGIC: u32 = u32::from_le_bytes(*b"GLAM");
const ATOM_MAGIC: u32 = u32::from_le_bytes(*b"GLAA");

/// One interior vertex of an atom journal: global id, adjacency in global
/// CSR order (`(neighbor gvid, global edge id)`), vertex data.
type VertexRecord<V> = (VertexId, Vec<(VertexId, EdgeId)>, V);
/// One ghost snapshot: global id + data at save time.
type GhostRecord<V> = (VertexId, V);
/// One incident edge: global edge id, both endpoints in insertion order,
/// edge data.
type EdgeRecord<E> = (EdgeId, VertexId, VertexId, E);

/// The decoded body of one atom journal.
type AtomBody<V, E> = (Vec<VertexRecord<V>>, Vec<GhostRecord<V>>, Vec<EdgeRecord<E>>);

fn atom_file_name(atom: AtomId) -> String {
    format!("atom_{atom}.bin")
}

/// Validate a `magic + WIRE_VERSION` file header (shared by the atom
/// store and the snapshot files in [`crate::distributed::snapshot`],
/// which reuse the journal conventions).
pub(crate) fn check_header(input: &mut &[u8], magic: u32, path: &Path) -> anyhow::Result<()> {
    let got_magic = u32::decode(input).with_context(|| format!("{}", path.display()))?;
    if got_magic != magic {
        bail!(
            "{}: bad magic {got_magic:#010x} (expected {magic:#010x})",
            path.display()
        );
    }
    let version = u32::decode(input)?;
    if version != WIRE_VERSION {
        bail!(
            "{}: wire version {version} (this build speaks {WIRE_VERSION})",
            path.display()
        );
    }
    Ok(())
}

impl AtomSet {
    /// Write this over-partition of `g` to `dir` as the paper's on-disk
    /// atom store: one journal file per atom plus `meta.bin` (assignment +
    /// meta-graph). Any cluster size can later load the same directory.
    pub fn save_atoms<V: Wire, E: Wire>(&self, g: &Graph<V, E>, dir: &Path) -> anyhow::Result<()> {
        let n = g.num_vertices();
        if self.assignment.len() != n {
            bail!(
                "atom set covers {} vertices but the graph has {n}",
                self.assignment.len()
            );
        }
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating atoms dir {}", dir.display()))?;

        // meta.bin: counts + assignment + the (tiny) meta-graph, so load
        // time never needs the data graph for phase-2 placement.
        let meta_graph = MetaGraph::build(g, self);
        let mut buf = Vec::new();
        META_MAGIC.encode(&mut buf);
        WIRE_VERSION.encode(&mut buf);
        (n as u64).encode(&mut buf);
        (g.num_edges() as u64).encode(&mut buf);
        (self.num_atoms as u32).encode(&mut buf);
        // Data-type tags: loading a store with the wrong app's types is a
        // clear error up front, not a confusing decode failure mid-file.
        std::any::type_name::<V>().to_string().encode(&mut buf);
        std::any::type_name::<E>().to_string().encode(&mut buf);
        let assignment32: Vec<u32> = self.assignment.iter().map(|&a| a as u32).collect();
        assignment32.encode(&mut buf);
        meta_graph.atom_weight.encode(&mut buf);
        let adjacency32: Vec<Vec<(u32, u64)>> = meta_graph
            .adjacency
            .iter()
            .map(|adj| adj.iter().map(|&(b, w)| (b as u32, w)).collect())
            .collect();
        adjacency32.encode(&mut buf);
        let meta_path = dir.join("meta.bin");
        std::fs::write(&meta_path, &buf)
            .with_context(|| format!("writing {}", meta_path.display()))?;

        // One journal per atom: interior vertices (with adjacency in
        // global CSR order — the replay needs the exact order to rebuild
        // identical local graphs), ghost data snapshots, incident edges.
        // Bucket vertices by atom in one pass (ascending id within each
        // bucket) rather than rescanning all n vertices per atom.
        let mut by_atom: Vec<Vec<VertexId>> = vec![Vec::new(); self.num_atoms];
        for v in 0..n as VertexId {
            by_atom[self.atom(v)].push(v);
        }
        for (atom, members) in by_atom.iter().enumerate() {
            let mut verts: Vec<VertexRecord<&V>> = Vec::new();
            let mut ghosts: Vec<GhostRecord<&V>> = Vec::new();
            let mut edges: Vec<EdgeRecord<&E>> = Vec::new();
            let mut ghost_seen = std::collections::HashSet::new();
            let mut edge_seen = std::collections::HashSet::new();
            for &v in members {
                let adj: Vec<(VertexId, EdgeId)> = g.neighbors(v).to_vec();
                for &(u, e) in &adj {
                    if self.atom(u) != atom && ghost_seen.insert(u) {
                        ghosts.push((u, g.vertex_data(u)));
                    }
                    if edge_seen.insert(e) {
                        let (a, b) = g.endpoints(e);
                        edges.push((e, a, b, g.edge_data(e)));
                    }
                }
                verts.push((v, adj, g.vertex_data(v)));
            }
            let mut buf = Vec::new();
            ATOM_MAGIC.encode(&mut buf);
            WIRE_VERSION.encode(&mut buf);
            (atom as u32).encode(&mut buf);
            (verts.len() as u32).encode(&mut buf);
            for (v, adj, data) in &verts {
                v.encode(&mut buf);
                adj.encode(&mut buf);
                data.encode(&mut buf);
            }
            (ghosts.len() as u32).encode(&mut buf);
            for (v, data) in &ghosts {
                v.encode(&mut buf);
                data.encode(&mut buf);
            }
            (edges.len() as u32).encode(&mut buf);
            for (e, a, b, data) in &edges {
                e.encode(&mut buf);
                a.encode(&mut buf);
                b.encode(&mut buf);
                data.encode(&mut buf);
            }
            let path = dir.join(atom_file_name(atom));
            std::fs::write(&path, &buf)
                .with_context(|| format!("writing {}", path.display()))?;
        }
        Ok(())
    }
}

/// Read and decode one atom journal.
pub(crate) fn read_atom_file<V: Wire, E: Wire>(
    dir: &Path,
    atom: AtomId,
) -> anyhow::Result<AtomBody<V, E>> {
    let path = dir.join(atom_file_name(atom));
    let buf =
        std::fs::read(&path).with_context(|| format!("reading atom file {}", path.display()))?;
    let mut input = &buf[..];
    check_header(&mut input, ATOM_MAGIC, &path)?;
    let stored_atom = u32::decode(&mut input)?;
    if stored_atom as usize != atom {
        bail!("{}: holds atom {stored_atom}, expected {atom}", path.display());
    }
    let body = (|| -> wire::Result<AtomBody<V, E>> {
        let nverts = u32::decode(&mut input)? as usize;
        let mut verts = Vec::with_capacity(nverts.min(input.len()));
        for _ in 0..nverts {
            verts.push(<VertexRecord<V>>::decode(&mut input)?);
        }
        let nghosts = u32::decode(&mut input)? as usize;
        let mut ghosts = Vec::with_capacity(nghosts.min(input.len().max(1)));
        for _ in 0..nghosts {
            ghosts.push(<GhostRecord<V>>::decode(&mut input)?);
        }
        let nedges = u32::decode(&mut input)? as usize;
        let mut edges = Vec::with_capacity(nedges.min(input.len().max(1)));
        for _ in 0..nedges {
            edges.push(<EdgeRecord<E>>::decode(&mut input)?);
        }
        if !input.is_empty() {
            return Err(wire::WireError::Trailing { extra: input.len() });
        }
        Ok((verts, ghosts, edges))
    })()
    .with_context(|| format!("decoding atom file {}", path.display()))?;
    Ok(body)
}

/// The opened metadata of an on-disk atom store (`meta.bin`): everything
/// phase-2 placement needs without reading a single atom journal.
#[derive(Debug, Clone)]
pub struct AtomStore {
    /// The phase-1 vertex → atom assignment.
    pub atoms: AtomSet,
    /// The stored meta-graph (phase-2 input).
    pub meta: MetaGraph,
    /// `type_name` of the stored vertex data.
    pub vtype: String,
    /// `type_name` of the stored edge data.
    pub etype: String,
    /// Vertex count of the stored graph.
    pub num_vertices: usize,
    /// Edge count of the stored graph.
    pub num_edges: usize,
    /// The directory this store was opened from.
    pub dir: PathBuf,
}

/// Read only the stored vertex/edge type tags from `dir/meta.bin`,
/// without parsing the O(V) assignment or the meta-graph (the tags sit
/// in the file's first bytes). This is what `graphlab worker` uses to
/// infer the app — cheap even for huge stores.
pub fn peek_types(dir: &Path) -> anyhow::Result<(String, String)> {
    use std::io::Read as _;
    let path = dir.join("meta.bin");
    let f = std::fs::File::open(&path)
        .with_context(|| format!("reading atom store meta {}", path.display()))?;
    // Type names are short; 64 KiB comfortably covers the header.
    let mut head = Vec::with_capacity(4096);
    f.take(64 * 1024)
        .read_to_end(&mut head)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut input = &head[..];
    check_header(&mut input, META_MAGIC, &path)?;
    let _num_vertices = u64::decode(&mut input)?;
    let _num_edges = u64::decode(&mut input)?;
    let _num_atoms = u32::decode(&mut input)?;
    let vtype =
        String::decode(&mut input).with_context(|| format!("decoding {}", path.display()))?;
    let etype = String::decode(&mut input)?;
    Ok((vtype, etype))
}

impl AtomStore {
    /// Open `dir/meta.bin`.
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("meta.bin");
        let buf = std::fs::read(&path)
            .with_context(|| format!("reading atom store meta {}", path.display()))?;
        let mut input = &buf[..];
        check_header(&mut input, META_MAGIC, &path)?;
        let num_vertices = u64::decode(&mut input)? as usize;
        let num_edges = u64::decode(&mut input)? as usize;
        let num_atoms = u32::decode(&mut input)? as usize;
        let vtype = String::decode(&mut input)
            .with_context(|| format!("decoding {}", path.display()))?;
        let etype = String::decode(&mut input)?;
        let assignment32 = Vec::<u32>::decode(&mut input)?;
        let atom_weight = Vec::<u64>::decode(&mut input)?;
        let adjacency32 = Vec::<Vec<(u32, u64)>>::decode(&mut input)?;
        // Range-check everything that later code indexes with: a corrupt
        // store must error here, never panic downstream.
        if assignment32.len() != num_vertices
            || atom_weight.len() != num_atoms
            || adjacency32.len() != num_atoms
        {
            bail!("{}: inconsistent counts", path.display());
        }
        if assignment32.iter().any(|&a| a as usize >= num_atoms)
            || adjacency32
                .iter()
                .flatten()
                .any(|&(b, _)| b as usize >= num_atoms)
        {
            bail!("{}: atom id out of range", path.display());
        }
        Ok(AtomStore {
            atoms: AtomSet {
                assignment: assignment32.into_iter().map(|a| a as AtomId).collect(),
                num_atoms,
            },
            meta: MetaGraph {
                atom_weight,
                adjacency: adjacency32
                    .into_iter()
                    .map(|adj| adj.into_iter().map(|(b, w)| (b as AtomId, w)).collect())
                    .collect(),
            },
            vtype,
            etype,
            num_vertices,
            num_edges,
            dir: dir.to_path_buf(),
        })
    }

    /// Check the stored vertex/edge data types against the ones the
    /// caller is about to decode: a store written by a different app
    /// fails here with both names, not with a decode error mid-journal.
    pub fn check_types<V, E>(&self) -> anyhow::Result<()> {
        let (v, e) = (std::any::type_name::<V>(), std::any::type_name::<E>());
        if self.vtype != v || self.etype != e {
            bail!(
                "atom store {} holds {} / {} data but {} / {} was requested",
                self.dir.display(),
                self.vtype,
                self.etype,
                v,
                e
            );
        }
        Ok(())
    }

    /// Phase 2 for this store: place atoms on `machines` machines and
    /// expand to the vertex-level [`Partition`] plus the
    /// [`AtomPlacement`] the distributed engines' disk loaders need.
    pub fn place(&self, machines: usize) -> (Partition, AtomPlacement) {
        let atom_to_machine = self.meta.partition(machines);
        let assignment: Vec<MachineId> = (0..self.num_vertices as VertexId)
            .map(|v| atom_to_machine[self.atoms.atom(v)])
            .collect();
        (
            Partition::from_assignment(assignment, machines),
            AtomPlacement {
                dir: self.dir.clone(),
                atom_to_machine,
            },
        )
    }
}

/// Disk-load routing for a distributed engine: where the atom journals
/// live and which machine each atom landed on (phase-2 output).
#[derive(Debug, Clone)]
pub struct AtomPlacement {
    /// The atom store directory.
    pub dir: PathBuf,
    /// Atom → machine assignment.
    pub atom_to_machine: Vec<MachineId>,
}

/// Replay every atom journal in `dir` into a full data graph (the driver
/// side reassembly / shared-engine load path). Returns the graph plus the
/// opened store metadata.
pub fn load_graph<V: Wire, E: Wire>(dir: &Path) -> anyhow::Result<(Graph<V, E>, AtomStore)> {
    let store = AtomStore::open(dir)?;
    store.check_types::<V, E>()?;
    let n = store.num_vertices;
    let m = store.num_edges;
    let mut vdata: Vec<Option<V>> = (0..n).map(|_| None).collect();
    let mut edges: Vec<Option<(VertexId, VertexId, E)>> = (0..m).map(|_| None).collect();
    for atom in 0..store.atoms.num_atoms() {
        let (verts, _ghosts, atom_edges) = read_atom_file::<V, E>(dir, atom)?;
        for (v, _adj, data) in verts {
            let slot = vdata
                .get_mut(v as usize)
                .with_context(|| format!("atom {atom}: vertex {v} out of range"))?;
            *slot = Some(data);
        }
        for (e, a, b, data) in atom_edges {
            let slot = edges
                .get_mut(e as usize)
                .with_context(|| format!("atom {atom}: edge {e} out of range"))?;
            if slot.is_none() {
                *slot = Some((a, b, data));
            }
        }
    }
    let mut builder = GraphBuilder::with_capacity(n, m);
    for (v, slot) in vdata.into_iter().enumerate() {
        let Some(data) = slot else {
            bail!("atom store {}: vertex {v} missing from every atom", dir.display());
        };
        builder.add_vertex(data);
    }
    // Re-add edges in global edge-id order: the rebuilt CSR (and therefore
    // every downstream local graph) is bit-identical to the original.
    for (e, slot) in edges.into_iter().enumerate() {
        let Some((a, b, data)) = slot else {
            bail!("atom store {}: edge {e} missing from every atom", dir.display());
        };
        builder.add_edge(a, b, data);
    }
    Ok((builder.build(), store))
}

/// Resolve an atoms directory the same cwd-robust way as the artifacts
/// dir: an explicit argument wins, then `GRAPHLAB_ATOMS`, then `atoms/`
/// relative to the cwd, then `atoms/` next to the workspace root (cargo
/// runs test binaries with cwd = the package dir `rust/`).
pub fn resolve_atoms_dir(arg: Option<&str>) -> PathBuf {
    if let Some(dir) = arg {
        return PathBuf::from(dir);
    }
    if let Ok(dir) = std::env::var("GRAPHLAB_ATOMS") {
        return PathBuf::from(dir);
    }
    let local = PathBuf::from("atoms");
    if local.exists() {
        return local;
    }
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("atoms");
    if repo_root.exists() {
        return repo_root;
    }
    local
}

/// The full two-phase pipeline: atoms → meta-graph → machine assignment.
pub fn two_phase<V, E>(g: &Graph<V, E>, k: usize, machines: usize, seed: u64) -> Partition {
    let atoms = AtomSet::grow_bfs(g, k, seed);
    let meta = MetaGraph::build(g, &atoms);
    let atom_to_machine = meta.partition(machines);
    let assignment = (0..g.num_vertices() as VertexId)
        .map(|v| atom_to_machine[atoms.atom(v)])
        .collect();
    Partition::from_assignment(assignment, machines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn grid(n: usize) -> Graph<u8, u8> {
        let mut b = GraphBuilder::new();
        b.add_vertices(n * n, |_| 0);
        for i in 0..n {
            for j in 0..n {
                let v = (i * n + j) as VertexId;
                if j + 1 < n {
                    b.add_edge(v, v + 1, 0);
                }
                if i + 1 < n {
                    b.add_edge(v, v + n as u32, 0);
                }
            }
        }
        b.build()
    }

    #[test]
    fn bfs_atoms_cover_and_balance() {
        let g = grid(20);
        let atoms = AtomSet::grow_bfs(&g, 16, 1);
        let sizes = atoms.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 400);
        assert!(*sizes.iter().max().unwrap() <= 2 * 400 / 16 + 1);
    }

    #[test]
    fn meta_graph_edge_weights_match_cut() {
        let g = grid(10);
        let atoms = AtomSet::grow_bfs(&g, 4, 2);
        let meta = MetaGraph::build(&g, &atoms);
        // Total meta edge weight (each pair counted once per direction / 2)
        let total: u64 = meta.adjacency.iter().flatten().map(|&(_, w)| w).sum::<u64>() / 2;
        let cut = (0..g.num_edges() as u32)
            .filter(|&e| {
                let (u, v) = g.endpoints(e);
                atoms.atom(u) != atoms.atom(v)
            })
            .count() as u64;
        assert_eq!(total, cut);
    }

    #[test]
    fn two_phase_beats_random_cut_on_grid() {
        let g = grid(24);
        let tp = two_phase(&g, 32, 4, 3);
        let rand = Partition::random(g.num_vertices(), 4, 3);
        assert!(tp.imbalance() < 1.5, "imbalance={}", tp.imbalance());
        assert!(
            tp.edge_cut(&g) < rand.edge_cut(&g),
            "two-phase {} vs random {}",
            tp.edge_cut(&g),
            rand.edge_cut(&g)
        );
    }

    #[test]
    fn same_atoms_serve_multiple_cluster_sizes() {
        let g = grid(16);
        let atoms = AtomSet::grow_bfs(&g, 32, 4);
        let meta = MetaGraph::build(&g, &atoms);
        for machines in [2, 4, 8] {
            let assign = meta.partition(machines);
            assert_eq!(assign.len(), 32);
            assert!(assign.iter().all(|&m| m < machines));
            // Every machine gets at least one atom at these sizes.
            let mut used = vec![false; machines];
            for &m in &assign {
                used[m] = true;
            }
            assert!(used.iter().all(|&u| u), "machines={machines}");
        }
    }
}
