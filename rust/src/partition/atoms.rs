//! Two-phase partitioning: atoms + meta-graph (paper Sec. 4.1, Fig. 4).
//!
//! Phase 1 (offline, expensive): over-partition the data graph into
//! `k >> #machines` **atoms** with a BFS region-grower (our stand-in for
//! Metis — DESIGN.md §Substitutions). Each atom corresponds to one "file"
//! in the paper's scheme.
//!
//! Phase 2 (load time, cheap): build the **meta-graph** — one vertex per
//! atom weighted by its data size, one edge per atom pair weighted by the
//! number of crossing edges — and run a fast balanced greedy partition of
//! the meta-graph onto the actual machine count. The same atom set serves
//! any cluster size without re-partitioning the full graph.

use super::{MachineId, Partition};
use crate::graph::{Graph, VertexId};
use crate::util::Rng;
use std::collections::VecDeque;

/// Atom id (phase-1 part index).
pub type AtomId = usize;

/// A phase-1 over-partition: vertex → atom.
#[derive(Debug, Clone)]
pub struct AtomSet {
    assignment: Vec<AtomId>,
    num_atoms: usize,
}

impl AtomSet {
    /// BFS region-growing over-partition into `k` atoms of roughly equal
    /// vertex count. Deterministic given the seed (seeds pick BFS sources).
    pub fn grow_bfs<V, E>(g: &Graph<V, E>, k: usize, seed: u64) -> Self {
        let n = g.num_vertices();
        let k = k.max(1).min(n.max(1));
        let target = n.div_ceil(k);
        let mut assignment = vec![usize::MAX; n];
        let mut rng = Rng::new(seed);
        let mut atom = 0usize;
        let mut unvisited: Vec<VertexId> = (0..n as VertexId).collect();
        rng.shuffle(&mut unvisited);
        let mut cursor = 0usize;
        let mut queue = VecDeque::new();
        let mut size = 0usize;
        while cursor < unvisited.len() {
            // Find a fresh BFS source.
            while cursor < unvisited.len() && assignment[unvisited[cursor] as usize] != usize::MAX
            {
                cursor += 1;
            }
            if cursor >= unvisited.len() {
                break;
            }
            queue.push_back(unvisited[cursor]);
            while let Some(v) = queue.pop_front() {
                if assignment[v as usize] != usize::MAX {
                    continue;
                }
                assignment[v as usize] = atom;
                size += 1;
                if size >= target && atom + 1 < k {
                    atom += 1;
                    size = 0;
                    queue.clear();
                    break;
                }
                for &(u, _) in g.neighbors(v) {
                    if assignment[u as usize] == usize::MAX {
                        queue.push_back(u);
                    }
                }
            }
        }
        // Any leftovers (disconnected tails after a clear) go to the
        // smallest atom.
        let mut sizes = vec![0usize; k];
        for &a in assignment.iter().filter(|&&a| a != usize::MAX) {
            sizes[a] += 1;
        }
        for a in assignment.iter_mut().filter(|a| **a == usize::MAX) {
            let m = (0..k).min_by_key(|&i| sizes[i]).unwrap();
            *a = m;
            sizes[m] += 1;
        }
        AtomSet {
            assignment,
            num_atoms: k,
        }
    }

    /// Hash over-partition (the "random" baseline for dense graphs).
    pub fn hashed(num_vertices: usize, k: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        AtomSet {
            assignment: (0..num_vertices).map(|_| rng.gen_range(k)).collect(),
            num_atoms: k,
        }
    }

    /// Atom of vertex `v`.
    pub fn atom(&self, v: VertexId) -> AtomId {
        self.assignment[v as usize]
    }

    /// Number of atoms.
    pub fn num_atoms(&self) -> usize {
        self.num_atoms
    }

    /// Atom sizes (vertex counts).
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.num_atoms];
        for &a in &self.assignment {
            s[a] += 1;
        }
        s
    }
}

/// The weighted atom-connectivity graph (paper Fig. 4(c)).
#[derive(Debug, Clone)]
pub struct MetaGraph {
    /// Vertex weight of each atom: bytes (here: vertex count as proxy).
    pub atom_weight: Vec<u64>,
    /// `edge_weight[a]` = list of `(b, crossing_edges)` for b adjacent to a.
    pub adjacency: Vec<Vec<(AtomId, u64)>>,
}

impl MetaGraph {
    /// Build the meta-graph of an atom set over a data graph.
    pub fn build<V, E>(g: &Graph<V, E>, atoms: &AtomSet) -> Self {
        let k = atoms.num_atoms();
        let mut atom_weight = vec![0u64; k];
        for v in 0..g.num_vertices() as VertexId {
            atom_weight[atoms.atom(v)] += 1;
        }
        let mut pair_counts: std::collections::HashMap<(AtomId, AtomId), u64> =
            std::collections::HashMap::new();
        for e in 0..g.num_edges() as u32 {
            let (u, v) = g.endpoints(e);
            let (a, b) = (atoms.atom(u), atoms.atom(v));
            if a != b {
                let key = (a.min(b), a.max(b));
                *pair_counts.entry(key).or_insert(0) += 1;
            }
        }
        let mut adjacency = vec![Vec::new(); k];
        for (&(a, b), &w) in &pair_counts {
            adjacency[a].push((b, w));
            adjacency[b].push((a, w));
        }
        for adj in &mut adjacency {
            adj.sort_unstable();
        }
        MetaGraph {
            atom_weight,
            adjacency,
        }
    }

    /// Fast balanced greedy partition of the meta-graph onto `machines`
    /// parts (phase 2). Atoms are placed heaviest-first onto the machine
    /// maximizing (edge affinity − balance penalty), an LDG-style
    /// streaming heuristic.
    pub fn partition(&self, machines: usize) -> Vec<MachineId> {
        let k = self.atom_weight.len();
        let machines = machines.max(1);
        let total: u64 = self.atom_weight.iter().sum();
        let capacity = (total as f64 / machines as f64) * 1.1 + 1.0;
        let mut order: Vec<AtomId> = (0..k).collect();
        order.sort_by_key(|&a| std::cmp::Reverse(self.atom_weight[a]));
        let mut assignment = vec![usize::MAX; k];
        let mut load = vec![0u64; machines];
        for a in order {
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for m in 0..machines {
                if load[m] as f64 + self.atom_weight[a] as f64 > capacity && load[m] > 0 {
                    continue;
                }
                let affinity: u64 = self.adjacency[a]
                    .iter()
                    .filter(|&&(b, _)| assignment[b] == m)
                    .map(|&(_, w)| w)
                    .sum();
                let balance = 1.0 - load[m] as f64 / capacity;
                let score = affinity as f64 * balance.max(0.01);
                if score > best_score {
                    best_score = score;
                    best = m;
                }
            }
            assignment[a] = best;
            load[best] += self.atom_weight[a];
        }
        assignment
    }
}

/// The full two-phase pipeline: atoms → meta-graph → machine assignment.
pub fn two_phase<V, E>(g: &Graph<V, E>, k: usize, machines: usize, seed: u64) -> Partition {
    let atoms = AtomSet::grow_bfs(g, k, seed);
    let meta = MetaGraph::build(g, &atoms);
    let atom_to_machine = meta.partition(machines);
    let assignment = (0..g.num_vertices() as VertexId)
        .map(|v| atom_to_machine[atoms.atom(v)])
        .collect();
    Partition::from_assignment(assignment, machines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn grid(n: usize) -> Graph<u8, u8> {
        let mut b = GraphBuilder::new();
        b.add_vertices(n * n, |_| 0);
        for i in 0..n {
            for j in 0..n {
                let v = (i * n + j) as VertexId;
                if j + 1 < n {
                    b.add_edge(v, v + 1, 0);
                }
                if i + 1 < n {
                    b.add_edge(v, v + n as u32, 0);
                }
            }
        }
        b.build()
    }

    #[test]
    fn bfs_atoms_cover_and_balance() {
        let g = grid(20);
        let atoms = AtomSet::grow_bfs(&g, 16, 1);
        let sizes = atoms.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 400);
        assert!(*sizes.iter().max().unwrap() <= 2 * 400 / 16 + 1);
    }

    #[test]
    fn meta_graph_edge_weights_match_cut() {
        let g = grid(10);
        let atoms = AtomSet::grow_bfs(&g, 4, 2);
        let meta = MetaGraph::build(&g, &atoms);
        // Total meta edge weight (each pair counted once per direction / 2)
        let total: u64 = meta.adjacency.iter().flatten().map(|&(_, w)| w).sum::<u64>() / 2;
        let cut = (0..g.num_edges() as u32)
            .filter(|&e| {
                let (u, v) = g.endpoints(e);
                atoms.atom(u) != atoms.atom(v)
            })
            .count() as u64;
        assert_eq!(total, cut);
    }

    #[test]
    fn two_phase_beats_random_cut_on_grid() {
        let g = grid(24);
        let tp = two_phase(&g, 32, 4, 3);
        let rand = Partition::random(g.num_vertices(), 4, 3);
        assert!(tp.imbalance() < 1.5, "imbalance={}", tp.imbalance());
        assert!(
            tp.edge_cut(&g) < rand.edge_cut(&g),
            "two-phase {} vs random {}",
            tp.edge_cut(&g),
            rand.edge_cut(&g)
        );
    }

    #[test]
    fn same_atoms_serve_multiple_cluster_sizes() {
        let g = grid(16);
        let atoms = AtomSet::grow_bfs(&g, 32, 4);
        let meta = MetaGraph::build(&g, &atoms);
        for machines in [2, 4, 8] {
            let assign = meta.partition(machines);
            assert_eq!(assign.len(), 32);
            assert!(assign.iter().all(|&m| m < machines));
            // Every machine gets at least one atom at these sizes.
            let mut used = vec![false; machines];
            for &m in &assign {
                used[m] = true;
            }
            assert!(used.iter().all(|&u| u), "machines={machines}");
        }
    }
}
