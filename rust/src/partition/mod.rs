//! Distributed data-graph partitioning (paper Sec. 4.1).
//!
//! The paper's **two-phase partitioning**: the graph is first
//! over-partitioned into `k >> #machines` *atoms* (by an expert, Metis, or
//! a heuristic — here a deterministic BFS grower or a hash cut), the atom
//! connectivity is summarized in a **meta-graph** weighted by data volume
//! and cross-atom edge counts, and at load time the meta-graph is quickly
//! re-partitioned onto the actual number of machines. This lets one atom
//! decomposition serve any cluster size without re-running the expensive
//! partitioner.
//!
//! [`Partition`] is the final vertex→machine assignment used by the
//! distributed engines; [`atoms`] implements the two-phase pipeline;
//! [`coloring`] provides the vertex colorings that drive the Chromatic
//! engine's consistency guarantees.

pub mod atoms;
pub mod coloring;

pub use atoms::{AtomSet, MetaGraph};
pub use coloring::Coloring;

use crate::graph::{Graph, VertexId};
use crate::util::Rng;

/// Machine identifier within a cluster.
pub type MachineId = usize;

/// A vertex → machine assignment.
#[derive(Debug, Clone)]
pub struct Partition {
    assignment: Vec<MachineId>,
    machines: usize,
}

impl Partition {
    /// Wrap an explicit assignment.
    pub fn from_assignment(assignment: Vec<MachineId>, machines: usize) -> Self {
        debug_assert!(assignment.iter().all(|&m| m < machines));
        Partition {
            assignment,
            machines,
        }
    }

    /// Random (hash) partition — what the paper uses for the dense Netflix
    /// and NER graphs ("random" in Table 2).
    pub fn random(num_vertices: usize, machines: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Partition {
            assignment: (0..num_vertices).map(|_| rng.gen_range(machines)).collect(),
            machines,
        }
    }

    /// Contiguous block partition (CoSeg's "frames" cut: slicing the 3-D
    /// grid across its slowest axis maps to contiguous vertex ranges).
    pub fn blocked(num_vertices: usize, machines: usize) -> Self {
        let per = num_vertices.div_ceil(machines.max(1));
        Partition {
            assignment: (0..num_vertices).map(|v| (v / per).min(machines - 1)).collect(),
            machines,
        }
    }

    /// Striped partition (round-robin) — the deliberately *worst-case* cut
    /// used in the paper's Fig. 8(b) lock-pipelining stress test.
    pub fn striped(num_vertices: usize, machines: usize) -> Self {
        Partition {
            assignment: (0..num_vertices).map(|v| v % machines).collect(),
            machines,
        }
    }

    /// Owner of vertex `v`.
    #[inline]
    pub fn owner(&self, v: VertexId) -> MachineId {
        self.assignment[v as usize]
    }

    /// Number of machines.
    pub fn machines(&self) -> usize {
        self.machines
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.assignment.len()
    }

    /// Vertices owned by machine `m`.
    pub fn owned(&self, m: MachineId) -> Vec<VertexId> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|&(_, &o)| o == m)
            .map(|(v, _)| v as VertexId)
            .collect()
    }

    /// Vertex counts per machine.
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.machines];
        for &m in &self.assignment {
            s[m] += 1;
        }
        s
    }

    /// Load imbalance: max/mean machine size (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let sizes = self.sizes();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let mean = self.assignment.len() as f64 / self.machines as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Number of edges crossing machines (the communication volume driver).
    pub fn edge_cut<V, E>(&self, g: &Graph<V, E>) -> usize {
        (0..g.num_edges() as u32)
            .filter(|&e| {
                let (u, v) = g.endpoints(e);
                self.owner(u) != self.owner(v)
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn grid(n: usize) -> Graph<u8, u8> {
        let mut b = GraphBuilder::new();
        b.add_vertices(n * n, |_| 0);
        for i in 0..n {
            for j in 0..n {
                let v = (i * n + j) as VertexId;
                if j + 1 < n {
                    b.add_edge(v, v + 1, 0);
                }
                if i + 1 < n {
                    b.add_edge(v, v + n as u32, 0);
                }
            }
        }
        b.build()
    }

    #[test]
    fn random_partition_is_roughly_balanced() {
        let p = Partition::random(10_000, 8, 42);
        assert!(p.imbalance() < 1.15, "imbalance={}", p.imbalance());
        assert_eq!(p.sizes().iter().sum::<usize>(), 10_000);
    }

    #[test]
    fn blocked_beats_striped_on_grids() {
        let g = grid(32);
        let blocked = Partition::blocked(g.num_vertices(), 4);
        let striped = Partition::striped(g.num_vertices(), 4);
        assert!(
            blocked.edge_cut(&g) * 4 < striped.edge_cut(&g),
            "blocked={} striped={}",
            blocked.edge_cut(&g),
            striped.edge_cut(&g)
        );
    }

    #[test]
    fn owned_partitions_are_disjoint_and_complete() {
        let p = Partition::random(1000, 5, 7);
        let mut seen = vec![false; 1000];
        for m in 0..5 {
            for v in p.owned(m) {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
                assert_eq!(p.owner(v), m);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
