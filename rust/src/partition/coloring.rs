//! Vertex colorings for the Chromatic engine (paper Sec. 4.2.1).
//!
//! A proper vertex coloring satisfies the **edge consistency** model when
//! the engine executes one color at a time; a *second-order* coloring
//! (distance-2) satisfies **full consistency**; the trivial single color
//! satisfies **vertex consistency**. Bipartite graphs (ALS, CoEM) are
//! two-colored directly, as the paper notes ("the bipartite graph is
//! naturally two colored").

use crate::graph::{Graph, VertexId};
use std::collections::VecDeque;

/// A vertex coloring: `color[v]` in `0..num_colors`.
#[derive(Debug, Clone)]
pub struct Coloring {
    colors: Vec<u32>,
    num_colors: u32,
}

impl Coloring {
    /// Greedy first-fit coloring in descending-degree order (the classic
    /// heuristic; exact chromatic number is NP-hard and unnecessary).
    pub fn greedy<V, E>(g: &Graph<V, E>) -> Self {
        let n = g.num_vertices();
        let mut order: Vec<VertexId> = (0..n as VertexId).collect();
        order.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
        let mut colors = vec![u32::MAX; n];
        let mut used = Vec::new();
        let mut num_colors = 0u32;
        for v in order {
            used.clear();
            used.resize(num_colors as usize + 1, false);
            for &(u, _) in g.neighbors(v) {
                let c = colors[u as usize];
                if c != u32::MAX {
                    used[c as usize] = true;
                }
            }
            let c = used.iter().position(|&b| !b).unwrap() as u32;
            colors[v as usize] = c;
            num_colors = num_colors.max(c + 1);
        }
        Coloring { colors, num_colors }
    }

    /// Two-coloring by BFS; returns `None` if the graph has an odd cycle.
    /// ALS and CoEM graphs are bipartite by construction, so this is the
    /// coloring their chromatic runs use.
    pub fn bipartite<V, E>(g: &Graph<V, E>) -> Option<Self> {
        let n = g.num_vertices();
        let mut colors = vec![u32::MAX; n];
        let mut queue = VecDeque::new();
        for s in 0..n as VertexId {
            if colors[s as usize] != u32::MAX {
                continue;
            }
            colors[s as usize] = 0;
            queue.push_back(s);
            while let Some(v) = queue.pop_front() {
                let cv = colors[v as usize];
                for &(u, _) in g.neighbors(v) {
                    let cu = &mut colors[u as usize];
                    if *cu == u32::MAX {
                        *cu = 1 - cv;
                        queue.push_back(u);
                    } else if *cu == cv {
                        return None;
                    }
                }
            }
        }
        Some(Coloring {
            colors,
            num_colors: if n == 0 { 0 } else { 2 },
        })
    }

    /// Second-order (distance-2) greedy coloring: no vertex shares a color
    /// with any vertex within two hops. Satisfies the **full consistency**
    /// model under the chromatic schedule.
    pub fn second_order<V, E>(g: &Graph<V, E>) -> Self {
        let n = g.num_vertices();
        let mut order: Vec<VertexId> = (0..n as VertexId).collect();
        order.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
        let mut colors = vec![u32::MAX; n];
        let mut num_colors = 0u32;
        let mut used = Vec::new();
        for v in order {
            used.clear();
            used.resize(num_colors as usize + 1, false);
            for &(u, _) in g.neighbors(v) {
                let c = colors[u as usize];
                if c != u32::MAX {
                    used[c as usize] = true;
                }
                for &(w, _) in g.neighbors(u) {
                    if w == v {
                        continue;
                    }
                    let c2 = colors[w as usize];
                    if c2 != u32::MAX {
                        used[c2 as usize] = true;
                    }
                }
            }
            let c = used.iter().position(|&b| !b).unwrap() as u32;
            colors[v as usize] = c;
            num_colors = num_colors.max(c + 1);
        }
        Coloring { colors, num_colors }
    }

    /// Single-color "coloring" — trivially satisfies vertex consistency
    /// (all updates independent, Map-like).
    pub fn uniform(num_vertices: usize) -> Self {
        Coloring {
            colors: vec![0; num_vertices],
            num_colors: if num_vertices == 0 { 0 } else { 1 },
        }
    }

    /// Color of vertex `v`.
    #[inline]
    pub fn color(&self, v: VertexId) -> u32 {
        self.colors[v as usize]
    }

    /// Number of distinct colors.
    pub fn num_colors(&self) -> u32 {
        self.num_colors
    }

    /// Number of vertices this coloring covers (engine-config validation).
    pub fn num_vertices(&self) -> usize {
        self.colors.len()
    }

    /// Vertices grouped by color.
    pub fn by_color(&self) -> Vec<Vec<VertexId>> {
        let mut groups = vec![Vec::new(); self.num_colors as usize];
        for (v, &c) in self.colors.iter().enumerate() {
            groups[c as usize].push(v as VertexId);
        }
        groups
    }

    /// Validity: no edge joins same-colored vertices.
    pub fn is_valid<V, E>(&self, g: &Graph<V, E>) -> bool {
        (0..g.num_edges() as u32).all(|e| {
            let (u, v) = g.endpoints(e);
            self.color(u) != self.color(v)
        })
    }

    /// Distance-2 validity (for the full-consistency coloring).
    pub fn is_second_order_valid<V, E>(&self, g: &Graph<V, E>) -> bool {
        if !self.is_valid(g) {
            return false;
        }
        for v in g.vertex_ids() {
            for &(u, _) in g.neighbors(v) {
                for &(w, _) in g.neighbors(u) {
                    if w != v && self.color(w) == self.color(v) {
                        return false;
                    }
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::util::Rng;

    fn random_graph(n: usize, m: usize, seed: u64) -> Graph<u8, u8> {
        let mut rng = Rng::new(seed);
        let mut b = GraphBuilder::new();
        b.add_vertices(n, |_| 0);
        let mut seen = std::collections::HashSet::new();
        while seen.len() < m {
            let u = rng.gen_range(n) as VertexId;
            let v = rng.gen_range(n) as VertexId;
            if u != v && seen.insert((u.min(v), u.max(v))) {
                b.add_edge(u, v, 0);
            }
        }
        b.build()
    }

    fn bipartite_graph(left: usize, right: usize, m: usize, seed: u64) -> Graph<u8, u8> {
        let mut rng = Rng::new(seed);
        let mut b = GraphBuilder::new();
        b.add_vertices(left + right, |_| 0);
        let mut seen = std::collections::HashSet::new();
        while seen.len() < m {
            let u = rng.gen_range(left) as VertexId;
            let v = (left + rng.gen_range(right)) as VertexId;
            if seen.insert((u, v)) {
                b.add_edge(u, v, 0);
            }
        }
        b.build()
    }

    #[test]
    fn greedy_is_valid_on_random_graphs() {
        for seed in 0..5 {
            let g = random_graph(200, 800, seed);
            let c = Coloring::greedy(&g);
            assert!(c.is_valid(&g), "seed={seed}");
            assert!(c.num_colors() <= g.max_degree() as u32 + 1);
        }
    }

    #[test]
    fn bipartite_two_colors() {
        let g = bipartite_graph(50, 80, 400, 9);
        let c = Coloring::bipartite(&g).expect("graph is bipartite");
        assert_eq!(c.num_colors(), 2);
        assert!(c.is_valid(&g));
    }

    #[test]
    fn odd_cycle_rejected() {
        let mut b = GraphBuilder::new();
        b.add_vertices(3, |_| 0u8);
        b.add_edge(0, 1, 0u8);
        b.add_edge(1, 2, 0);
        b.add_edge(2, 0, 0);
        let g = b.build();
        assert!(Coloring::bipartite(&g).is_none());
        let c = Coloring::greedy(&g);
        assert!(c.is_valid(&g));
        assert_eq!(c.num_colors(), 3);
    }

    #[test]
    fn second_order_is_distance_two_valid() {
        for seed in 0..3 {
            let g = random_graph(100, 300, seed + 100);
            let c = Coloring::second_order(&g);
            assert!(c.is_second_order_valid(&g), "seed={seed}");
        }
    }

    #[test]
    fn by_color_partitions_vertices() {
        let g = random_graph(100, 300, 1);
        let c = Coloring::greedy(&g);
        let groups = c.by_color();
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 100);
        for (color, group) in groups.iter().enumerate() {
            for &v in group {
                assert_eq!(c.color(v), color as u32);
            }
        }
    }
}
