//! The **Chromatic engine** (paper Sec. 4.2.1).
//!
//! Executes update tasks in a static color-stratified order: given a proper
//! vertex coloring, all tasks of one color run in parallel across machines
//! (and across threads within a machine) with edge consistency guaranteed
//! by the coloring itself — no locks. Between colors, modified vertex and
//! edge data is pushed to the machines ghosting it (version-tagged, only
//! modified data is sent — the paper's cache-versioning optimization) and
//! a full communication barrier is enforced. Sync operations and the
//! global continue/stop decision run at sweep boundaries through a leader
//! reduction, and the engine's schedule is *deterministic*: repeated runs
//! produce identical update sequences regardless of machine count, the
//! property the paper highlights for debugging.
//!
//! Consistency coverage: a proper coloring yields **edge** consistency; a
//! distance-2 coloring yields **full** consistency; the uniform coloring
//! yields **vertex** consistency (paper Sec. 4.2.1). Callers pick the
//! coloring to match `program.consistency()` (`color_for` helps).

use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::bail;

use super::{Ctx, ExecStats, GlobalValues, Scope, SyncOp, VertexProgram};
use crate::distributed::network::NetworkModel;
use crate::distributed::snapshot::{SnapshotCfg, SnapshotSession};
use crate::distributed::transport::{
    peer_grace, ClusterConfig, FaultPlan, TransportKind, CHROMATIC_GRACE,
};
use crate::distributed::{cluster_setup, ClusterSetup, DataValue, LocalGraph};
use crate::graph::{EdgeId, Graph, SharedStore, VertexId};
use crate::partition::atoms::AtomPlacement;
use crate::partition::{Coloring, Partition};
use crate::scheduler::Task;
use crate::util::ThreadPool;
use crate::wire::{self, Wire};

/// Options for a chromatic run (crate-internal: external callers go
/// through the `engine::Engine` builder).
pub(crate) struct ChromaticOpts {
    /// Machine count (cluster size).
    pub machines: usize,
    /// Worker threads per machine for the color-parallel updates.
    pub threads_per_machine: usize,
    /// Maximum sweeps before forced stop.
    pub max_sweeps: u64,
    /// Network model (latency injection; InProc transport only).
    pub network: NetworkModel,
    /// Which byte-level substrate carries the frames (ignored when
    /// `cluster` is set — a multi-process cluster is always TCP).
    pub transport: TransportKind,
    /// Multi-process mode: run **only** machine `cluster.me` in this
    /// process, over TCP to the other worker processes.
    pub cluster: Option<ClusterConfig>,
    /// Leader-side callback after every sweep: (sweep, total updates,
    /// globals).
    #[allow(clippy::type_complexity)]
    pub on_sweep: Option<Box<dyn Fn(u64, u64, &GlobalValues) + Send + Sync>>,
    /// When set, each machine replays its own on-disk atom journals
    /// instead of slicing the in-memory graph (the paper's load path).
    pub atoms: Option<AtomPlacement>,
    /// When set, the leader cuts Chandy–Lamport snapshots at sweep
    /// boundaries (paper Sec. 4.3).
    pub snapshot: Option<SnapshotCfg>,
    /// Overlay the newest complete snapshot under this directory onto
    /// the freshly-loaded local graphs before running (recovery path).
    pub restore: Option<PathBuf>,
    /// Deterministic fault injection: wrap every transport in a
    /// [`crate::distributed::Faulty`] decorator.
    pub fault: Option<FaultPlan>,
    /// Pin each machine loop to a CPU (`me % available_cpus`) so the OS
    /// scheduler stops migrating engine threads mid-run. Best-effort.
    pub pin_threads: bool,
}

impl Default for ChromaticOpts {
    fn default() -> Self {
        ChromaticOpts {
            machines: 2,
            threads_per_machine: 1,
            max_sweeps: u64::MAX,
            network: NetworkModel::default(),
            transport: TransportKind::InProc,
            cluster: None,
            on_sweep: None,
            atoms: None,
            snapshot: None,
            restore: None,
            fault: None,
            pin_threads: false,
        }
    }
}

/// Pick the coloring that discharges `consistency` for `program`'s runs.
pub fn color_for<V, E>(g: &Graph<V, E>, consistency: super::Consistency) -> Coloring {
    match consistency {
        super::Consistency::Vertex | super::Consistency::Unsafe => {
            Coloring::uniform(g.num_vertices())
        }
        super::Consistency::Edge => {
            Coloring::bipartite(g).unwrap_or_else(|| Coloring::greedy(g))
        }
        super::Consistency::Full => Coloring::second_order(g),
    }
}

enum Msg<V, E> {
    /// Ghost coherence + remote task delivery (flushed once per color).
    /// `sweep` disambiguates which sweep scheduled `tasks`: a peer may be
    /// one sweep ahead of the receiver, and its tasks belong to the sweep
    /// *after* the receiver's next one.
    Ghost {
        sweep: u64,
        verts: Vec<(VertexId, u64, V)>,
        edges: Vec<(EdgeId, u64, E)>,
        tasks: Vec<Task>,
    },
    /// Color barrier marker.
    ColorDone { color: u32 },
    /// Sweep-end report to the leader.
    Report {
        pending: u64,
        updates: u64,
        accs: Vec<Vec<f64>>,
    },
    /// Leader's sweep decision broadcast.
    Decision {
        cont: bool,
        values: Vec<(String, Vec<f64>)>,
    },
    /// Chandy–Lamport snapshot token (paper Sec. 4.3): everything this
    /// channel carried before it belongs to cut `epoch`.
    Snap { epoch: u64 },
}

/// The chromatic protocol's frame grammar: one discriminant byte, then
/// the variant's fields in declaration order (DESIGN.md §Wire-format).
impl<V: Wire, E: Wire> Wire for Msg<V, E> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Msg::Ghost {
                sweep,
                verts,
                edges,
                tasks,
            } => {
                out.push(0);
                sweep.encode(out);
                verts.encode(out);
                edges.encode(out);
                tasks.encode(out);
            }
            Msg::ColorDone { color } => {
                out.push(1);
                color.encode(out);
            }
            Msg::Report {
                pending,
                updates,
                accs,
            } => {
                out.push(2);
                pending.encode(out);
                updates.encode(out);
                accs.encode(out);
            }
            Msg::Decision { cont, values } => {
                out.push(3);
                cont.encode(out);
                values.encode(out);
            }
            Msg::Snap { epoch } => {
                out.push(4);
                epoch.encode(out);
            }
        }
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => Msg::Ghost {
                sweep: u64::decode(input)?,
                verts: Vec::<(VertexId, u64, V)>::decode(input)?,
                edges: Vec::<(EdgeId, u64, E)>::decode(input)?,
                tasks: Vec::<Task>::decode(input)?,
            },
            1 => Msg::ColorDone {
                color: u32::decode(input)?,
            },
            2 => Msg::Report {
                pending: u64::decode(input)?,
                updates: u64::decode(input)?,
                accs: Vec::<Vec<f64>>::decode(input)?,
            },
            3 => Msg::Decision {
                cont: bool::decode(input)?,
                values: Vec::<(String, Vec<f64>)>::decode(input)?,
            },
            4 => Msg::Snap {
                epoch: u64::decode(input)?,
            },
            tag => {
                return Err(wire::WireError::BadTag {
                    what: "chromatic::Msg",
                    tag,
                })
            }
        })
    }
}

/// Append this machine's full local state (owned + ghost copies) out of
/// the chromatic engine's split stores — the "own half" of a snapshot
/// cut. The caller must be between colors (barrier waits, sweep
/// boundaries), where no update threads are running.
fn record_stores<V: DataValue, E: DataValue>(
    lg: &LocalGraph<V, E>,
    vstore: &SharedStore<V>,
    estore: &SharedStore<E>,
    vversion: &[u64],
    eversion: &[u64],
    verts: &mut Vec<(VertexId, u64, V)>,
    edges: &mut Vec<(EdgeId, u64, E)>,
) {
    verts.reserve(lg.l2g.len());
    for (i, &gv) in lg.l2g.iter().enumerate() {
        // SAFETY: between colors — the pool's workers are parked and
        // ghost applies happen on this thread, so no writers exist.
        verts.push((gv, vversion[i], unsafe { vstore.get(i) }.clone()));
    }
    edges.reserve(lg.le2g.len());
    for (i, &ge) in lg.le2g.iter().enumerate() {
        // SAFETY: as above.
        edges.push((ge, eversion[i], unsafe { estore.get(i) }.clone()));
    }
}

/// Run `program` on `graph` under the chromatic engine.
///
/// `initial` tasks seed the first sweep (priorities are ignored — the
/// chromatic schedule is static, paper Sec. 3.4). Returns the transformed
/// graph and statistics. Misconfiguration (partition not matching the
/// machine count or the graph) is an error, not a panic — it surfaces
/// through the `engine::Engine` builder's `Result`.
pub(crate) fn run<V, E, P>(
    graph: Graph<V, E>,
    coloring: &Coloring,
    partition: &Partition,
    program: &P,
    initial: Vec<Task>,
    syncs: Vec<Box<dyn SyncOp<V>>>,
    opts: ChromaticOpts,
) -> anyhow::Result<(Graph<V, E>, ExecStats)>
where
    V: DataValue,
    E: DataValue,
    P: VertexProgram<V, E>,
{
    if partition.machines() != opts.machines {
        bail!(
            "chromatic engine: partition is for {} machines but the engine runs {}",
            partition.machines(),
            opts.machines
        );
    }
    if partition.num_vertices() != graph.num_vertices() {
        bail!(
            "chromatic engine: partition covers {} vertices but the graph has {}",
            partition.num_vertices(),
            graph.num_vertices()
        );
    }
    if coloring.num_vertices() != graph.num_vertices() {
        bail!(
            "chromatic engine: coloring covers {} vertices but the graph has {}",
            coloring.num_vertices(),
            graph.num_vertices()
        );
    }
    let start = std::time::Instant::now();
    let machines = opts.machines;
    let num_colors = coloring.num_colors().max(1);
    let consistency = program.consistency();

    // Ranks, local graphs (the paper's "merge your atom files" load
    // step, literal when an atom directory is given), mesh, and the
    // topology/fallback split — the shared distributed-engine front half.
    let ClusterSetup {
        locals,
        endpoints,
        stats: net_stats,
        vfallback,
        efallback,
        topo,
    } = cluster_setup::<V, E, Msg<V, E>>(
        graph,
        partition,
        opts.atoms.as_ref(),
        machines,
        opts.network,
        opts.transport,
        opts.cluster.as_ref(),
        opts.fault.as_ref(),
        opts.restore.as_deref(),
    )?;
    let endpoints_ref = &topo.endpoints;
    let snap_cfg = &opts.snapshot;

    let syncs = &syncs;
    let on_sweep = &opts.on_sweep;
    // In a multi-process cluster each follower process must drive its own
    // progress callback off the leader's Decision broadcasts (there is no
    // leader thread in this process to do it).
    let cluster_mode = opts.cluster.is_some();
    let threads_per_machine = opts.threads_per_machine;
    let max_sweeps = opts.max_sweeps;
    let pin_threads = opts.pin_threads;
    // Per-machine update counts (each machine writes its own slot at
    // exit): the ExecStats load-balance vector.
    let updates_by_machine: Mutex<Vec<u64>> = Mutex::new(vec![0; machines]);
    let sweeps_done = std::sync::atomic::AtomicU64::new(0);

    // Each machine returns (global vid, V) for owned vertices and
    // (global eid, E) for canonically-owned edges.
    type MachineOut<V, E> = (Vec<(VertexId, V)>, Vec<(EdgeId, E)>);
    let outputs: Mutex<Vec<Option<MachineOut<V, E>>>> =
        Mutex::new((0..machines).map(|_| None).collect());

    // Machine loops return typed errors (barrier timeouts naming the
    // peer failures that stranded them, snapshot I/O); the first one
    // surfaces through `Engine::run`. Genuine bugs still panic and are
    // re-raised on the caller thread.
    std::thread::scope(|s| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for (lg, mut ep) in locals.into_iter().zip(endpoints) {
            let coloring = &coloring;
            let partition = &partition;
            let initial = &initial;
            let outputs = &outputs;
            let updates_by_machine = &updates_by_machine;
            let sweeps_done = &sweeps_done;
            handles.push(s.spawn(move || -> anyhow::Result<()> {
                let mut lg = lg;
                let me = ep.me();
                if pin_threads {
                    crate::util::affinity::pin_current_thread(
                        me % crate::util::affinity::available_cpus(),
                    );
                }
                let grace = peer_grace(CHROMATIC_GRACE);
                let mut snap: Option<SnapshotSession<V, E>> = snap_cfg
                    .as_ref()
                    .map(|cfg| SnapshotSession::new(cfg, me, machines));
                let owned = lg.owned;
                let vstore = SharedStore::new(std::mem::take(&mut lg.vdata));
                let estore = SharedStore::new(std::mem::take(&mut lg.edata));
                let mut vversion = std::mem::take(&mut lg.vversion);
                let mut eversion = std::mem::take(&mut lg.eversion);
                let lg = lg;
                let globals = GlobalValues::new();
                // One persistent pool per machine for the whole run: the
                // per-color `parallel_for` below reuses parked workers
                // instead of spawning threads every color of every sweep.
                let pool = ThreadPool::new(threads_per_machine.max(1));

                // Owned vertices grouped by color, in global-id order
                // (static deterministic schedule).
                let mut by_color: Vec<Vec<u32>> = vec![Vec::new(); num_colors as usize];
                for lv in 0..owned as u32 {
                    by_color[coloring.color(lg.l2g[lv as usize]) as usize].push(lv);
                }

                let mut task_cur = vec![false; owned];
                let mut task_next = vec![false; owned];
                // Tasks scheduled by peers already in the *next* sweep
                // (they belong to the sweep after task_next).
                let mut task_future = vec![false; owned];
                for t in initial.iter() {
                    if partition.owner(t.vertex) == me {
                        task_cur[lg.g2l[&t.vertex] as usize] = true;
                    }
                }

                let mut my_updates: u64 = 0;
                let mut sweep: u64 = 0;
                // Cumulative ColorDone counts per color. Channels are FIFO
                // per peer but not synchronized across peers, so markers
                // for a *later* color (or the next sweep) may arrive while
                // we still wait on an earlier barrier; cumulative counts
                // absorb that skew (each peer sends exactly one marker per
                // color per sweep).
                let mut color_done = vec![0u64; num_colors as usize];
                let batch_w = program.batch_width().max(1);

                loop {
                    for color in 0..num_colors {
                        // --- execute this color's scheduled owned tasks ---
                        let batch: Vec<u32> = by_color[color as usize]
                            .iter()
                            .copied()
                            .filter(|&lv| task_cur[lv as usize])
                            .collect();
                        for &lv in &batch {
                            task_cur[lv as usize] = false;
                        }
                        // Parallel over chunks; collect per-chunk results.
                        struct ChunkOut {
                            dirty_v: Vec<u32>,
                            dirty_e: Vec<u32>,
                            tasks: Vec<Task>,
                        }
                        let chunk_outs: Mutex<Vec<ChunkOut>> = Mutex::new(Vec::new());
                        let nchunks = batch.len().div_ceil(batch_w);
                        pool.parallel_for(nchunks, 1, |ci| {
                            let chunk = &batch[ci * batch_w..((ci + 1) * batch_w).min(batch.len())];
                            let mut scopes: Vec<Scope<V, E>> = chunk
                                .iter()
                                .map(|&lv| {
                                    let mut sc = Scope::new_buffer(consistency);
                                    // SAFETY: coloring guarantees no two
                                    // concurrently-updated vertices are
                                    // adjacent, so center writes and
                                    // neighbor/edge access never alias
                                    // across threads (property-tested).
                                    unsafe {
                                        sc.reset(
                                            lg.l2g[lv as usize],
                                            vstore.get_mut(lv as usize) as *mut V,
                                        );
                                        let lo = lg.adj_offsets[lv as usize] as usize;
                                        let hi = lg.adj_offsets[lv as usize + 1] as usize;
                                        for &(nlv, nle) in &lg.adj[lo..hi] {
                                            sc.push_neighbor(
                                                lg.l2g[nlv as usize],
                                                lg.le2g[nle as usize],
                                                vstore.get_mut(nlv as usize) as *mut V,
                                                estore.get_mut(nle as usize) as *mut E,
                                            );
                                        }
                                    }
                                    sc
                                })
                                .collect();
                            let mut ctx = Ctx::new(&globals);
                            ctx.set_updates_hint(my_updates);
                            let mut refs: Vec<&mut Scope<V, E>> = scopes.iter_mut().collect();
                            program.update_batch(&mut refs, &mut ctx);
                            let mut out = ChunkOut {
                                dirty_v: Vec::new(),
                                dirty_e: Vec::new(),
                                tasks: std::mem::take(&mut ctx.scheduled),
                            };
                            for (k, sc) in scopes.iter().enumerate() {
                                let lv = chunk[k];
                                if sc.center_dirty() {
                                    out.dirty_v.push(lv);
                                }
                                for (i, &(_, nle)) in lg.neighbors(lv).iter().enumerate() {
                                    if sc.edge_dirty(i) {
                                        out.dirty_e.push(nle);
                                    }
                                }
                            }
                            chunk_outs.lock().unwrap().push(out);
                        });
                        my_updates += batch.len() as u64;

                        // --- build per-peer ghost flushes ---
                        #[allow(clippy::type_complexity)]
                        let mut per_peer: Vec<(
                            Vec<(VertexId, u64, V)>,
                            Vec<(EdgeId, u64, E)>,
                            Vec<Task>,
                        )> = (0..machines).map(|_| Default::default()).collect();
                        for out in chunk_outs.into_inner().unwrap() {
                            for lv in out.dirty_v {
                                vversion[lv as usize] += 1;
                                let gv = lg.l2g[lv as usize];
                                let ver = vversion[lv as usize];
                                for &peer in &lg.mirrors[lv as usize] {
                                    // SAFETY: color finished; no writers.
                                    let val = unsafe { vstore.get(lv as usize) }.clone();
                                    per_peer[peer].0.push((gv, ver, val));
                                }
                            }
                            for le in out.dirty_e {
                                eversion[le as usize] += 1;
                                if let Some(peer) = lg.edge_mirror[le as usize] {
                                    let val = unsafe { estore.get(le as usize) }.clone();
                                    per_peer[peer].1.push((
                                        lg.le2g[le as usize],
                                        eversion[le as usize],
                                        val,
                                    ));
                                }
                            }
                            for t in out.tasks {
                                let owner = partition.owner(t.vertex);
                                if owner == me {
                                    task_next[lg.g2l[&t.vertex] as usize] = true;
                                } else {
                                    per_peer[owner].2.push(t);
                                }
                            }
                        }
                        for (peer, (verts, edges, tasks)) in per_peer.into_iter().enumerate() {
                            if peer == me {
                                continue;
                            }
                            // Ghost flush + barrier marker ride one batched
                            // send: a single pooled buffer, one transport
                            // write per peer per color.
                            let mut batch = Vec::with_capacity(2);
                            if !verts.is_empty() || !edges.is_empty() || !tasks.is_empty() {
                                batch.push(Msg::Ghost { sweep, verts, edges, tasks });
                            }
                            batch.push(Msg::ColorDone { color });
                            ep.send_batch(peer, batch);
                        }

                        // --- barrier: apply peers' data until all done ---
                        let target = (machines as u64 - 1) * (sweep + 1);
                        while color_done[color as usize] < target {
                            let Some(rcv) = ep.recv_timeout(grace) else {
                                // Name the transport failure (decode error,
                                // dead stream) that actually stranded the
                                // barrier, not just the timeout.
                                bail!(
                                    "chromatic: color barrier timeout (machine {me}, sweep {sweep}, color {color}, have {} want {target}, dist {:?}, peer errors: {:?})",
                                    color_done[color as usize], color_done, ep.peer_errors()
                                );
                            };
                            match rcv.msg {
                                Msg::Ghost { sweep: msg_sweep, verts, edges, tasks } => {
                                    // Writes racing `src`'s snapshot token
                                    // are channel state of the cut.
                                    let cut = snap
                                        .as_ref()
                                        .is_some_and(|sx| sx.recording_from(rcv.src));
                                    for (gv, ver, val) in verts {
                                        if cut {
                                            snap.as_mut().unwrap().record_vertex(gv, ver, &val);
                                        }
                                        let lv = lg.g2l[&gv] as usize;
                                        debug_assert!(ver > vversion[lv]);
                                        vversion[lv] = ver;
                                        // SAFETY: ghosts are not written by
                                        // local updates; applying between
                                        // colors is race-free.
                                        unsafe { *vstore.get_mut(lv) = val };
                                    }
                                    for (ge, ver, val) in edges {
                                        if cut {
                                            snap.as_mut().unwrap().record_edge(ge, ver, &val);
                                        }
                                        let le = lg.ge2l[&ge] as usize;
                                        debug_assert!(ver > eversion[le]);
                                        eversion[le] = ver;
                                        unsafe { *estore.get_mut(le) = val };
                                    }
                                    let bucket = if msg_sweep == sweep {
                                        &mut task_next
                                    } else {
                                        debug_assert_eq!(msg_sweep, sweep + 1);
                                        &mut task_future
                                    };
                                    for t in tasks {
                                        bucket[lg.g2l[&t.vertex] as usize] = true;
                                    }
                                }
                                Msg::ColorDone { color: c } => {
                                    color_done[c as usize] += 1;
                                }
                                Msg::Snap { epoch } => {
                                    if let Some(sess) = snap.as_mut() {
                                        if sess.on_token(rcv.src, epoch, |vs, es| {
                                            record_stores(
                                                &lg, &vstore, &estore, &vversion, &eversion,
                                                vs, es,
                                            )
                                        })? {
                                            for peer in (0..machines).filter(|&p| p != me) {
                                                ep.send(peer, Msg::Snap { epoch });
                                            }
                                        }
                                    }
                                }
                                _ => panic!("unexpected message in color barrier"),
                            }
                        }
                    }

                    // --- sweep boundary: sync reduction + decision ---
                    let pending = task_next.iter().filter(|&&b| b).count() as u64;
                    let accs: Vec<Vec<f64>> = syncs
                        .iter()
                        .map(|op| {
                            let mut acc = op.init();
                            for lv in 0..owned {
                                // SAFETY: between colors; no writers.
                                op.fold(&mut acc, lg.l2g[lv], unsafe { vstore.get(lv) });
                            }
                            acc
                        })
                        .collect();
                    ep.send(
                        0,
                        Msg::Report {
                            pending,
                            updates: my_updates,
                            accs,
                        },
                    );

                    let cont = if me == 0 {
                        // Leader: gather reports, merge, decide, broadcast.
                        let mut merged: Vec<Vec<f64>> =
                            syncs.iter().map(|op| op.init()).collect();
                        let mut total_pending = 0u64;
                        let mut updates_sum = 0u64;
                        let mut got = 0;
                        while got < machines {
                            let Some(rcv) = ep.recv_timeout(grace) else {
                                bail!(
                                    "chromatic: sweep barrier timeout (machine {me}, sweep {sweep}, peer errors: {:?})",
                                    ep.peer_errors()
                                );
                            };
                            match rcv.msg {
                                Msg::Report {
                                    pending,
                                    updates,
                                    accs,
                                } => {
                                    total_pending += pending;
                                    updates_sum += updates;
                                    for (op_i, a) in accs.into_iter().enumerate() {
                                        syncs[op_i].merge(&mut merged[op_i], &a);
                                    }
                                    got += 1;
                                }
                                // Peers echo the leader's own token back.
                                Msg::Snap { epoch } => {
                                    if let Some(sess) = snap.as_mut() {
                                        if sess.on_token(rcv.src, epoch, |vs, es| {
                                            record_stores(
                                                &lg, &vstore, &estore, &vversion, &eversion,
                                                vs, es,
                                            )
                                        })? {
                                            for peer in (0..machines).filter(|&p| p != me) {
                                                ep.send(peer, Msg::Snap { epoch });
                                            }
                                        }
                                    }
                                }
                                _ => panic!("unexpected message at sweep barrier"),
                            }
                        }
                        let values: Vec<(String, Vec<f64>)> = syncs
                            .iter()
                            .zip(merged)
                            .map(|(op, acc)| (op.key().to_string(), op.finalize(acc)))
                            .collect();
                        sweep += 1;
                        let cont = total_pending > 0 && sweep < max_sweeps;
                        sweeps_done.store(sweep, std::sync::atomic::Ordering::Relaxed);
                        for (k, v) in &values {
                            globals.set(k, v.clone());
                        }
                        if let Some(cb) = on_sweep {
                            cb(sweep, updates_sum, &globals);
                        }
                        for peer in 1..machines {
                            ep.send(
                                peer,
                                Msg::Decision {
                                    cont,
                                    values: values.clone(),
                                },
                            );
                        }
                        // Cut a snapshot at the sweep boundary when due:
                        // record local state first, then a token on every
                        // channel (the Chandy–Lamport marker order — FIFO
                        // channels put everything sent before the token
                        // inside the cut). The leader counts the *global*
                        // update total reported this sweep.
                        if cont {
                            if let Some(sess) = snap.as_mut() {
                                if sess.due(updates_sum) {
                                    let epoch = sess.begin(updates_sum, |vs, es| {
                                        record_stores(
                                            &lg, &vstore, &estore, &vversion, &eversion, vs, es,
                                        )
                                    })?;
                                    for peer in 1..machines {
                                        ep.send(peer, Msg::Snap { epoch });
                                    }
                                }
                            }
                        }
                        cont
                    } else {
                        // Follower: wait for the decision.
                        loop {
                            let Some(rcv) = ep.recv_timeout(grace) else {
                                bail!(
                                    "chromatic: decision timeout (machine {me}, sweep {sweep}, dist {color_done:?}, peer errors: {:?})",
                                    ep.peer_errors()
                                );
                            };
                            match rcv.msg {
                                Msg::Decision { cont, values } => {
                                    for (k, v) in values {
                                        globals.set(&k, v);
                                    }
                                    sweep += 1;
                                    // In cluster mode this follower is the
                                    // only machine in its process, so it
                                    // owns the progress callback (updates
                                    // count is local, like its stats).
                                    if cluster_mode {
                                        if let Some(cb) = on_sweep {
                                            cb(sweep, my_updates, &globals);
                                        }
                                    }
                                    break cont;
                                }
                                // Fast peers may already be into the next
                                // sweep: absorb their traffic here.
                                Msg::Ghost { sweep: msg_sweep, verts, edges, tasks } => {
                                    let cut = snap
                                        .as_ref()
                                        .is_some_and(|sx| sx.recording_from(rcv.src));
                                    for (gv, ver, val) in verts {
                                        if cut {
                                            snap.as_mut().unwrap().record_vertex(gv, ver, &val);
                                        }
                                        let lv = lg.g2l[&gv] as usize;
                                        vversion[lv] = ver;
                                        // SAFETY: no updates execute while
                                        // awaiting the decision.
                                        unsafe { *vstore.get_mut(lv) = val };
                                    }
                                    for (ge, ver, val) in edges {
                                        if cut {
                                            snap.as_mut().unwrap().record_edge(ge, ver, &val);
                                        }
                                        let le = lg.ge2l[&ge] as usize;
                                        eversion[le] = ver;
                                        unsafe { *estore.get_mut(le) = val };
                                    }
                                    let bucket = if msg_sweep == sweep {
                                        &mut task_next
                                    } else {
                                        debug_assert_eq!(msg_sweep, sweep + 1);
                                        &mut task_future
                                    };
                                    for t in tasks {
                                        bucket[lg.g2l[&t.vertex] as usize] = true;
                                    }
                                }
                                Msg::ColorDone { color: c } => {
                                    color_done[c as usize] += 1;
                                }
                                Msg::Snap { epoch } => {
                                    if let Some(sess) = snap.as_mut() {
                                        if sess.on_token(rcv.src, epoch, |vs, es| {
                                            record_stores(
                                                &lg, &vstore, &estore, &vversion, &eversion,
                                                vs, es,
                                            )
                                        })? {
                                            for peer in (0..machines).filter(|&p| p != me) {
                                                ep.send(peer, Msg::Snap { epoch });
                                            }
                                        }
                                    }
                                }
                                _ => panic!("unexpected message awaiting decision"),
                            }
                        }
                    };

                    if !cont {
                        break;
                    }
                    std::mem::swap(&mut task_cur, &mut task_next);
                    for (nb, fb) in task_next.iter_mut().zip(task_future.iter_mut()) {
                        // Future-sweep tasks become next-sweep tasks now.
                        *nb = *fb;
                        *fb = false;
                    }
                }

                // Every machine records how many sweeps it saw (the
                // leader also stores per sweep): in cluster mode a
                // follower process is the only local machine, and its
                // count is the one reported.
                sweeps_done.fetch_max(sweep, std::sync::atomic::Ordering::Relaxed);

                // Return owned vertex data + canonically-owned edge data.
                let vdata = vstore.into_vec();
                let edata = estore.into_vec();
                let verts: Vec<(VertexId, V)> = (0..owned)
                    .map(|lv| (lg.l2g[lv], vdata[lv].clone()))
                    .collect();
                let edges: Vec<(EdgeId, E)> = lg
                    .le2g
                    .iter()
                    .enumerate()
                    .filter(|&(le, _)| {
                        // Canonical owner: owner of the min endpoint.
                        let ge = lg.le2g[le];
                        let (a, b) = endpoints_ref[ge as usize];
                        partition.owner(a.min(b)) == me
                    })
                    .map(|(le, &ge)| (ge, edata[le].clone()))
                    .collect();
                updates_by_machine.lock().unwrap()[me] = my_updates;
                outputs.lock().unwrap()[me] = Some((verts, edges));
                Ok(())
            }));
        }
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    // Reassemble the global graph from machine outputs. In-process runs
    // must cover every slot (an uncovered one is a partition/ownership
    // bug, kept as a loud invariant); in cluster mode only this process's
    // machine reported, so the rest keep the input data (the
    // authoritative copies live in the other worker processes).
    let mut vdata_opt: Vec<Option<V>> = (0..topo.adj_offsets.len() - 1).map(|_| None).collect();
    let mut edata_opt: Vec<Option<E>> = (0..topo.endpoints.len()).map(|_| None).collect();
    for out in outputs.into_inner().unwrap().into_iter().flatten() {
        for (v, d) in out.0 {
            vdata_opt[v as usize] = Some(d);
        }
        for (e, d) in out.1 {
            edata_opt[e as usize] = Some(d);
        }
    }
    let vdata = crate::distributed::reassemble(vdata_opt, vfallback, "vertex");
    let edata = crate::distributed::reassemble(edata_opt, efallback, "edge");
    let graph = Graph::from_parts(vdata, edata, topo);

    let updates_per_machine = updates_by_machine.into_inner().unwrap();
    let stats = ExecStats {
        updates: updates_per_machine.iter().sum(),
        sweeps: sweeps_done.load(std::sync::atomic::Ordering::Relaxed),
        seconds: start.elapsed().as_secs_f64(),
        updates_per_machine,
        bytes_sent: net_stats
            .iter()
            .map(|s| s.bytes_sent.load(std::sync::atomic::Ordering::Relaxed))
            .collect(),
        msgs_sent: net_stats
            .iter()
            .map(|s| s.msgs_sent.load(std::sync::atomic::Ordering::Relaxed))
            .collect(),
    };
    Ok((graph, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Round-trip by re-encoding (Msg derives no PartialEq), plus prefix
    /// totality: truncated frames are errors, never panics.
    fn round_trip(msg: Msg<f32, u64>) {
        let bytes = wire::to_bytes(&msg);
        let back: Msg<f32, u64> = wire::from_bytes(&bytes).unwrap();
        assert_eq!(wire::to_bytes(&back), bytes);
        for cut in 0..bytes.len() {
            assert!(wire::from_bytes::<Msg<f32, u64>>(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn every_chromatic_frame_variant_round_trips() {
        round_trip(Msg::Ghost {
            sweep: 2,
            verts: vec![(1, 3, 0.5), (2, 1, -1.5)],
            edges: vec![(0, 1, 42)],
            tasks: vec![Task { vertex: 7, priority: 1.0 }],
        });
        round_trip(Msg::ColorDone { color: 5 });
        round_trip(Msg::Report {
            pending: 9,
            updates: 100,
            accs: vec![vec![1.0], vec![2.0, 3.0]],
        });
        round_trip(Msg::Decision {
            cont: true,
            values: vec![("total_rank".to_string(), vec![1.0])],
        });
        round_trip(Msg::Snap { epoch: 3 });
    }

    #[test]
    fn unknown_discriminant_is_an_error() {
        assert!(wire::from_bytes::<Msg<f32, u64>>(&[9]).is_err());
    }
}
