//! The **Locking engine** (paper Sec. 4.2.2).
//!
//! Each machine runs an event loop over its owned partition: worker
//! transactions pull tasks from a local scheduler (FIFO / priority /
//! multiqueue), acquire the distributed reader–writer locks demanded by
//! the consistency model — *in ascending global vertex order*, which makes
//! the protocol deadlock-free — evaluate the update, push modified data to
//! the authoritative owners, release, and repeat.
//!
//! The paper's latency-hiding techniques are reproduced:
//!
//! * **ghost caching with versioning** — lock grants piggyback vertex/edge
//!   data only when the requester's cached version is stale;
//! * **lock pipelining** — up to `maxpending` transactions progress their
//!   lock chains concurrently (Fig. 8(b) sweeps this knob);
//! * **ready-batch execution** — granted transactions are executed through
//!   `VertexProgram::update_batch`, letting PJRT-backed programs amortize
//!   compiled-kernel invocations;
//! * **parallel update evaluation** — with `--threads N` (N > 1) each
//!   machine pairs its pump thread with a pool of N executor threads:
//!   granted batches are snapshotted at dispatch and evaluated off the
//!   pump, which keeps sole ownership of sockets, locks, ghost pushes,
//!   and termination accounting (the paper's headline deployment runs
//!   8 cores per node — Fig. 7). See DESIGN.md §"Execution off the pump
//!   thread" for the snapshot safety argument.
//!
//! Termination uses the Safra/Misra token ring
//! ([`crate::distributed::termination`]); sync operations run under a
//! leader-coordinated global
//! barrier (machines drain in-flight transactions, fold their partition,
//! and resume after the leader broadcasts the merged result).
//!
//! The `Consistency::Unsafe` mode (for the paper's Fig. 1) skips locking
//! entirely and propagates dirty data to ghost holders eagerly —
//! "inconsistent asynchronous iterations".

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::bail;

use super::{Consistency, Ctx, ExecStats, GlobalValues, Scope, SyncOp, VertexProgram};
use crate::distributed::locks::{LockReq, LockTable, TxnId};
use crate::distributed::network::NetworkModel;
use crate::distributed::snapshot::{record_from_graph, SnapshotCfg, SnapshotSession};
use crate::distributed::termination::{Termination, Token, TokenAction};
use crate::distributed::transport::{
    peer_grace, ClusterConfig, FaultPlan, TransportKind, LOCKING_GRACE,
};
use crate::distributed::{cluster_setup, ClusterSetup, DataValue, LocalGraph};
use crate::graph::{EdgeId, Graph, VertexId};
use crate::partition::atoms::AtomPlacement;
use crate::partition::{MachineId, Partition};
use crate::scheduler::{self, Policy, Task};
use crate::util::threadpool::DispatchQueue;
use crate::wire::{self, Wire};

/// Options for a locking-engine run (crate-internal: external callers go
/// through the `engine::Engine` builder).
pub(crate) struct LockingOpts {
    /// Machine count.
    pub machines: usize,
    /// Maximum transactions in flight per machine (lock pipelining depth;
    /// 0 means 1 — a fully serial pipeline, the paper's baseline).
    pub maxpending: usize,
    /// Update-executor threads per machine (the paper runs 8 cores per
    /// node). 1 (or 0) evaluates granted batches inline on the pump
    /// thread — the bit-deterministic sequential oracle; N > 1 spawns N
    /// pool workers per machine and the pump thread only pumps the
    /// protocol.
    pub threads: usize,
    /// Scheduler policy (parsed at the CLI boundary via
    /// [`Policy::parse`], so unknown names fail with an error up front).
    pub scheduler: Policy,
    /// Network model (latency injection for Fig. 8(b); InProc only).
    pub network: NetworkModel,
    /// Which byte-level substrate carries the frames (ignored when
    /// `cluster` is set — a multi-process cluster is always TCP).
    pub transport: TransportKind,
    /// Multi-process mode: run **only** machine `cluster.me` in this
    /// process, over TCP to the other worker processes.
    pub cluster: Option<ClusterConfig>,
    /// Period of leader-initiated global sync barriers (None = only at
    /// termination). The paper's tau is counted in updates; a wall-clock
    /// period is allowed by its footnote 2 ("the resolution of the
    /// synchronization interval is left up to the implementation").
    pub sync_period: Option<Duration>,
    /// Stop after approximately this many updates per machine.
    pub max_updates_per_machine: u64,
    /// Leader callback at each sync barrier: (epoch, total updates seen).
    #[allow(clippy::type_complexity)]
    pub on_sync: Option<Box<dyn Fn(u64, u64, &GlobalValues) + Send + Sync>>,
    /// Seed for the multiqueue scheduler.
    pub seed: u64,
    /// When set, each machine replays its own on-disk atom journals
    /// instead of slicing the in-memory graph (the paper's load path).
    pub atoms: Option<AtomPlacement>,
    /// When set, the leader cuts Chandy–Lamport snapshots (paper Sec.
    /// 4.3). An update-count trigger fires on the *leader's* local
    /// counter — the engine is asynchronous, so the global total is only
    /// known at sync barriers; the period is approximate (about
    /// `machines ×` the configured count cluster-wide on a balanced
    /// partition).
    pub snapshot: Option<SnapshotCfg>,
    /// Overlay the newest complete snapshot under this directory onto
    /// the freshly-loaded local graphs before running (recovery path).
    pub restore: Option<PathBuf>,
    /// Deterministic fault injection: wrap every transport in a
    /// [`crate::distributed::Faulty`] decorator.
    pub fault: Option<FaultPlan>,
    /// Pin each machine loop to a CPU (`me % available_cpus`) so the OS
    /// scheduler stops migrating engine threads mid-run. Best-effort.
    pub pin_threads: bool,
}

impl Default for LockingOpts {
    fn default() -> Self {
        LockingOpts {
            machines: 2,
            maxpending: 64,
            threads: 1,
            scheduler: Policy::Fifo,
            network: NetworkModel::default(),
            transport: TransportKind::InProc,
            cluster: None,
            sync_period: None,
            max_updates_per_machine: u64::MAX,
            on_sync: None,
            seed: 0,
            atoms: None,
            snapshot: None,
            restore: None,
            fault: None,
            pin_threads: false,
        }
    }
}

enum Msg<V, E> {
    LockReq {
        txn: TxnId,
        vertex: VertexId,
        write: bool,
        /// Requester's cached version of the vertex data.
        vver: u64,
        /// Edge between requester's center and `vertex`, with cached
        /// version, when this owner is the edge's canonical home.
        edge: Option<(EdgeId, u64)>,
    },
    Grant {
        txn_seq: u64,
        vertex: VertexId,
        vdata: Option<(u64, V)>,
        edata: Option<(EdgeId, u64, E)>,
    },
    Release {
        txn: TxnId,
        unlocks: Vec<(VertexId, bool)>,
        vwrites: Vec<(VertexId, u64, V)>,
        ewrites: Vec<(EdgeId, u64, E)>,
        tasks: Vec<Task>,
    },
    /// Eager dirty-data push (Unsafe mode only — no locks, races allowed).
    GhostPush {
        verts: Vec<(VertexId, u64, V)>,
        edges: Vec<(EdgeId, u64, E)>,
    },
    SyncBegin {
        epoch: u64,
    },
    SyncPartial {
        epoch: u64,
        accs: Vec<Vec<f64>>,
        updates: u64,
        capped: bool,
    },
    SyncEnd {
        epoch: u64,
        values: Vec<(String, Vec<f64>)>,
    },
    Token(Token),
    Halt,
    FinalReport {
        accs: Vec<Vec<f64>>,
        updates: u64,
    },
    /// Chandy–Lamport snapshot token (paper Sec. 4.3): everything this
    /// channel carried before it belongs to cut `epoch`.
    Snap {
        epoch: u64,
    },
}

/// The locking protocol's frame grammar: one discriminant byte, then the
/// variant's fields in declaration order (see DESIGN.md §Wire-format).
impl<V: Wire, E: Wire> Wire for Msg<V, E> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Msg::LockReq {
                txn,
                vertex,
                write,
                vver,
                edge,
            } => {
                out.push(0);
                txn.encode(out);
                vertex.encode(out);
                write.encode(out);
                vver.encode(out);
                edge.encode(out);
            }
            Msg::Grant {
                txn_seq,
                vertex,
                vdata,
                edata,
            } => {
                out.push(1);
                txn_seq.encode(out);
                vertex.encode(out);
                vdata.encode(out);
                edata.encode(out);
            }
            Msg::Release {
                txn,
                unlocks,
                vwrites,
                ewrites,
                tasks,
            } => {
                out.push(2);
                txn.encode(out);
                unlocks.encode(out);
                vwrites.encode(out);
                ewrites.encode(out);
                tasks.encode(out);
            }
            Msg::GhostPush { verts, edges } => {
                out.push(3);
                verts.encode(out);
                edges.encode(out);
            }
            Msg::SyncBegin { epoch } => {
                out.push(4);
                epoch.encode(out);
            }
            Msg::SyncPartial {
                epoch,
                accs,
                updates,
                capped,
            } => {
                out.push(5);
                epoch.encode(out);
                accs.encode(out);
                updates.encode(out);
                capped.encode(out);
            }
            Msg::SyncEnd { epoch, values } => {
                out.push(6);
                epoch.encode(out);
                values.encode(out);
            }
            Msg::Token(tok) => {
                out.push(7);
                tok.encode(out);
            }
            Msg::Halt => out.push(8),
            Msg::FinalReport { accs, updates } => {
                out.push(9);
                accs.encode(out);
                updates.encode(out);
            }
            Msg::Snap { epoch } => {
                out.push(10);
                epoch.encode(out);
            }
        }
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => Msg::LockReq {
                txn: TxnId::decode(input)?,
                vertex: VertexId::decode(input)?,
                write: bool::decode(input)?,
                vver: u64::decode(input)?,
                edge: Option::<(EdgeId, u64)>::decode(input)?,
            },
            1 => Msg::Grant {
                txn_seq: u64::decode(input)?,
                vertex: VertexId::decode(input)?,
                vdata: Option::<(u64, V)>::decode(input)?,
                edata: Option::<(EdgeId, u64, E)>::decode(input)?,
            },
            2 => Msg::Release {
                txn: TxnId::decode(input)?,
                unlocks: Vec::<(VertexId, bool)>::decode(input)?,
                vwrites: Vec::<(VertexId, u64, V)>::decode(input)?,
                ewrites: Vec::<(EdgeId, u64, E)>::decode(input)?,
                tasks: Vec::<Task>::decode(input)?,
            },
            3 => Msg::GhostPush {
                verts: Vec::<(VertexId, u64, V)>::decode(input)?,
                edges: Vec::<(EdgeId, u64, E)>::decode(input)?,
            },
            4 => Msg::SyncBegin {
                epoch: u64::decode(input)?,
            },
            5 => Msg::SyncPartial {
                epoch: u64::decode(input)?,
                accs: Vec::<Vec<f64>>::decode(input)?,
                updates: u64::decode(input)?,
                capped: bool::decode(input)?,
            },
            6 => Msg::SyncEnd {
                epoch: u64::decode(input)?,
                values: Vec::<(String, Vec<f64>)>::decode(input)?,
            },
            7 => Msg::Token(Token::decode(input)?),
            8 => Msg::Halt,
            9 => Msg::FinalReport {
                accs: Vec::<Vec<f64>>::decode(input)?,
                updates: u64::decode(input)?,
            },
            10 => Msg::Snap {
                epoch: u64::decode(input)?,
            },
            tag => {
                return Err(wire::WireError::BadTag {
                    what: "locking::Msg",
                    tag,
                })
            }
        })
    }
}

/// Metadata for queued remote lock requests, keyed by (txn, vertex):
/// (requester's cached vertex version, edge id + cached edge version when
/// this owner is the edge's canonical home).
type ReqMeta = HashMap<(TxnId, VertexId), (u64, Option<(EdgeId, u64)>)>;

/// One in-flight transaction (a scope acquisition chain).
struct Txn {
    seq: u64,
    center_lv: u32,
    /// (global vertex, write) in ascending vertex order.
    plan: Vec<(VertexId, bool)>,
    /// Next plan index to request.
    next: usize,
}

/// One scope slot of a dispatched transaction: the neighbor's ids plus
/// *owned copies* of its vertex and edge data, snapshotted at dispatch.
/// Slot order mirrors `lg.adj[center]`, so dirty flags index identically
/// on both the inline and the pool path.
struct JobNbr<V, E> {
    ng: VertexId,
    ge: EdgeId,
    vdata: V,
    edata: E,
}

/// A fully-granted transaction packaged for an executor thread. Workers
/// build their `Scope` over these owned buffers, never over `lg` — the
/// pump keeps exclusive ownership of the local graph. Snapshotting at
/// dispatch is equivalent to snapshotting at grant time: every slot in
/// the plan is still locked between the final grant and the dispatch, so
/// no writer (local or remote) can touch the data in between.
struct TxnJob<V, E> {
    seq: u64,
    center_lv: u32,
    plan: Vec<(VertexId, bool)>,
    center_g: VertexId,
    center: V,
    nbrs: Vec<JobNbr<V, E>>,
}

/// Which scope slots an update dirtied (indices follow `lg.adj[center]`).
struct TxnFlags {
    center_dirty: bool,
    nbr_dirty: Vec<bool>,
    edge_dirty: Vec<bool>,
}

/// What an executor thread hands back to the pump: the jobs (now holding
/// the *mutated* snapshots) with their dirty flags, plus every task the
/// batch scheduled. The pump alone turns this into version bumps, sends,
/// ghost pushes, lock releases, and termination accounting.
struct Completion<V, E> {
    txns: Vec<(TxnJob<V, E>, TxnFlags)>,
    tasks: Vec<Task>,
}

/// Marker sent instead of a [`Completion`] when an update function
/// panicked on an executor thread; the pump re-raises it loudly (locks
/// held by the dead batch can never be released — continuing would hang
/// the cluster).
struct PoolPanic;

/// A job queued to the per-machine executor pool: the captured batch and
/// the pump's update counter at dispatch (the batch's `updates_hint`).
type ExecJob<V, E> = (Vec<TxnJob<V, E>>, u64);

/// An executed transaction as seen by the shared write-back path: both
/// the inline path (flags read off live scopes) and the pool path (flags
/// shipped back in the [`Completion`]) reduce to this.
struct TxnDone {
    seq: u64,
    center_lv: u32,
    plan: Vec<(VertexId, bool)>,
    flags: TxnFlags,
}

/// Run `program` under the distributed locking engine. Misconfiguration
/// (partition not matching the machine count or the graph) is an error,
/// not a panic — it surfaces through the `engine::Engine` builder's
/// `Result`.
pub(crate) fn run<V, E, P>(
    graph: Graph<V, E>,
    partition: &Partition,
    program: &P,
    initial: Vec<Task>,
    syncs: Vec<Box<dyn SyncOp<V>>>,
    opts: LockingOpts,
) -> anyhow::Result<(Graph<V, E>, ExecStats)>
where
    V: DataValue,
    E: DataValue,
    P: VertexProgram<V, E>,
{
    if partition.machines() != opts.machines {
        bail!(
            "locking engine: partition is for {} machines but the engine runs {}",
            partition.machines(),
            opts.machines
        );
    }
    if partition.num_vertices() != graph.num_vertices() {
        bail!(
            "locking engine: partition covers {} vertices but the graph has {}",
            partition.num_vertices(),
            graph.num_vertices()
        );
    }
    let start = Instant::now();
    let machines = opts.machines;
    let consistency = program.consistency();
    let n_global = graph.num_vertices();

    // Ranks, local graphs (the paper's load step: merge your atom files,
    // or slice the in-memory graph), mesh, and the topology/fallback
    // split — the shared distributed-engine front half.
    let ClusterSetup {
        locals,
        endpoints,
        stats: net_stats,
        vfallback,
        efallback,
        topo,
    } = cluster_setup::<V, E, Msg<V, E>>(
        graph,
        partition,
        opts.atoms.as_ref(),
        machines,
        opts.network,
        opts.transport,
        opts.cluster.as_ref(),
        opts.fault.as_ref(),
        opts.restore.as_deref(),
    )?;
    let endpoints_ref = &topo.endpoints;
    let snap_cfg = &opts.snapshot;

    let syncs = &syncs;
    let on_sync = &opts.on_sync;
    // In a multi-process cluster each non-leader process must drive its
    // own progress callback off machine 0's SyncEnd broadcasts (there is
    // no leader thread in this process to do it).
    let cluster_mode = opts.cluster.is_some();
    let maxpending = opts.maxpending.max(1);
    let sched_policy = opts.scheduler;
    let sync_period = opts.sync_period;
    let cap = opts.max_updates_per_machine;
    let seed = opts.seed;
    let pin_threads = opts.pin_threads;
    let threads = opts.threads.max(1);

    // Per-machine update counts (each machine writes its own slot at
    // exit): the ExecStats load-balance vector.
    let updates_by_machine: std::sync::Mutex<Vec<u64>> =
        std::sync::Mutex::new(vec![0; machines]);
    let epochs = std::sync::atomic::AtomicU64::new(0);
    type MachineOut<V, E> = (Vec<(VertexId, V)>, Vec<(EdgeId, E)>);
    let outputs: std::sync::Mutex<Vec<Option<MachineOut<V, E>>>> =
        std::sync::Mutex::new((0..machines).map(|_| None).collect());

    // Machine loops return typed errors (peer-failure grace aborts,
    // snapshot I/O); the first one surfaces through `Engine::run`.
    // Genuine bugs still panic and are re-raised on the caller thread.
    std::thread::scope(|s| -> anyhow::Result<()> {
        let mut handles = Vec::new();
        for (mut lg, mut ep) in locals.into_iter().zip(endpoints) {
            let partition = &partition;
            let initial = &initial;
            let outputs = &outputs;
            let updates_by_machine = &updates_by_machine;
            let epochs = &epochs;
            let me = ep.me();
            // Per-machine executor pool plumbing. The globals live in an
            // Arc because executor threads read them (`Ctx::global`)
            // while the pump writes sync results; GlobalValues is
            // internally locked. With threads == 1 the queue and channel
            // exist but stay unused — granted batches run inline.
            let globals = std::sync::Arc::new(GlobalValues::new());
            let jobs_q: std::sync::Arc<DispatchQueue<ExecJob<V, E>>> =
                std::sync::Arc::new(DispatchQueue::new());
            let (done_tx, done_rx) =
                std::sync::mpsc::channel::<Result<Completion<V, E>, PoolPanic>>();
            if threads > 1 {
                for w in 0..threads {
                    let jobs_q = jobs_q.clone();
                    let done_tx = done_tx.clone();
                    let globals = globals.clone();
                    std::thread::Builder::new()
                        .name(format!("graphlab-lockexec-{me}-{w}"))
                        .spawn_scoped(s, move || {
                            if pin_threads {
                                // Executors land after every machine's
                                // pump slot so pumps keep their cores.
                                crate::util::affinity::pin_current_thread(
                                    (me + machines * (w + 1))
                                        % crate::util::affinity::available_cpus(),
                                );
                            }
                            executor_loop(&jobs_q, &done_tx, program, consistency, &globals);
                        })
                        .expect("spawn locking executor");
                }
            }
            // The pump holds no sender: once it exits (closing the
            // queue via the guard below) and the executors drain, the
            // channel fully disconnects instead of leaking a sender.
            drop(done_tx);
            handles.push(s.spawn(move || -> anyhow::Result<()> {
                // Close the job queue on *every* exit path (including
                // unwinds): executors parked in `pop` would otherwise
                // deadlock the thread scope's implicit join.
                let _close = jobs_q.close_guard();
                if pin_threads {
                    crate::util::affinity::pin_current_thread(
                        me % crate::util::affinity::available_cpus(),
                    );
                }
                let owned = lg.owned;
                let grace = peer_grace(LOCKING_GRACE);
                // The pump sends many small protocol frames per iteration
                // (grants, releases, ghost pushes): coalesce them per peer
                // and flush once per iteration — see the flush below.
                ep.set_autobatch(true);
                let mut snap: Option<SnapshotSession<V, E>> = snap_cfg
                    .as_ref()
                    .map(|cfg| SnapshotSession::new(cfg, me, machines));
                let mut sched = sched_policy.build(n_global, seed ^ me as u64);
                for t in initial.iter() {
                    if partition.owner(t.vertex) == me {
                        sched.push(*t);
                    }
                }

                let mut locks = LockTable::new();
                let mut req_meta: ReqMeta = HashMap::new();
                let mut pipeline: HashMap<u64, Txn> = HashMap::new();
                let mut ready: Vec<Txn> = Vec::new();
                let mut next_seq: u64 = 0;
                let mut my_updates: u64 = 0;
                let mut term = Termination::new(me, machines);
                let mut held_token: Option<Token> = None;
                let mut halted = false;
                // Sync barrier state.
                let mut syncing = false;
                let mut sync_epoch = 0u64;
                let mut sync_partial_sent = false;
                let mut last_sync = Instant::now();
                let mut last_token = Instant::now() - Duration::from_secs(1);
                // Leader sync gathering.
                let mut gather: Vec<Vec<f64>> = Vec::new();
                let mut gather_updates = 0u64;
                let mut gather_capped = true;
                let mut gather_count = 0usize;
                // Leader: FinalReports that arrive while the main loop is
                // still draining (consumed here, credited in the final
                // gather after the loop).
                let mut final_accs: Vec<Vec<f64>> = Vec::new();
                let mut final_updates_in = 0u64;
                let mut final_got = 0usize;
                let batch_w = program.batch_width().max(1);

                // ---------------------------------------------------------
                // helpers as closures over machine state are impossible
                // (borrow rules), so the loop below is written imperatively
                // with small inline blocks.
                // ---------------------------------------------------------

                // Transactions dispatched to the executor pool whose
                // completions have not yet been committed. They still
                // hold their locks, so every drain / idle / admission
                // condition must count them alongside pipeline + ready.
                let mut inflight: usize = 0;
                // Events pulled in by the blocking idle wait below, to be
                // consumed at the top of the next iteration.
                let mut pending_msg: Option<crate::distributed::network::Received<Msg<V, E>>> =
                    None;
                let mut pending_done: Option<Result<Completion<V, E>, PoolPanic>> = None;
                // Peer failures seen while idle; the run aborts once any
                // have been pending for longer than the grace window.
                let mut pending_peer_failure: Vec<crate::distributed::transport::PeerError> =
                    Vec::new();
                let mut peer_failure_since: Option<Instant> = None;
                'main: loop {
                    let mut progressed = false;

                    // ---- 1. drain incoming messages -----------------------
                    while let Some(rcv) = pending_msg.take().or_else(|| ep.try_recv()) {
                        progressed = true;
                        match rcv.msg {
                            Msg::LockReq {
                                txn,
                                vertex,
                                write,
                                vver,
                                edge,
                            } => {
                                let granted = locks.request(LockReq { txn, vertex, write });
                                if granted {
                                    send_grant(
                                        &ep, &lg, txn, vertex, vver, edge,
                                    );
                                } else {
                                    req_meta.insert((txn, vertex), (vver, edge));
                                }
                            }
                            Msg::Grant {
                                txn_seq,
                                vertex,
                                vdata,
                                edata,
                            } => {
                                // Writes racing `src`'s snapshot token are
                                // channel state of the active cut.
                                let cut = snap
                                    .as_ref()
                                    .is_some_and(|sx| sx.recording_from(rcv.src));
                                // Apply piggybacked data only if strictly
                                // newer: with pipelined requests the owner
                                // may grant from a snapshot that predates a
                                // Release still in flight from *this*
                                // machine, in which case our local copy
                                // (written under the write lock) is the
                                // fresher one.
                                if let Some((ver, val)) = vdata {
                                    if cut {
                                        snap.as_mut().unwrap().record_vertex(vertex, ver, &val);
                                    }
                                    let lv = lg.g2l[&vertex] as usize;
                                    if ver > lg.vversion[lv] {
                                        lg.vdata[lv] = val;
                                        lg.vversion[lv] = ver;
                                    }
                                }
                                if let Some((ge, ver, val)) = edata {
                                    if cut {
                                        snap.as_mut().unwrap().record_edge(ge, ver, &val);
                                    }
                                    let le = lg.ge2l[&ge] as usize;
                                    if ver > lg.eversion[le] {
                                        lg.edata[le] = val;
                                        lg.eversion[le] = ver;
                                    }
                                }
                                let txn = pipeline
                                    .get_mut(&txn_seq)
                                    .expect("grant for unknown txn");
                                debug_assert_eq!(txn.plan[txn.next].0, vertex);
                                txn.next += 1;
                                pump_txn(
                                    &mut pipeline,
                                    txn_seq,
                                    &mut locks,
                                    &mut req_meta,
                                    &ep,
                                    &lg,
                                    partition,
                                    me,
                                    &mut ready,
                                );
                            }
                            Msg::Release {
                                txn,
                                unlocks,
                                vwrites,
                                ewrites,
                                tasks,
                            } => {
                                term.on_recv();
                                // A Release in flight at the cut carries
                                // writes the sender's recorded state already
                                // reflects — they are channel state and must
                                // land in the snapshot too.
                                let cut = snap
                                    .as_ref()
                                    .is_some_and(|sx| sx.recording_from(rcv.src));
                                for (v, ver, val) in vwrites {
                                    if cut {
                                        snap.as_mut().unwrap().record_vertex(v, ver, &val);
                                    }
                                    let lv = lg.g2l[&v] as usize;
                                    debug_assert!(ver > lg.vversion[lv]);
                                    lg.vdata[lv] = val;
                                    lg.vversion[lv] = ver;
                                }
                                for (ge, ver, val) in ewrites {
                                    if cut {
                                        snap.as_mut().unwrap().record_edge(ge, ver, &val);
                                    }
                                    let le = lg.ge2l[&ge] as usize;
                                    debug_assert!(ver > lg.eversion[le]);
                                    lg.edata[le] = val;
                                    lg.eversion[le] = ver;
                                }
                                for t in tasks {
                                    if !halted {
                                        sched.push(t);
                                    }
                                }
                                for (v, write) in unlocks {
                                    let promoted = locks.release(v, txn, write);
                                    for p in promoted {
                                        handle_promotion(
                                            p,
                                            &mut req_meta,
                                            &mut pipeline,
                                            &mut locks,
                                            &ep,
                                            &lg,
                                            partition,
                                            me,
                                            &mut ready,
                                        );
                                    }
                                }
                            }
                            Msg::GhostPush { verts, edges } => {
                                let cut = snap
                                    .as_ref()
                                    .is_some_and(|sx| sx.recording_from(rcv.src));
                                for (v, ver, val) in verts {
                                    if cut {
                                        snap.as_mut().unwrap().record_vertex(v, ver, &val);
                                    }
                                    if let Some(&lv) = lg.g2l.get(&v) {
                                        lg.vdata[lv as usize] = val;
                                        lg.vversion[lv as usize] =
                                            lg.vversion[lv as usize].max(ver);
                                    }
                                }
                                for (ge, ver, val) in edges {
                                    if cut {
                                        snap.as_mut().unwrap().record_edge(ge, ver, &val);
                                    }
                                    if let Some(&le) = lg.ge2l.get(&ge) {
                                        lg.edata[le as usize] = val;
                                        lg.eversion[le as usize] =
                                            lg.eversion[le as usize].max(ver);
                                    }
                                }
                            }
                            Msg::SyncBegin { epoch } => {
                                syncing = true;
                                sync_epoch = epoch;
                                sync_partial_sent = false;
                            }
                            Msg::SyncPartial {
                                epoch,
                                accs,
                                updates,
                                capped,
                            } => {
                                debug_assert_eq!(me, 0);
                                debug_assert_eq!(epoch, sync_epoch);
                                if gather.is_empty() {
                                    gather = accs;
                                } else {
                                    for (i, a) in accs.into_iter().enumerate() {
                                        syncs[i].merge(&mut gather[i], &a);
                                    }
                                }
                                gather_updates += updates;
                                gather_capped &= capped;
                                gather_count += 1;
                                if gather_count == machines {
                                    // Finalize, publish, broadcast SyncEnd.
                                    let values: Vec<(String, Vec<f64>)> = syncs
                                        .iter()
                                        .zip(std::mem::take(&mut gather))
                                        .map(|(op, acc)| {
                                            (op.key().to_string(), op.finalize(acc))
                                        })
                                        .collect();
                                    for (k, v) in &values {
                                        globals.set(k, v.clone());
                                    }
                                    epochs.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                                    if let Some(cb) = on_sync {
                                        cb(sync_epoch, gather_updates, &globals);
                                    }
                                    for peer in 0..machines {
                                        if peer != me {
                                            ep.send(
                                                peer,
                                                Msg::SyncEnd {
                                                    epoch: sync_epoch,
                                                    values: values.clone(),
                                                },
                                            );
                                        }
                                    }
                                    // Leader applies locally.
                                    syncing = false;
                                    // If every machine hit its update cap,
                                    // stop even though tasks remain.
                                    if gather_capped {
                                        for peer in 1..machines {
                                            ep.send(peer, Msg::Halt);
                                        }
                                        halted = true;
                                    }
                                    gather_updates = 0;
                                    gather_capped = true;
                                    gather_count = 0;
                                }
                            }
                            Msg::SyncEnd { epoch, values } => {
                                debug_assert_eq!(epoch, sync_epoch);
                                for (k, v) in values {
                                    globals.set(&k, v);
                                }
                                syncing = false;
                                // In cluster mode this process has no
                                // leader thread: drive the progress
                                // callback off the leader's broadcast
                                // (updates count is local, like stats).
                                if cluster_mode {
                                    if let Some(cb) = on_sync {
                                        cb(epoch, my_updates, &globals);
                                    }
                                }
                            }
                            Msg::Token(tok) => {
                                let idle = is_idle(
                                    &pipeline, &ready, inflight, &*sched, syncing, my_updates,
                                    cap,
                                );
                                match term.on_token(tok, idle) {
                                    TokenAction::Forward(t) => {
                                        ep.send((me + 1) % machines, Msg::Token(t));
                                    }
                                    TokenAction::Terminate => {
                                        for peer in 0..machines {
                                            if peer != me {
                                                ep.send(peer, Msg::Halt);
                                            }
                                        }
                                        halted = true;
                                    }
                                    TokenAction::Hold => {
                                        held_token = Some(tok);
                                    }
                                }
                            }
                            Msg::Halt => {
                                halted = true;
                            }
                            Msg::FinalReport { accs, updates } => {
                                // A fast follower can halt, report, and
                                // exit while the leader is still draining
                                // its own pipeline. Keep these strictly
                                // apart from the sync-barrier `gather`
                                // state (they are different protocols) and
                                // carry them into the final gather below.
                                debug_assert_eq!(me, 0);
                                if final_accs.is_empty() {
                                    final_accs = accs;
                                } else {
                                    for (i, a) in accs.into_iter().enumerate() {
                                        syncs[i].merge(&mut final_accs[i], &a);
                                    }
                                }
                                final_updates_in += updates;
                                final_got += 1;
                            }
                            Msg::Snap { epoch } => {
                                // Chandy–Lamport marker rule: on the first
                                // token of an epoch, record local state and
                                // broadcast the token on every channel;
                                // commit once all peers' tokens are in.
                                if let Some(sess) = snap.as_mut() {
                                    if sess.on_token(rcv.src, epoch, |vs, es| {
                                        record_from_graph(&lg, vs, es)
                                    })? {
                                        for peer in (0..machines).filter(|&p| p != me) {
                                            ep.send(peer, Msg::Snap { epoch });
                                        }
                                    }
                                }
                            }
                        }
                    }

                    // ---- 1b. drain executor completions ------------------
                    // The pump is the only thread that touches `lg`, the
                    // lock table, the endpoint, or the termination state:
                    // committing a completion here is what turns an
                    // executed batch into version bumps, Releases, ghost
                    // pushes, and promotions.
                    while let Some(done) = pending_done.take().or_else(|| done_rx.try_recv().ok())
                    {
                        progressed = true;
                        let comp = match done {
                            Ok(c) => c,
                            Err(PoolPanic) => panic!(
                                "locking engine machine {me}: update executor panicked"
                            ),
                        };
                        inflight -= comp.txns.len();
                        my_updates += comp.txns.len() as u64;
                        commit_completion(
                            comp,
                            consistency,
                            &mut lg,
                            partition,
                            me,
                            &mut locks,
                            &mut req_meta,
                            &ep,
                            &mut sched,
                            &mut pipeline,
                            &mut ready,
                            &mut term,
                            halted,
                        );
                    }

                    // ---- 2. sync-barrier drain ---------------------------
                    if syncing
                        && !sync_partial_sent
                        && pipeline.is_empty()
                        && ready.is_empty()
                        && inflight == 0
                    {
                        let accs: Vec<Vec<f64>> = syncs
                            .iter()
                            .map(|op| {
                                let mut acc = op.init();
                                for lv in 0..owned {
                                    op.fold(&mut acc, lg.l2g[lv], &lg.vdata[lv]);
                                }
                                acc
                            })
                            .collect();
                        ep.send(
                            0,
                            Msg::SyncPartial {
                                epoch: sync_epoch,
                                accs,
                                updates: my_updates,
                                capped: my_updates >= cap,
                            },
                        );
                        sync_partial_sent = true;
                        progressed = true;
                    }

                    if halted && pipeline.is_empty() && ready.is_empty() && inflight == 0 {
                        break 'main;
                    }

                    // ---- 3. start new transactions -----------------------
                    // `inflight` counts against both the pipelining depth
                    // (dispatched batches still occupy their maxpending
                    // slots — the knob bounds *uncommitted* transactions)
                    // and the update cap (their updates are counted only
                    // at completion).
                    if !syncing && !halted {
                        while pipeline.len() + ready.len() + inflight < maxpending
                            && (my_updates + (pipeline.len() + ready.len() + inflight) as u64)
                                < cap
                        {
                            let Some(task) = sched.pop() else {
                                break;
                            };
                            progressed = true;
                            let lv = lg.g2l[&task.vertex];
                            let seq = next_seq;
                            next_seq += 1;
                            let mut plan = Vec::new();
                            crate::engine::shared::scope_lock_plan(
                                task.vertex,
                                lg.neighbors(lv).iter().map(|&(nlv, _)| lg.l2g[nlv as usize]),
                                consistency,
                                &mut plan,
                            );
                            let txn = Txn {
                                seq,
                                center_lv: lv,
                                plan,
                                next: 0,
                            };
                            pipeline.insert(seq, txn);
                            pump_txn(
                                &mut pipeline,
                                seq,
                                &mut locks,
                                &mut req_meta,
                                &ep,
                                &lg,
                                partition,
                                me,
                                &mut ready,
                            );
                        }
                    }

                    // ---- 4. execute ready batches ------------------------
                    // Flush when the batch is full, when draining, or when
                    // this iteration made no other progress — ready
                    // transactions hold locks that may block the whole
                    // pipeline, so waiting for a full batch can deadlock
                    // when maxpending < batch width.
                    let flush = !ready.is_empty()
                        && (ready.len() >= batch_w
                            || pipeline.is_empty()
                            || syncing
                            || halted
                            || !progressed);
                    if flush {
                        progressed = true;
                        let batch: Vec<Txn> = ready.drain(..).collect();
                        if threads > 1 {
                            // Snapshot the batch's scopes (every slot is
                            // still locked, so the copies are exactly the
                            // grant-time values) and hand it to the pool;
                            // the completion is committed in phase 1b.
                            inflight += batch.len();
                            let jobs: Vec<TxnJob<V, E>> =
                                batch.into_iter().map(|t| capture_job(t, &lg)).collect();
                            jobs_q.push((jobs, my_updates));
                        } else {
                            // Inline path: unchanged sequential oracle.
                            let blen = batch.len() as u64;
                            execute_batch(
                                batch,
                                program,
                                consistency,
                                &mut lg,
                                &globals,
                                partition,
                                me,
                                &mut locks,
                                &mut req_meta,
                                &ep,
                                &mut sched,
                                &mut pipeline,
                                &mut ready,
                                &mut term,
                                my_updates,
                                halted,
                            );
                            my_updates += blen;
                        }
                    }

                    // ---- 5. leader: periodic sync + termination ----------
                    if me == 0 && !syncing && !halted {
                        if let Some(period) = sync_period {
                            if last_sync.elapsed() >= period {
                                last_sync = Instant::now();
                                syncing = true;
                                sync_epoch += 1;
                                sync_partial_sent = false;
                                gather.clear();
                                gather_updates = 0;
                                gather_capped = true;
                                gather_count = 0;
                                for peer in 1..machines {
                                    ep.send(peer, Msg::SyncBegin { epoch: sync_epoch });
                                }
                                progressed = true;
                            }
                        }
                        // Cut a snapshot when due: record local state
                        // first, then a token on every channel (the
                        // Chandy–Lamport marker order).
                        if let Some(sess) = snap.as_mut() {
                            if sess.due(my_updates) {
                                let epoch = sess
                                    .begin(my_updates, |vs, es| record_from_graph(&lg, vs, es))?;
                                for peer in 1..machines {
                                    ep.send(peer, Msg::Snap { epoch });
                                }
                                progressed = true;
                            }
                        }
                        let idle =
                            is_idle(&pipeline, &ready, inflight, &*sched, syncing, my_updates, cap)
                                && last_token.elapsed() > Duration::from_micros(500);
                        if idle {
                            last_token = Instant::now();
                        }
                        if let Some(action) = term.leader_try_start(idle) {
                            match action {
                                TokenAction::Forward(t) => {
                                    ep.send(1 % machines, Msg::Token(t));
                                }
                                TokenAction::Terminate => {
                                    halted = true;
                                }
                                TokenAction::Hold => {}
                            }
                        }
                    }
                    // Re-offer a held token once idle.
                    if let Some(tok) = held_token {
                        let idle = is_idle(
                            &pipeline, &ready, inflight, &*sched, syncing, my_updates, cap,
                        );
                        if idle {
                            match term.maybe_forward(tok, idle) {
                                TokenAction::Forward(t) => {
                                    held_token = None;
                                    ep.send((me + 1) % machines, Msg::Token(t));
                                }
                                TokenAction::Terminate => {
                                    held_token = None;
                                    for peer in 0..machines {
                                        if peer != me {
                                            ep.send(peer, Msg::Halt);
                                        }
                                    }
                                    halted = true;
                                }
                                TokenAction::Hold => {}
                            }
                        }
                    }

                    // ---- 6. flush coalesced sends, then park if idle -----
                    // Everything sections 1–5 sent this iteration is still
                    // coalescing in per-peer buffers; push it out *before*
                    // the idle check — an idle spin makes no transport
                    // calls, so an unflushed LockReq would deadlock the
                    // whole pipeline.
                    ep.flush();
                    if !progressed {
                        // A disconnected peer (frame decode failure, dead
                        // stream, EOF from a killed process) can never
                        // unblock this loop — surface the typed transport
                        // error loudly instead of hanging forever. The
                        // abort fires only after a grace window of
                        // *continuous idleness* (`peer_failure_since`
                        // resets on every productive iteration): frames
                        // sent before the failure (e.g. a Halt racing a
                        // finished peer's EOF) may still be in flight and
                        // must win, and a machine that is still making
                        // progress off its other peers is not stuck.
                        let mut errs = ep.peer_errors();
                        pending_peer_failure.append(&mut errs);
                        if !pending_peer_failure.is_empty() {
                            let since =
                                *peer_failure_since.get_or_insert_with(Instant::now);
                            if since.elapsed() > grace {
                                bail!(
                                    "locking engine machine {me}: peer failure, cannot make progress: {pending_peer_failure:?}"
                                );
                            }
                        }
                        // Park on whichever event source can actually
                        // unblock this iteration instead of spinning
                        // (the old spin/yield/20 µs backoff burned a
                        // core on every idle machine — §Perf). With
                        // batches in flight the executor channel is the
                        // next wake (bounded tightly: completions feed
                        // releases other machines may be blocked on);
                        // otherwise only a peer message can help, and
                        // `recv_timeout` flushes + blocks on the
                        // transport directly. The timeout bounds the
                        // latency of the leader's timer-driven work
                        // (sync periods, snapshot triggers, tokens).
                        if inflight > 0 {
                            if let Ok(done) = done_rx.recv_timeout(Duration::from_micros(100)) {
                                pending_done = Some(done);
                            }
                        } else if let Some(rcv) = ep.recv_timeout(Duration::from_millis(1)) {
                            pending_msg = Some(rcv);
                        }
                    } else {
                        // Progress re-anchors the peer-failure grace
                        // window: only continuous idleness counts.
                        peer_failure_since = None;
                    }
                }
                // The break above fires before the iteration-bottom flush,
                // so Halt broadcasts sent this iteration can still be
                // coalescing — push them out before the final exchange.
                ep.flush();

                // ---- final report / leader finalization ------------------
                if me != 0 {
                    let accs: Vec<Vec<f64>> = syncs
                        .iter()
                        .map(|op| {
                            let mut acc = op.init();
                            for lv in 0..owned {
                                op.fold(&mut acc, lg.l2g[lv], &lg.vdata[lv]);
                            }
                            acc
                        })
                        .collect();
                    ep.send(
                        0,
                        Msg::FinalReport {
                            accs,
                            updates: my_updates,
                        },
                    );
                    // The leader is blocked gathering this report; it must
                    // not sit in a coalescing buffer until endpoint drop.
                    ep.flush();
                } else {
                    // Leader: gather final reports from everyone else,
                    // starting from any that already arrived during the
                    // main loop's drain.
                    let mut acc0: Vec<Vec<f64>> = syncs
                        .iter()
                        .map(|op| {
                            let mut acc = op.init();
                            for lv in 0..owned {
                                op.fold(&mut acc, lg.l2g[lv], &lg.vdata[lv]);
                            }
                            acc
                        })
                        .collect();
                    for (i, a) in final_accs.iter().enumerate() {
                        syncs[i].merge(&mut acc0[i], a);
                    }
                    let mut updates_sum = my_updates + final_updates_in;
                    let mut got = 1 + final_got;
                    let deadline = Instant::now() + Duration::from_secs(30);
                    while got < machines && Instant::now() < deadline {
                        if let Some(rcv) = ep.recv_timeout(Duration::from_millis(50)) {
                            if let Msg::FinalReport { accs, updates } = rcv.msg {
                                for (i, a) in accs.into_iter().enumerate() {
                                    syncs[i].merge(&mut acc0[i], &a);
                                }
                                updates_sum += updates;
                                got += 1;
                            }
                        }
                    }
                    if got < machines {
                        // Loud, not silent: the published globals would
                        // otherwise masquerade as cluster-wide values.
                        // Include errors already drained during the main
                        // loop — they are usually the explanation.
                        let mut errs = pending_peer_failure;
                        errs.extend(ep.peer_errors());
                        eprintln!(
                            "WARNING: locking engine leader: final sync gather incomplete \
                             ({got}/{machines} machines reported within 30s; peer errors: {errs:?}) \
                             — published global values are partial"
                        );
                    }
                    let values: Vec<(String, Vec<f64>)> = syncs
                        .iter()
                        .zip(acc0)
                        .map(|(op, acc)| (op.key().to_string(), op.finalize(acc)))
                        .collect();
                    for (k, v) in &values {
                        globals.set(k, v.clone());
                    }
                    if let Some(cb) = on_sync {
                        let e = epochs.load(std::sync::atomic::Ordering::Relaxed) + 1;
                        cb(e, updates_sum, &globals);
                    }
                }

                // Return authoritative data.
                let verts: Vec<(VertexId, V)> = (0..owned)
                    .map(|lv| (lg.l2g[lv], lg.vdata[lv].clone()))
                    .collect();
                let edges: Vec<(EdgeId, E)> = lg
                    .le2g
                    .iter()
                    .enumerate()
                    .filter(|&(_, &ge)| {
                        let (a, b) = endpoints_ref[ge as usize];
                        partition.owner(a.min(b)) == me
                    })
                    .map(|(le, &ge)| (ge, lg.edata[le].clone()))
                    .collect();
                updates_by_machine.lock().unwrap()[me] = my_updates;
                outputs.lock().unwrap()[me] = Some((verts, edges));
                Ok(())
            }));
        }
        let mut first_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;

    // Reassemble from machine outputs. In-process runs must cover every
    // slot (an uncovered one is a partition/ownership bug, kept as a loud
    // invariant); in cluster mode only this process's machine reported,
    // so unreported slots keep the input data (the authoritative copies
    // live in the other worker processes).
    let mut vdata_opt: Vec<Option<V>> = (0..topo.adj_offsets.len() - 1).map(|_| None).collect();
    let mut edata_opt: Vec<Option<E>> = (0..topo.endpoints.len()).map(|_| None).collect();
    for out in outputs.into_inner().unwrap().into_iter().flatten() {
        for (v, d) in out.0 {
            vdata_opt[v as usize] = Some(d);
        }
        for (e, d) in out.1 {
            edata_opt[e as usize] = Some(d);
        }
    }
    let vdata = crate::distributed::reassemble(vdata_opt, vfallback, "vertex");
    let edata = crate::distributed::reassemble(edata_opt, efallback, "edge");
    let graph = Graph::from_parts(vdata, edata, topo);

    let updates_per_machine = updates_by_machine.into_inner().unwrap();
    let stats = ExecStats {
        updates: updates_per_machine.iter().sum(),
        sweeps: epochs.load(std::sync::atomic::Ordering::Relaxed),
        seconds: start.elapsed().as_secs_f64(),
        updates_per_machine,
        bytes_sent: net_stats
            .iter()
            .map(|s| s.bytes_sent.load(std::sync::atomic::Ordering::Relaxed))
            .collect(),
        msgs_sent: net_stats
            .iter()
            .map(|s| s.msgs_sent.load(std::sync::atomic::Ordering::Relaxed))
            .collect(),
    };
    Ok((graph, stats))
}

// ---------------------------------------------------------------------------
// helper functions (free functions to satisfy the borrow checker)
// ---------------------------------------------------------------------------

fn is_idle(
    pipeline: &HashMap<u64, Txn>,
    ready: &[Txn],
    inflight: usize,
    sched: &dyn scheduler::Scheduler,
    syncing: bool,
    my_updates: u64,
    cap: u64,
) -> bool {
    pipeline.is_empty()
        && ready.is_empty()
        && inflight == 0
        && !syncing
        && (sched.is_empty() || my_updates >= cap)
}

/// Build and send the grant for a (now-granted) remote request.
fn send_grant<V: DataValue, E: DataValue>(
    ep: &crate::distributed::Endpoint<Msg<V, E>>,
    lg: &LocalGraph<V, E>,
    txn: TxnId,
    vertex: VertexId,
    req_vver: u64,
    edge: Option<(EdgeId, u64)>,
) {
    let lv = lg.g2l[&vertex] as usize;
    let vdata = if req_vver < lg.vversion[lv] {
        Some((lg.vversion[lv], lg.vdata[lv].clone()))
    } else {
        None
    };
    let edata = edge.and_then(|(ge, req_ever)| {
        let le = lg.ge2l[&ge] as usize;
        if req_ever < lg.eversion[le] {
            Some((ge, lg.eversion[le], lg.edata[le].clone()))
        } else {
            None
        }
    });
    ep.send(
        txn.machine,
        Msg::Grant {
            txn_seq: txn.seq,
            vertex,
            vdata,
            edata,
        },
    );
}

/// A queued request became granted: local txns advance, remote get a Grant.
#[allow(clippy::too_many_arguments)]
fn handle_promotion<V: DataValue, E: DataValue>(
    p: LockReq,
    req_meta: &mut ReqMeta,
    pipeline: &mut HashMap<u64, Txn>,
    locks: &mut LockTable,
    ep: &crate::distributed::Endpoint<Msg<V, E>>,
    lg: &LocalGraph<V, E>,
    partition: &Partition,
    me: MachineId,
    ready: &mut Vec<Txn>,
) {
    if p.txn.machine == me {
        let txn = pipeline.get_mut(&p.txn.seq).expect("promotion for unknown txn");
        debug_assert_eq!(txn.plan[txn.next].0, p.vertex);
        txn.next += 1;
        pump_txn(pipeline, p.txn.seq, locks, req_meta, ep, lg, partition, me, ready);
    } else {
        let (vver, edge) = req_meta
            .remove(&(p.txn, p.vertex))
            .expect("missing request metadata");
        send_grant(ep, lg, p.txn, p.vertex, vver, edge);
    }
}

/// Advance a transaction's lock chain as far as possible without waiting.
#[allow(clippy::too_many_arguments)]
fn pump_txn<V: DataValue, E: DataValue>(
    pipeline: &mut HashMap<u64, Txn>,
    seq: u64,
    locks: &mut LockTable,
    req_meta: &mut ReqMeta,
    ep: &crate::distributed::Endpoint<Msg<V, E>>,
    lg: &LocalGraph<V, E>,
    partition: &Partition,
    me: MachineId,
    ready: &mut Vec<Txn>,
) {
    let _ = req_meta;
    loop {
        let txn = pipeline.get_mut(&seq).unwrap();
        if txn.next >= txn.plan.len() {
            // All locks held: move to the ready queue.
            let txn = pipeline.remove(&seq).unwrap();
            ready.push(txn);
            return;
        }
        let (v, write) = txn.plan[txn.next];
        let owner = partition.owner(v);
        let txn_id = TxnId { machine: me, seq };
        if owner == me {
            if locks.request(LockReq {
                txn: txn_id,
                vertex: v,
                write,
            }) {
                txn.next += 1;
                continue;
            }
            return; // queued locally; promotion will resume us
        }
        // Remote: send the request with cache versions for piggybacking.
        let lv = lg.g2l[&v] as usize;
        let center_g = lg.l2g[txn.center_lv as usize];
        let edge = if v < center_g {
            // This owner is canonical for the center-v edge: ask for it.
            lg.neighbors(txn.center_lv)
                .iter()
                .find(|&&(nlv, _)| lg.l2g[nlv as usize] == v)
                .map(|&(_, le)| (lg.le2g[le as usize], lg.eversion[le as usize]))
        } else {
            None
        };
        ep.send(
            owner,
            Msg::LockReq {
                txn: txn_id,
                vertex: v,
                write,
                vver: lg.vversion[lv],
                edge,
            },
        );
        return; // wait for the grant
    }
}

/// Execute a batch of fully-locked transactions *inline on the pump
/// thread* (the `threads == 1` path), write back, release. This is the
/// sequential oracle: scopes point straight into `lg` and the
/// floating-point evaluation order is identical to the pre-pool engine.
#[allow(clippy::too_many_arguments)]
fn execute_batch<V, E, P>(
    batch: Vec<Txn>,
    program: &P,
    consistency: Consistency,
    lg: &mut LocalGraph<V, E>,
    globals: &GlobalValues,
    partition: &Partition,
    me: MachineId,
    locks: &mut LockTable,
    req_meta: &mut ReqMeta,
    ep: &crate::distributed::Endpoint<Msg<V, E>>,
    sched: &mut dyn scheduler::Scheduler,
    pipeline: &mut HashMap<u64, Txn>,
    ready: &mut Vec<Txn>,
    term: &mut Termination,
    updates_hint: u64,
    halted: bool,
) where
    V: DataValue,
    E: DataValue,
    P: VertexProgram<V, E>,
{
    // Assemble scopes (raw pointers into lg data; locks guarantee
    // exclusivity; batch members' scopes may alias READ slots only, which
    // is fine since read locks are shared).
    let vptr = lg.vdata.as_mut_ptr();
    let eptr = lg.edata.as_mut_ptr();
    let mut scopes: Vec<Scope<V, E>> = batch
        .iter()
        .map(|txn| {
            let mut sc = Scope::new_buffer(consistency);
            unsafe {
                sc.reset(lg.l2g[txn.center_lv as usize], vptr.add(txn.center_lv as usize));
                let lo = lg.adj_offsets[txn.center_lv as usize] as usize;
                let hi = lg.adj_offsets[txn.center_lv as usize + 1] as usize;
                for &(nlv, nle) in &lg.adj[lo..hi] {
                    sc.push_neighbor(
                        lg.l2g[nlv as usize],
                        lg.le2g[nle as usize],
                        vptr.add(nlv as usize),
                        eptr.add(nle as usize),
                    );
                }
            }
            sc
        })
        .collect();
    let mut ctx = Ctx::new(globals);
    ctx.set_updates_hint(updates_hint);
    {
        let mut refs: Vec<&mut Scope<V, E>> = scopes.iter_mut().collect();
        program.update_batch(&mut refs, &mut ctx);
    }
    let dones: Vec<TxnDone> = batch
        .into_iter()
        .zip(&scopes)
        .map(|(txn, sc)| {
            let deg = lg.neighbors(txn.center_lv).len();
            TxnDone {
                seq: txn.seq,
                center_lv: txn.center_lv,
                plan: txn.plan,
                flags: TxnFlags {
                    center_dirty: sc.center_dirty(),
                    nbr_dirty: (0..deg).map(|i| sc.nbr_dirty(i)).collect(),
                    edge_dirty: (0..deg).map(|i| sc.edge_dirty(i)).collect(),
                },
            }
        })
        .collect();
    let tasks = std::mem::take(&mut ctx.scheduled);
    write_back_release(
        dones, tasks, consistency, lg, partition, me, locks, req_meta, ep, sched, pipeline,
        ready, term, halted,
    );
}

/// Package a fully-granted transaction for an executor thread: owned
/// clones of the center and of every scope slot. All plan slots are
/// still locked, so these copies are exactly the grant-time values and
/// stay valid until the completion commits (nothing can write a locked
/// slot in between — see the version-gate argument in DESIGN.md).
fn capture_job<V: DataValue, E: DataValue>(txn: Txn, lg: &LocalGraph<V, E>) -> TxnJob<V, E> {
    let c = txn.center_lv as usize;
    let nbrs = lg
        .neighbors(txn.center_lv)
        .iter()
        .map(|&(nlv, nle)| JobNbr {
            ng: lg.l2g[nlv as usize],
            ge: lg.le2g[nle as usize],
            vdata: lg.vdata[nlv as usize].clone(),
            edata: lg.edata[nle as usize].clone(),
        })
        .collect();
    TxnJob {
        seq: txn.seq,
        center_lv: txn.center_lv,
        plan: txn.plan,
        center_g: lg.l2g[c],
        center: lg.vdata[c].clone(),
        nbrs,
    }
}

/// Evaluate a dispatched batch on an executor thread: build scopes over
/// the jobs' own snapshot buffers (no pointer into `lg` ever crosses a
/// thread boundary), run `update_batch`, and report per-slot dirty flags
/// plus the tasks the batch scheduled. Mutations land in the job buffers;
/// the pump moves dirty ones into `lg` at commit.
fn run_jobs<V, E, P>(
    jobs: &mut [TxnJob<V, E>],
    program: &P,
    consistency: Consistency,
    globals: &GlobalValues,
    updates_hint: u64,
) -> (Vec<TxnFlags>, Vec<Task>)
where
    V: DataValue,
    E: DataValue,
    P: VertexProgram<V, E>,
{
    let mut scopes: Vec<Scope<V, E>> = Vec::with_capacity(jobs.len());
    for job in jobs.iter_mut() {
        let mut sc = Scope::new_buffer(consistency);
        // SAFETY: the pointers target this job's owned buffers, which
        // outlive the scopes (both live to the end of this function and
        // the scopes are dropped first), and no Rust reference to the
        // buffers is formed while `update_batch` writes through them.
        unsafe {
            sc.reset(job.center_g, &mut job.center as *mut V);
            for nbr in job.nbrs.iter_mut() {
                sc.push_neighbor(
                    nbr.ng,
                    nbr.ge,
                    &mut nbr.vdata as *mut V,
                    &mut nbr.edata as *mut E,
                );
            }
        }
        scopes.push(sc);
    }
    let mut ctx = Ctx::new(globals);
    ctx.set_updates_hint(updates_hint);
    {
        let mut refs: Vec<&mut Scope<V, E>> = scopes.iter_mut().collect();
        program.update_batch(&mut refs, &mut ctx);
    }
    let flags = jobs
        .iter()
        .zip(&scopes)
        .map(|(job, sc)| TxnFlags {
            center_dirty: sc.center_dirty(),
            nbr_dirty: (0..job.nbrs.len()).map(|i| sc.nbr_dirty(i)).collect(),
            edge_dirty: (0..job.nbrs.len()).map(|i| sc.edge_dirty(i)).collect(),
        })
        .collect();
    (flags, std::mem::take(&mut ctx.scheduled))
}

/// The executor thread body: pop, evaluate, report, repeat until the
/// pump closes the queue. Panics inside the update function are caught
/// and forwarded as [`PoolPanic`] so the pump (which may be blocked on
/// this very completion) re-raises them instead of hanging.
fn executor_loop<V, E, P>(
    jobs_q: &DispatchQueue<ExecJob<V, E>>,
    done_tx: &std::sync::mpsc::Sender<Result<Completion<V, E>, PoolPanic>>,
    program: &P,
    consistency: Consistency,
    globals: &GlobalValues,
) where
    V: DataValue,
    E: DataValue,
    P: VertexProgram<V, E>,
{
    while let Some((mut jobs, hint)) = jobs_q.pop() {
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs(&mut jobs, program, consistency, globals, hint)
        }));
        let msg = match out {
            Ok((flags, tasks)) => Ok(Completion {
                txns: jobs.into_iter().zip(flags).collect(),
                tasks,
            }),
            Err(_) => Err(PoolPanic),
        };
        if done_tx.send(msg).is_err() {
            return; // pump already gone (unwinding) — nothing to do
        }
    }
}

/// Commit a pool completion on the pump thread: move the dirty snapshot
/// values into `lg` (safe — every dirtied slot is still locked by its
/// transaction, so `lg` cannot have advanced past the snapshot), then
/// run the shared write-back/release path.
#[allow(clippy::too_many_arguments)]
fn commit_completion<V, E>(
    comp: Completion<V, E>,
    consistency: Consistency,
    lg: &mut LocalGraph<V, E>,
    partition: &Partition,
    me: MachineId,
    locks: &mut LockTable,
    req_meta: &mut ReqMeta,
    ep: &crate::distributed::Endpoint<Msg<V, E>>,
    sched: &mut dyn scheduler::Scheduler,
    pipeline: &mut HashMap<u64, Txn>,
    ready: &mut Vec<Txn>,
    term: &mut Termination,
    halted: bool,
) where
    V: DataValue,
    E: DataValue,
{
    let mut dones = Vec::with_capacity(comp.txns.len());
    for (job, flags) in comp.txns {
        let c = job.center_lv as usize;
        let lo = lg.adj_offsets[c] as usize;
        if flags.center_dirty {
            lg.vdata[c] = job.center;
        }
        for (i, nbr) in job.nbrs.into_iter().enumerate() {
            let (nlv, nle) = lg.adj[lo + i];
            if flags.nbr_dirty[i] {
                lg.vdata[nlv as usize] = nbr.vdata;
            }
            if flags.edge_dirty[i] {
                lg.edata[nle as usize] = nbr.edata;
            }
        }
        dones.push(TxnDone {
            seq: job.seq,
            center_lv: job.center_lv,
            plan: job.plan,
            flags,
        });
    }
    write_back_release(
        dones, comp.tasks, consistency, lg, partition, me, locks, req_meta, ep, sched,
        pipeline, ready, term, halted,
    );
}

/// The pump-thread half of transaction completion, shared by the inline
/// and pool paths: bump versions, build per-owner Release parts, eager
/// ghost pushes (Unsafe mode), release local locks (running promotions),
/// and count remote sends into the termination token state.
#[allow(clippy::too_many_arguments)]
fn write_back_release<V, E>(
    dones: Vec<TxnDone>,
    mut tasks: Vec<Task>,
    consistency: Consistency,
    lg: &mut LocalGraph<V, E>,
    partition: &Partition,
    me: MachineId,
    locks: &mut LockTable,
    req_meta: &mut ReqMeta,
    ep: &crate::distributed::Endpoint<Msg<V, E>>,
    sched: &mut dyn scheduler::Scheduler,
    pipeline: &mut HashMap<u64, Txn>,
    ready: &mut Vec<Txn>,
    term: &mut Termination,
    halted: bool,
) where
    V: DataValue,
    E: DataValue,
{
    // Write-back + release, one transaction at a time.
    let count = dones.len();
    for (k, done) in dones.iter().enumerate() {
        let center_lv = done.center_lv as usize;
        let center_g = lg.l2g[center_lv];
        // Per-owner release parts.
        #[allow(clippy::type_complexity)]
        let mut parts: HashMap<
            MachineId,
            (
                Vec<(VertexId, bool)>,
                Vec<(VertexId, u64, V)>,
                Vec<(EdgeId, u64, E)>,
                Vec<Task>,
            ),
        > = HashMap::new();

        // Dirty center: bump our authoritative version. Ghost holders
        // refresh via future grants (or eagerly in Unsafe mode).
        if done.flags.center_dirty {
            lg.vversion[center_lv] += 1;
        }
        // Dirty neighbors (full consistency): send to their owners.
        for (i, &(nlv, nle)) in lg.adj
            [lg.adj_offsets[center_lv] as usize..lg.adj_offsets[center_lv + 1] as usize]
            .iter()
            .enumerate()
        {
            let nlv = nlv as usize;
            if done.flags.nbr_dirty[i] {
                let owner = lg.owner[nlv];
                if owner == me {
                    lg.vversion[nlv] += 1;
                } else {
                    lg.vversion[nlv] += 1; // our ghost now at granted+1
                    parts.entry(owner).or_default().1.push((
                        lg.l2g[nlv],
                        lg.vversion[nlv],
                        lg.vdata[nlv].clone(),
                    ));
                }
            }
            let nle = nle as usize;
            if done.flags.edge_dirty[i] {
                let ge = lg.le2g[nle];
                let (a, b) = {
                    // endpoints: center and neighbor
                    (center_g.min(lg.l2g[nlv]), center_g.max(lg.l2g[nlv]))
                };
                let canon_owner = partition.owner(a.min(b));
                lg.eversion[nle] += 1;
                if canon_owner != me {
                    parts.entry(canon_owner).or_default().2.push((
                        ge,
                        lg.eversion[nle],
                        lg.edata[nle].clone(),
                    ));
                }
            }
        }
        // Unlocks grouped by owner.
        let txn_id = TxnId {
            machine: me,
            seq: done.seq,
        };
        for &(v, write) in &done.plan {
            let owner = partition.owner(v);
            parts.entry(owner).or_default().0.push((v, write));
        }
        // Scheduled tasks grouped by owner. Tasks were accumulated
        // across the whole batch; attribute them to owners now (after
        // the last transaction's write-back is fine: tasks are work
        // hints, not data).
        if k + 1 == count {
            for t in tasks.drain(..) {
                let owner = partition.owner(t.vertex);
                if owner == me {
                    if !halted {
                        sched.push(t);
                    }
                } else {
                    parts.entry(owner).or_default().3.push(t);
                }
            }
        }

        // Unsafe mode: eager ghost push of the dirty center.
        if matches!(consistency, Consistency::Unsafe) && done.flags.center_dirty {
            let ver = lg.vversion[center_lv];
            let val = lg.vdata[center_lv].clone();
            for &peer in &lg.mirrors[center_lv] {
                ep.send(
                    peer,
                    Msg::GhostPush {
                        verts: vec![(center_g, ver, val.clone())],
                        edges: vec![],
                    },
                );
            }
        }

        for (owner, (unlocks, vwrites, ewrites, tasks)) in parts {
            if owner == me {
                // Local: apply writes (already in place), release locks.
                for t in tasks {
                    if !halted {
                        sched.push(t);
                    }
                }
                for (v, write) in unlocks {
                    let promoted = locks.release(v, txn_id, write);
                    for p in promoted {
                        handle_promotion(
                            p, req_meta, pipeline, locks, ep, lg, partition, me, ready,
                        );
                    }
                }
            } else {
                term.on_send();
                ep.send(
                    owner,
                    Msg::Release {
                        txn: txn_id,
                        unlocks,
                        vwrites,
                        ewrites,
                        tasks,
                    },
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Round-trip by re-encoding (Msg derives no PartialEq), plus prefix
    /// totality: truncated frames are errors, never panics.
    fn round_trip(msg: Msg<f32, u64>) {
        let bytes = wire::to_bytes(&msg);
        let back: Msg<f32, u64> = wire::from_bytes(&bytes).unwrap();
        assert_eq!(wire::to_bytes(&back), bytes);
        for cut in 0..bytes.len() {
            assert!(wire::from_bytes::<Msg<f32, u64>>(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn every_locking_frame_variant_round_trips() {
        let txn = TxnId { machine: 1, seq: 9 };
        round_trip(Msg::LockReq {
            txn,
            vertex: 4,
            write: true,
            vver: 7,
            edge: Some((3, 2)),
        });
        round_trip(Msg::Grant {
            txn_seq: 5,
            vertex: 2,
            vdata: Some((1, 0.5)),
            edata: Some((8, 3, 77)),
        });
        round_trip(Msg::Release {
            txn,
            unlocks: vec![(1, true), (2, false)],
            vwrites: vec![(1, 2, 1.5)],
            ewrites: vec![(0, 1, 99)],
            tasks: vec![Task { vertex: 3, priority: 2.0 }],
        });
        round_trip(Msg::GhostPush {
            verts: vec![(6, 1, -0.25)],
            edges: vec![(1, 1, 7)],
        });
        round_trip(Msg::SyncBegin { epoch: 3 });
        round_trip(Msg::SyncPartial {
            epoch: 3,
            accs: vec![vec![1.0, 2.0], vec![]],
            updates: 8,
            capped: false,
        });
        round_trip(Msg::SyncEnd {
            epoch: 3,
            values: vec![("rmse".to_string(), vec![2.0])],
        });
        round_trip(Msg::Token(Token {
            count: -2,
            black: true,
            round: 4,
        }));
        round_trip(Msg::Halt);
        round_trip(Msg::FinalReport {
            accs: vec![vec![0.0; 3]],
            updates: 11,
        });
        round_trip(Msg::Snap { epoch: 12 });
    }

    #[test]
    fn unknown_discriminant_is_an_error() {
        assert!(wire::from_bytes::<Msg<f32, u64>>(&[42]).is_err());
    }
}
