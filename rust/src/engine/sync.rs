//! The **sync operation** (paper Sec. 3.3): `(Key, Fold, Merge, Finalize,
//! acc(0), tau)` — a MapReduce-style global aggregate maintained while the
//! asynchronous computation runs, readable from every update function.
//!
//! Accumulators are `Vec<f64>` — sufficient for every aggregate in the
//! paper's applications (RMSE, convergence counters, GMM parameter sums,
//! top-k ranks) while keeping the distributed protocol trivially
//! serializable.

use std::collections::HashMap;
use std::sync::RwLock;

use crate::graph::VertexId;

/// A sync operation definition.
pub trait SyncOp<V>: Send + Sync {
    /// Unique key under which the finalized value is published.
    fn key(&self) -> &str;

    /// `acc(0)` — the initial accumulator.
    fn init(&self) -> Vec<f64>;

    /// Fold one vertex into the accumulator.
    fn fold(&self, acc: &mut Vec<f64>, vertex: VertexId, data: &V);

    /// Merge a partial accumulator (parallel / distributed reduction).
    fn merge(&self, acc: &mut Vec<f64>, other: &[f64]) {
        for (a, b) in acc.iter_mut().zip(other) {
            *a += b;
        }
    }

    /// Transform the final accumulator into the published value.
    fn finalize(&self, acc: Vec<f64>) -> Vec<f64> {
        acc
    }

    /// Sync interval `tau`, in update-function executions. `0` means "at
    /// every natural barrier" (color boundary for the Chromatic engine,
    /// periodic barrier for the Locking engine).
    fn interval(&self) -> u64 {
        0
    }
}

/// A closure-based [`SyncOp`] for apps and tests.
pub struct FnSync<V> {
    key: String,
    init: Vec<f64>,
    interval: u64,
    #[allow(clippy::type_complexity)]
    fold: Box<dyn Fn(&mut Vec<f64>, VertexId, &V) + Send + Sync>,
    #[allow(clippy::type_complexity)]
    finalize: Box<dyn Fn(Vec<f64>) -> Vec<f64> + Send + Sync>,
}

impl<V> FnSync<V> {
    /// Build from closures with additive merge.
    pub fn new(
        key: &str,
        init: Vec<f64>,
        interval: u64,
        fold: impl Fn(&mut Vec<f64>, VertexId, &V) + Send + Sync + 'static,
        finalize: impl Fn(Vec<f64>) -> Vec<f64> + Send + Sync + 'static,
    ) -> Self {
        FnSync {
            key: key.to_string(),
            init,
            interval,
            fold: Box::new(fold),
            finalize: Box::new(finalize),
        }
    }
}

impl<V> SyncOp<V> for FnSync<V> {
    fn key(&self) -> &str {
        &self.key
    }
    fn init(&self) -> Vec<f64> {
        self.init.clone()
    }
    fn fold(&self, acc: &mut Vec<f64>, vertex: VertexId, data: &V) {
        (self.fold)(acc, vertex, data)
    }
    fn finalize(&self, acc: Vec<f64>) -> Vec<f64> {
        (self.finalize)(acc)
    }
    fn interval(&self) -> u64 {
        self.interval
    }
}

/// Published sync results, readable from update functions via
/// [`crate::engine::Ctx::global`]. One instance is shared per engine run
/// (in the distributed engines every machine holds a replica that the
/// leader refreshes after each global reduce).
#[derive(Default)]
pub struct GlobalValues {
    map: RwLock<HashMap<String, Vec<f64>>>,
}

impl GlobalValues {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Latest finalized value for `key`.
    pub fn get(&self, key: &str) -> Option<Vec<f64>> {
        self.map.read().unwrap().get(key).cloned()
    }

    /// Publish a finalized value.
    pub fn set(&self, key: &str, value: Vec<f64>) {
        self.map.write().unwrap().insert(key.to_string(), value);
    }

    /// All published keys (for logging).
    pub fn keys(&self) -> Vec<String> {
        self.map.read().unwrap().keys().cloned().collect()
    }
}

/// Run `ops` sequentially over `n` vertices with data accessor `data`,
/// publishing finalized values into `globals`. Used by the shared-memory
/// engine at sync barriers; the distributed engines split fold/merge
/// across machines instead.
pub fn run_syncs_local<V>(
    ops: &[Box<dyn SyncOp<V>>],
    n: usize,
    data: impl Fn(VertexId) -> V,
    globals: &GlobalValues,
) where
    V: Clone,
{
    for op in ops {
        let mut acc = op.init();
        for v in 0..n as VertexId {
            let d = data(v);
            op.fold(&mut acc, v, &d);
        }
        globals.set(op.key(), op.finalize(acc));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_merge_finalize_pipeline() {
        // Mean of vertex values: acc = [sum, count], finalize = [sum/count].
        let op: FnSync<f64> = FnSync::new(
            "mean",
            vec![0.0, 0.0],
            0,
            |acc, _v, d| {
                acc[0] += *d;
                acc[1] += 1.0;
            },
            |acc| vec![acc[0] / acc[1].max(1.0)],
        );
        let data = [1.0f64, 2.0, 3.0, 4.0];
        let mut acc = op.init();
        for (v, d) in data.iter().enumerate() {
            op.fold(&mut acc, v as VertexId, d);
        }
        // Split-merge must equal sequential.
        let mut a1 = op.init();
        let mut a2 = op.init();
        for (v, d) in data.iter().enumerate().take(2) {
            op.fold(&mut a1, v as VertexId, d);
        }
        for (v, d) in data.iter().enumerate().skip(2) {
            op.fold(&mut a2, v as VertexId, d);
        }
        op.merge(&mut a1, &a2);
        assert_eq!(a1, acc);
        assert_eq!(op.finalize(acc), vec![2.5]);
    }

    #[test]
    fn globals_roundtrip() {
        let g = GlobalValues::new();
        assert!(g.get("x").is_none());
        g.set("x", vec![1.0, 2.0]);
        assert_eq!(g.get("x").unwrap(), vec![1.0, 2.0]);
        g.set("x", vec![3.0]);
        assert_eq!(g.get("x").unwrap(), vec![3.0]);
    }

    #[test]
    fn top_two_sync_from_the_paper() {
        // The paper's PageRank example: second most popular page.
        struct TopTwo;
        impl SyncOp<f64> for TopTwo {
            fn key(&self) -> &str {
                "top2"
            }
            fn init(&self) -> Vec<f64> {
                vec![f64::NEG_INFINITY, f64::NEG_INFINITY]
            }
            fn fold(&self, acc: &mut Vec<f64>, _v: VertexId, d: &f64) {
                if *d > acc[0] {
                    acc[1] = acc[0];
                    acc[0] = *d;
                } else if *d > acc[1] {
                    acc[1] = *d;
                }
            }
            fn merge(&self, acc: &mut Vec<f64>, other: &[f64]) {
                for &x in other {
                    if x > acc[0] {
                        acc[1] = acc[0];
                        acc[0] = x;
                    } else if x > acc[1] {
                        acc[1] = x;
                    }
                }
            }
            fn finalize(&self, acc: Vec<f64>) -> Vec<f64> {
                vec![acc[1]]
            }
        }
        let op = TopTwo;
        let globals = GlobalValues::new();
        let data = [0.3, 0.9, 0.1, 0.7];
        run_syncs_local(
            &[Box::new(op) as Box<dyn SyncOp<f64>>],
            data.len(),
            |v| data[v as usize],
            &globals,
        );
        assert_eq!(globals.get("top2").unwrap(), vec![0.7]);
    }
}
