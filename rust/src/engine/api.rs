//! The unified execution API: one builder, one stats type, runtime engine
//! selection.
//!
//! The paper's core promise (Sec. 3, Sec. 4.2) is that a single update
//! function runs unchanged on the shared-memory runtime and on both
//! distributed engines. [`Engine`] is that promise as an API: pick an
//! [`EngineKind`] at runtime (e.g. from a `--engine` CLI flag via
//! `FromStr`), configure the run with builder methods, and call
//! [`Engine::run`] — the builder computes whatever the chosen engine needs
//! (a proper coloring for the chromatic engine, a vertex partition for the
//! distributed engines) and returns one [`Exec`] carrying the transformed
//! graph plus engine-independent [`ExecStats`].
//!
//! ```no_run
//! use graphlab::apps::{self, pagerank};
//! use graphlab::engine::{Engine, EngineKind};
//!
//! # fn main() -> anyhow::Result<()> {
//! let n = 1000;
//! let edges = graphlab::datagen::web_graph(n, 8, 1);
//! let g = pagerank::build(n, &edges, 0.15);
//! let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-6, n, use_pjrt: false };
//! let exec = Engine::new("chromatic".parse::<EngineKind>()?)
//!     .machines(4)
//!     .sync(pagerank::total_rank_sync())
//!     .max_sweeps(100)
//!     .run(g, &prog, apps::all_vertices(n))?;
//! println!("{} updates, {} sweeps", exec.stats.updates, exec.stats.sweeps);
//! # Ok(())
//! # }
//! ```

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Result};

use super::{chromatic, locking, shared, GlobalValues, SyncOp, VertexProgram};
use crate::distributed::snapshot::SnapshotCfg;
use crate::distributed::{
    ClusterConfig, DataValue, FaultPlan, NetworkModel, SnapshotTrigger, TransportKind,
};
use crate::graph::Graph;
use crate::partition::atoms::{AtomPlacement, AtomStore};
use crate::partition::{Coloring, Partition};
use crate::scheduler::{SchedSpec, Task};

/// Which execution engine runs the program (paper Sec. 4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// The UAI'10 multicore runtime: worker threads + per-vertex RW locks.
    Shared,
    /// The distributed color-stepped engine (Sec. 4.2.1).
    Chromatic,
    /// The distributed pipelined-locking engine (Sec. 4.2.2).
    Locking,
}

/// Every engine, in CLI listing order.
pub const ENGINE_KINDS: [EngineKind; 3] =
    [EngineKind::Shared, EngineKind::Chromatic, EngineKind::Locking];

impl EngineKind {
    /// Parse an engine name; unknown names are an error, not a panic.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "shared" => EngineKind::Shared,
            "chromatic" => EngineKind::Chromatic,
            "locking" => EngineKind::Locking,
            other => bail!("unknown engine '{other}' (shared|chromatic|locking)"),
        })
    }

    /// The CLI name of this engine.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Shared => "shared",
            EngineKind::Chromatic => "chromatic",
            EngineKind::Locking => "locking",
        }
    }

    /// Whether this engine runs on the in-process cluster (machines > 1).
    pub fn is_distributed(self) -> bool {
        !matches!(self, EngineKind::Shared)
    }
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        EngineKind::parse(s)
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Engine-independent statistics of one execution.
///
/// Per-machine vectors have one entry per machine; the shared-memory
/// engine reports a single machine with zeroed wire traffic (nothing
/// crosses a network there).
#[derive(Debug, Clone)]
pub struct ExecStats {
    /// Update-function executions, summed over machines.
    pub updates: u64,
    /// Engine epochs: color sweeps (chromatic), global sync epochs
    /// (locking), sync barriers (shared).
    pub sweeps: u64,
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Updates executed by each machine (load balance; len = machines).
    pub updates_per_machine: Vec<u64>,
    /// Measured wire bytes sent per machine — encoded frame lengths from
    /// the `wire` codec, not a size model (zeroed for shared).
    pub bytes_sent: Vec<u64>,
    /// Messages sent per machine (zeroed for shared).
    pub msgs_sent: Vec<u64>,
}

impl ExecStats {
    /// Machine count of the run.
    pub fn machines(&self) -> usize {
        self.updates_per_machine.len().max(1)
    }

    /// Total measured wire bytes across machines.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent.iter().sum()
    }

    /// Total messages across machines.
    pub fn total_msgs(&self) -> u64 {
        self.msgs_sent.iter().sum()
    }

    /// Update-load balance: max over machines divided by the mean
    /// (1.0 = perfectly balanced; 1.0 for empty runs).
    pub fn balance(&self) -> f64 {
        let n = self.updates_per_machine.len();
        if n == 0 || self.updates == 0 {
            return 1.0;
        }
        let max = *self.updates_per_machine.iter().max().unwrap() as f64;
        let mean = self.updates as f64 / n as f64;
        max / mean
    }

    /// Updates per wall-clock second.
    pub fn updates_per_sec(&self) -> f64 {
        self.updates as f64 / self.seconds.max(1e-9)
    }

    /// The stable machine-readable stats line the experiment lab ingests
    /// (`lab-metric k=v …`; parsed by `crate::lab::ingest`). One line of
    /// space-separated `key=value` pairs; per-machine vectors travel as
    /// `;`-joined number lists. This format is load-bearing — the run
    /// database is built from it — so treat any change as a schema bump.
    pub fn lab_metric_line(&self) -> String {
        let join = |v: &[u64]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(";")
        };
        let mut line = format!(
            "lab-metric updates={} sweeps={} seconds={:.6} updates_per_sec={:.1} \
             balance={:.4} machines={} bytes_sent={} msgs_sent={}",
            self.updates,
            self.sweeps,
            self.seconds,
            self.updates_per_sec(),
            self.balance(),
            self.machines(),
            self.total_bytes(),
            self.total_msgs(),
        );
        if !self.updates_per_machine.is_empty() {
            line.push_str(" updates_per_machine=");
            line.push_str(&join(&self.updates_per_machine));
        }
        if !self.bytes_sent.is_empty() {
            line.push_str(" bytes_per_machine=");
            line.push_str(&join(&self.bytes_sent));
        }
        line
    }
}

/// The result of an [`Engine::run`]: the transformed graph + statistics.
pub struct Exec<V, E> {
    /// The data graph after execution (all machine copies reconciled).
    pub graph: Graph<V, E>,
    /// Engine-independent run statistics.
    pub stats: ExecStats,
}

/// Progress callback: `(epoch, updates_so_far, globals)` at every engine
/// epoch (sweep / sync barrier).
type ProgressFn = Box<dyn Fn(u64, u64, &GlobalValues) + Send + Sync>;

/// Builder for one engine execution; see the [module docs](self) for an
/// end-to-end example.
///
/// Defaults: 4 workers, 2 machines, work-stealing FIFO scheduling, no
/// update/sweep caps, lock-pipelining depth 64, no periodic locking sync,
/// zero-latency in-process transport (swap in real loopback sockets with
/// [`Engine::transport`], or a real multi-process cluster with
/// [`Engine::cluster`]), seed 1. The coloring (chromatic) and partition
/// (distributed engines) are computed internally from the graph and the
/// program's consistency model unless overridden with
/// [`Engine::with_coloring`] / [`Engine::with_partition`].
pub struct Engine<V> {
    kind: EngineKind,
    workers: usize,
    machines: usize,
    sched: SchedSpec,
    syncs: Vec<Box<dyn SyncOp<V>>>,
    max_updates: u64,
    max_sweeps: u64,
    maxpending: usize,
    sync_period: Option<Duration>,
    network: NetworkModel,
    transport: TransportKind,
    cluster: Option<ClusterConfig>,
    seed: u64,
    coloring: Option<Coloring>,
    partition: Option<Partition>,
    atoms_dir: Option<PathBuf>,
    snapshot_every: Option<SnapshotTrigger>,
    snapshot_root: Option<PathBuf>,
    restore: Option<PathBuf>,
    fault: Option<FaultPlan>,
    pin_threads: bool,
    on_progress: Option<ProgressFn>,
}

impl<V> Engine<V> {
    /// A builder for `kind` with default configuration.
    pub fn new(kind: EngineKind) -> Self {
        Engine {
            kind,
            workers: 4,
            machines: 2,
            sched: SchedSpec::default(),
            syncs: Vec::new(),
            max_updates: u64::MAX,
            max_sweeps: u64::MAX,
            maxpending: 64,
            sync_period: None,
            network: NetworkModel::default(),
            transport: TransportKind::InProc,
            cluster: None,
            seed: 1,
            coloring: None,
            partition: None,
            atoms_dir: None,
            snapshot_every: None,
            snapshot_root: None,
            restore: None,
            fault: None,
            pin_threads: false,
            on_progress: None,
        }
    }

    /// The engine this builder targets.
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// Worker threads: the shared engine's thread count, or threads per
    /// machine on the distributed engines. On the locking engine, 1
    /// evaluates granted batches inline on the per-machine pump thread
    /// (the bit-deterministic sequential path); N > 1 adds a pool of N
    /// update-executor threads per machine fed by the lock pipeline (the
    /// paper's 8-cores-per-node deployment, Fig. 7).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// In-process machine count (distributed engines; ignored by shared).
    pub fn machines(mut self, m: usize) -> Self {
        self.machines = m.max(1);
        self
    }

    /// Task scheduling: queue policy + organization for the shared engine;
    /// the locking engine uses the spec's pop policy for its per-machine
    /// queue. The chromatic schedule is static (paper Sec. 3.4) and
    /// ignores this.
    pub fn scheduler(mut self, spec: SchedSpec) -> Self {
        self.sched = spec;
        self
    }

    /// Attach a sync operation (may be called repeatedly).
    pub fn sync(mut self, op: impl SyncOp<V> + 'static) -> Self {
        self.syncs.push(Box::new(op));
        self
    }

    /// Attach a batch of boxed sync operations.
    pub fn syncs(mut self, ops: Vec<Box<dyn SyncOp<V>>>) -> Self {
        self.syncs.extend(ops);
        self
    }

    /// Cap total update executions across machines (safety net for
    /// non-converging runs). The locking engine splits the cap into
    /// per-machine caps of `ceil(cap / machines)`, so it stops within
    /// `machines - 1` updates of the requested total; the chromatic
    /// engine's static schedule is capped in whole sweeps via
    /// [`Engine::max_sweeps`] instead and ignores this.
    pub fn max_updates(mut self, cap: u64) -> Self {
        self.max_updates = cap;
        self
    }

    /// Cap chromatic sweeps (ignored by the other engines, which are not
    /// sweep-structured).
    pub fn max_sweeps(mut self, cap: u64) -> Self {
        self.max_sweeps = cap;
        self
    }

    /// Locking engine: maximum transactions in flight per machine (lock
    /// pipelining depth, Fig. 8(b)).
    pub fn maxpending(mut self, depth: usize) -> Self {
        self.maxpending = depth;
        self
    }

    /// Locking engine: period of leader-initiated global sync barriers
    /// (default: syncs run only at termination).
    pub fn sync_period(mut self, period: Duration) -> Self {
        self.sync_period = Some(period);
        self
    }

    /// Network model for the in-process cluster (latency injection).
    /// The TCP transport ignores it — real wires have real latency.
    pub fn network(mut self, model: NetworkModel) -> Self {
        self.network = model;
        self
    }

    /// Which byte-level substrate carries the distributed engines'
    /// frames: [`TransportKind::InProc`] (channels, the default) or
    /// [`TransportKind::Tcp`] (a real loopback-socket mesh inside this
    /// process — same `Exec` result, actual kernel sockets under every
    /// frame). Ignored by the shared engine, which has no network.
    pub fn transport(mut self, kind: TransportKind) -> Self {
        self.transport = kind;
        self
    }

    /// Multi-process cluster mode: this process runs **only** machine
    /// `me` of `hosts.len()`, over TCP to the other worker processes
    /// (`hosts[i]` is machine `i`'s `host:port` listen address). Implies
    /// the TCP transport and overrides [`Engine::machines`] with
    /// `hosts.len()`.
    ///
    /// Every process must construct the identical graph and partition —
    /// route the run through an atom store ([`Engine::atoms_dir`], the
    /// paper's startup path) so placement is derived deterministically
    /// from `meta.bin` on every machine. The returned [`Exec`] is
    /// **local**: its graph carries authoritative data only for the
    /// vertices machine `me` owns (the rest keep their input values),
    /// and per-machine stats vectors are filled only in slot `me`.
    /// Global sync values (via [`Engine::sync`] / the progress callback)
    /// are still true cluster-wide reductions.
    pub fn cluster(mut self, me: usize, hosts: Vec<String>) -> Self {
        self.transport = TransportKind::Tcp;
        self.cluster = Some(ClusterConfig { me, hosts });
        self
    }

    /// Seed for the internally computed partition (chromatic) and the
    /// locking engine's randomized scheduler. The shared engine's queue
    /// randomness is seeded by the [`SchedSpec`] passed to
    /// [`Engine::scheduler`] (the spec travels with its own seed so a
    /// parsed `--scheduler` flag stays self-contained).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the internally computed coloring (chromatic engine). The
    /// coloring must discharge the program's consistency model (proper ⇒
    /// edge, distance-2 ⇒ full, uniform ⇒ vertex).
    pub fn with_coloring(mut self, coloring: Coloring) -> Self {
        self.coloring = Some(coloring);
        self
    }

    /// Override the internally computed vertex partition (distributed
    /// engines). Its machine count must match [`Engine::machines`];
    /// mismatches surface as an error from [`Engine::run`].
    pub fn with_partition(mut self, partition: Partition) -> Self {
        self.partition = Some(partition);
        self
    }

    /// Route the distributed engines through the on-disk atom store at
    /// `dir` (`graphlab partition <app> --atoms-dir` writes one): phase-2
    /// placement runs on the stored meta-graph and **each machine replays
    /// only its own atom journals** instead of slicing the in-memory
    /// graph. The graph passed to [`Engine::run`] must describe the same
    /// dataset (load it with [`crate::partition::atoms::load_graph`]) —
    /// it supplies the topology for result reassembly; vertex/edge data
    /// enters the machines from disk. Mutually exclusive with
    /// [`Engine::with_partition`] (the store's atom placement *is* the
    /// partition); ignored by the shared engine, which has no machine
    /// load step.
    pub fn atoms_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.atoms_dir = Some(dir.into());
        self
    }

    /// Cut a Chandy–Lamport snapshot whenever `trigger` fires (paper Sec.
    /// 4.3): every `k` updates or every `d` seconds, the leader injects a
    /// token and each machine writes its part of a consistent cut to
    /// `snapshot_<epoch>/` under the snapshot root ([`Engine::snapshot_to`],
    /// defaulting to the atom-store dir). Distributed engines only. On the
    /// locking engine the update-count trigger fires on the *leader's*
    /// local counter — approximate, roughly `machines×` the flag value
    /// cluster-wide.
    pub fn snapshot_every(mut self, trigger: SnapshotTrigger) -> Self {
        self.snapshot_every = Some(trigger);
        self
    }

    /// Directory that holds `snapshot_<epoch>/` directories. Defaults to
    /// the atom-store dir ([`Engine::atoms_dir`]); required if snapshots
    /// are enabled without one.
    pub fn snapshot_to(mut self, dir: impl Into<PathBuf>) -> Self {
        self.snapshot_root = Some(dir.into());
        self
    }

    /// Recovery (paper Sec. 4.3): after the local graphs are built (from
    /// the in-memory graph or atom journals), overlay the newest
    /// *complete* `snapshot_<epoch>/` under `dir`, version-gated per
    /// record. Torn or partial snapshot directories are skipped; if no
    /// complete snapshot exists the run proceeds from the journals alone.
    /// Distributed engines only.
    pub fn restore_from(mut self, dir: impl Into<PathBuf>) -> Self {
        self.restore = Some(dir.into());
        self
    }

    /// Wrap every machine's transport in a [`crate::distributed::Faulty`]
    /// decorator executing this seeded fault plan (kill/drop/duplicate/
    /// delay/sever) — deterministic failure injection for tests.
    /// Distributed engines only.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Pin each distributed-engine machine loop to a CPU
    /// (`machine_id % available_cpus`) so hot event loops stop migrating
    /// between cores mid-run. Best-effort (shells out to `taskset`; a
    /// failed pin is a no-op) and off by default. Ignored by the shared
    /// engine, whose workers are pool threads, not per-machine loops.
    pub fn pin_threads(mut self, on: bool) -> Self {
        self.pin_threads = on;
        self
    }

    /// Progress callback `(epoch, updates_so_far, globals)` invoked at
    /// every engine epoch (chromatic sweep, locking sync barrier, shared
    /// sync barrier).
    pub fn on_progress(
        mut self,
        cb: impl Fn(u64, u64, &GlobalValues) + Send + Sync + 'static,
    ) -> Self {
        self.on_progress = Some(Box::new(cb));
        self
    }

    /// Execute `program` over `graph` from the `initial` task set on the
    /// configured engine. Consumes the builder (sync operations and
    /// callbacks move into the run).
    pub fn run<E, P>(
        mut self,
        graph: Graph<V, E>,
        program: &P,
        initial: Vec<Task>,
    ) -> Result<Exec<V, E>>
    where
        V: DataValue,
        E: DataValue,
        P: VertexProgram<V, E>,
    {
        let n = graph.num_vertices();
        // Snapshots, restore and fault injection all live in the
        // distributed substrate — meaningless on the shared engine.
        if !self.kind.is_distributed()
            && (self.snapshot_every.is_some() || self.restore.is_some() || self.fault.is_some())
        {
            bail!(
                "snapshot/restore/fault-plan need a distributed engine \
                 (chromatic|locking), not shared"
            );
        }
        let snapshot = match self.snapshot_every {
            None => None,
            Some(trigger) => {
                let root = match self.snapshot_root.take().or_else(|| self.atoms_dir.clone()) {
                    Some(r) => r,
                    None => bail!(
                        "snapshot_every needs a snapshot root: set snapshot_to \
                         (--snapshot-dir) or atoms_dir (--atoms-dir)"
                    ),
                };
                Some(SnapshotCfg { root, trigger })
            }
        };
        // Cluster mode: the hosts file is the authority on cluster size.
        if let Some(c) = &self.cluster {
            if !self.kind.is_distributed() {
                bail!("cluster mode needs a distributed engine (chromatic|locking), not shared");
            }
            if c.me >= c.hosts.len() {
                bail!(
                    "cluster machine id {} out of range for {} hosts",
                    c.me,
                    c.hosts.len()
                );
            }
            self.machines = c.hosts.len();
        }
        // Disk path: open the atom store once, place atoms on machines
        // (phase 2 over the stored meta-graph), and derive the vertex
        // partition from that placement so the engines and the per-machine
        // journal replays agree on ownership.
        let atoms = match (&self.atoms_dir, self.kind.is_distributed()) {
            (Some(dir), true) => {
                if self.partition.is_some() {
                    bail!(
                        "atoms_dir and with_partition are mutually exclusive: \
                         the atom placement determines the partition"
                    );
                }
                let store = AtomStore::open(dir)?;
                if store.num_vertices != n {
                    bail!(
                        "atom store {} holds {} vertices but the graph has {n}",
                        dir.display(),
                        store.num_vertices
                    );
                }
                Some(store.place(self.machines))
            }
            _ => None,
        };
        match self.kind {
            EngineKind::Shared => {
                // Adapt the unified (epoch, updates, globals) callback to
                // the shared engine's (updates, globals) sync hook by
                // counting barriers.
                let on_sync = self.on_progress.map(|cb| {
                    let barrier = AtomicU64::new(0);
                    Box::new(move |updates: u64, globals: &GlobalValues| {
                        let epoch = barrier.fetch_add(1, Ordering::Relaxed) + 1;
                        cb(epoch, updates, globals)
                    }) as Box<dyn Fn(u64, &GlobalValues) + Send + Sync>
                });
                let (graph, stats) = shared::run(
                    graph,
                    program,
                    initial,
                    self.syncs,
                    self.sched,
                    shared::SharedOpts {
                        workers: self.workers,
                        max_updates: self.max_updates,
                        on_sync,
                    },
                );
                Ok(Exec { graph, stats })
            }
            EngineKind::Chromatic => {
                let coloring = match self.coloring {
                    Some(c) => c,
                    None => chromatic::color_for(&graph, program.consistency()),
                };
                let (partition, placement) = split_placement(atoms, || match self.partition {
                    Some(p) => p,
                    None => Partition::random(n, self.machines, self.seed),
                });
                let (graph, stats) = chromatic::run(
                    graph,
                    &coloring,
                    &partition,
                    program,
                    initial,
                    self.syncs,
                    chromatic::ChromaticOpts {
                        machines: self.machines,
                        threads_per_machine: self.workers,
                        max_sweeps: self.max_sweeps,
                        network: self.network,
                        transport: self.transport,
                        cluster: self.cluster,
                        on_sweep: self.on_progress,
                        atoms: placement,
                        snapshot,
                        restore: self.restore,
                        fault: self.fault,
                        pin_threads: self.pin_threads,
                    },
                )?;
                Ok(Exec { graph, stats })
            }
            EngineKind::Locking => {
                let (partition, placement) = split_placement(atoms, || match self.partition {
                    Some(p) => p,
                    None => Partition::blocked(n, self.machines),
                });
                // Ceiling split: never silently undershoots the requested
                // total (overshoot is bounded by machines - 1 updates).
                let per_machine_cap = if self.max_updates == u64::MAX {
                    u64::MAX
                } else {
                    self.max_updates.div_ceil(self.machines as u64)
                };
                let (graph, stats) = locking::run(
                    graph,
                    &partition,
                    program,
                    initial,
                    self.syncs,
                    locking::LockingOpts {
                        machines: self.machines,
                        maxpending: self.maxpending,
                        threads: self.workers,
                        scheduler: self.sched.policy,
                        network: self.network,
                        transport: self.transport,
                        cluster: self.cluster,
                        sync_period: self.sync_period,
                        max_updates_per_machine: per_machine_cap,
                        on_sync: self.on_progress,
                        seed: self.seed,
                        atoms: placement,
                        snapshot,
                        restore: self.restore,
                        fault: self.fault,
                        pin_threads: self.pin_threads,
                    },
                )?;
                Ok(Exec { graph, stats })
            }
        }
    }
}

/// Unzip the optional atoms placement, falling back to the in-memory
/// partition when no atom store is in play.
fn split_placement(
    atoms: Option<(Partition, AtomPlacement)>,
    fallback: impl FnOnce() -> Partition,
) -> (Partition, Option<AtomPlacement>) {
    match atoms {
        Some((partition, placement)) => (partition, Some(placement)),
        None => (fallback(), None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_names_round_trip() {
        // Rejection of unknown names is covered by the integration test in
        // rust/tests/engine_equivalence.rs.
        for kind in ENGINE_KINDS {
            assert_eq!(kind.name().parse::<EngineKind>().unwrap(), kind);
            assert_eq!(format!("{kind}"), kind.name());
        }
    }

    #[test]
    fn exec_stats_derived_metrics() {
        let stats = ExecStats {
            updates: 100,
            sweeps: 3,
            seconds: 2.0,
            updates_per_machine: vec![70, 30],
            bytes_sent: vec![10, 20],
            msgs_sent: vec![1, 2],
        };
        assert_eq!(stats.machines(), 2);
        assert_eq!(stats.total_bytes(), 30);
        assert_eq!(stats.total_msgs(), 3);
        assert!((stats.balance() - 1.4).abs() < 1e-12);
        assert!((stats.updates_per_sec() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn mismatched_config_is_an_error_not_a_panic() {
        struct Noop;
        impl VertexProgram<u64, ()> for Noop {
            fn update(
                &self,
                _scope: &mut crate::engine::Scope<u64, ()>,
                _ctx: &mut crate::engine::Ctx,
            ) {
            }
        }
        fn ring8() -> Graph<u64, ()> {
            let mut b = crate::graph::GraphBuilder::new();
            b.add_vertices(8, |_| 0u64);
            for i in 0..8u32 {
                b.add_edge(i, (i + 1) % 8, ());
            }
            b.build()
        }
        // 3-machine partition on a 2-machine engine: must surface as Err.
        let res = Engine::new(EngineKind::Locking)
            .machines(2)
            .with_partition(Partition::blocked(8, 3))
            .run(ring8(), &Noop, vec![]);
        assert!(res.is_err());
        let res = Engine::new(EngineKind::Chromatic)
            .machines(4)
            .with_partition(Partition::blocked(8, 2))
            .run(ring8(), &Noop, vec![]);
        assert!(res.is_err());
        // Coloring built for a different (smaller) graph: Err, not an
        // index panic inside a machine thread.
        let small = {
            let mut b = crate::graph::GraphBuilder::new();
            b.add_vertices(4, |_| 0u64);
            b.add_edge(0, 1, ());
            b.build()
        };
        let res = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .with_coloring(Coloring::greedy(&small))
            .run(ring8(), &Noop, vec![]);
        assert!(res.is_err());
        // Snapshot/restore/fault are distributed-substrate features: the
        // shared engine must reject them, not silently ignore them.
        let res = Engine::new(EngineKind::Shared)
            .snapshot_every(SnapshotTrigger::Updates(10))
            .run(ring8(), &Noop, vec![]);
        assert!(res.unwrap_err().to_string().contains("distributed engine"));
        let res = Engine::new(EngineKind::Shared)
            .fault_plan(FaultPlan::kill_at(0, 1))
            .run(ring8(), &Noop, vec![]);
        assert!(res.is_err());
        // Snapshots need somewhere to live: no snapshot_to, no atoms_dir.
        let res = Engine::new(EngineKind::Locking)
            .machines(2)
            .snapshot_every(SnapshotTrigger::Updates(10))
            .run(ring8(), &Noop, vec![]);
        assert!(res.unwrap_err().to_string().contains("snapshot root"));
    }
}
