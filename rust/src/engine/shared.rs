//! The shared-memory GraphLab engine (paper Sec. 4.2.2, first half).
//!
//! This is the multicore runtime of the original UAI'10 GraphLab that the
//! distributed engines build on: worker threads pull tasks from per-worker
//! schedulers (stealing from victims when their own queue runs dry — see
//! [`crate::scheduler::WorkStealing`]), acquire the per-vertex
//! reader–writer locks demanded by the consistency model (always in
//! ascending vertex order — deadlock-free), evaluate the update function,
//! release, repeat. Sync operations are triggered by a global update
//! counter and run under a stop-the-world barrier, exactly as described in
//! the paper.
//!
//! The queue organization is selected by [`SchedSpec`]: the default is
//! work stealing; `SchedSpec::global` keeps the original single
//! mutex-guarded queue as an A/B baseline (`--scheduler global-fifo` on
//! the CLI, swept by `graphlab bench-sched`).
//!
//! The engine is also the *sequential oracle* for the distributed engines'
//! equivalence tests (`workers = 1` gives a fully deterministic run: one
//! local queue, no stealing, no randomness).

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::{Consistency, Ctx, ExecStats, GlobalValues, Scope, SyncOp, VertexProgram};
use crate::graph::{Graph, VertexId};
use crate::scheduler::{SchedSpec, Scheduler, Task, WorkStealing};
use crate::util::Rng;

/// Options for a shared-memory run (crate-internal: external callers go
/// through the `engine::Engine` builder).
pub(crate) struct SharedOpts {
    /// Worker thread count.
    pub workers: usize,
    /// Hard cap on update executions (safety net for non-converging runs).
    pub max_updates: u64,
    /// Callback invoked after every sync barrier (figure harness probes).
    #[allow(clippy::type_complexity)]
    pub on_sync: Option<Box<dyn Fn(u64, &GlobalValues) + Send + Sync>>,
}

impl Default for SharedOpts {
    fn default() -> Self {
        SharedOpts {
            workers: 4,
            max_updates: u64::MAX,
            on_sync: None,
        }
    }
}

// ---------------------------------------------------------------------------
// Per-vertex reader-writer spinlocks
// ---------------------------------------------------------------------------

const WRITER: u32 = 1 << 31;

/// Array of reader–writer spinlocks, one per vertex.
pub(crate) struct VertexLocks {
    locks: Vec<AtomicU32>,
}

impl VertexLocks {
    pub(crate) fn new(n: usize) -> Self {
        VertexLocks {
            locks: (0..n).map(|_| AtomicU32::new(0)).collect(),
        }
    }

    #[inline]
    pub(crate) fn lock_read(&self, v: VertexId) {
        let l = &self.locks[v as usize];
        loop {
            let cur = l.load(Ordering::Relaxed);
            if cur & WRITER == 0
                && l.compare_exchange_weak(cur, cur + 1, Ordering::Acquire, Ordering::Relaxed)
                    .is_ok()
            {
                return;
            }
            std::hint::spin_loop();
        }
    }

    #[inline]
    pub(crate) fn unlock_read(&self, v: VertexId) {
        self.locks[v as usize].fetch_sub(1, Ordering::Release);
    }

    #[inline]
    pub(crate) fn lock_write(&self, v: VertexId) {
        let l = &self.locks[v as usize];
        loop {
            if l.compare_exchange_weak(0, WRITER, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
            std::hint::spin_loop();
        }
    }

    #[inline]
    pub(crate) fn unlock_write(&self, v: VertexId) {
        self.locks[v as usize].store(0, Ordering::Release);
    }
}

/// The lock plan for one scope under a consistency model: vertices in
/// ascending order, each tagged write(true)/read(false).
pub(crate) fn scope_lock_plan(
    center: VertexId,
    neighbors: impl Iterator<Item = VertexId>,
    consistency: Consistency,
    out: &mut Vec<(VertexId, bool)>,
) {
    out.clear();
    match consistency {
        Consistency::Unsafe => {}
        Consistency::Vertex => out.push((center, true)),
        Consistency::Edge => {
            out.push((center, true));
            for u in neighbors {
                out.push((u, false));
            }
            out.sort_unstable_by_key(|&(v, _)| v);
        }
        Consistency::Full => {
            out.push((center, true));
            for u in neighbors {
                out.push((u, true));
            }
            out.sort_unstable_by_key(|&(v, _)| v);
        }
    }
}

// ---------------------------------------------------------------------------
// Stop-the-world sync gate
// ---------------------------------------------------------------------------

struct GateState {
    pausing: bool,
    parked: usize,
    exited: usize,
}

struct SyncGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

impl SyncGate {
    fn new() -> Self {
        SyncGate {
            state: Mutex::new(GateState {
                pausing: false,
                parked: 0,
                exited: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Called by the sync initiator: park all other live workers, run `f`,
    /// resume. `others` = worker count - 1; workers that have exited count
    /// as permanently parked.
    fn stop_the_world(&self, others: usize, f: impl FnOnce()) {
        let mut st = self.state.lock().unwrap();
        st.pausing = true;
        self.cv.notify_all();
        while st.parked + st.exited < others {
            st = self.cv.wait(st).unwrap();
        }
        drop(st);
        f();
        let mut st = self.state.lock().unwrap();
        st.pausing = false;
        self.cv.notify_all();
    }

    /// Called by workers at loop top: if a sync is pending, park until done.
    fn checkpoint(&self) {
        let mut st = self.state.lock().unwrap();
        if !st.pausing {
            return;
        }
        st.parked += 1;
        self.cv.notify_all();
        while st.pausing {
            st = self.cv.wait(st).unwrap();
        }
        st.parked -= 1;
        self.cv.notify_all();
    }

    /// Called once by each worker on exit so pending barriers don't wait
    /// for it.
    fn retire(&self) {
        let mut st = self.state.lock().unwrap();
        st.exited += 1;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Task queue facade: work-stealing (default) or single global queue
// ---------------------------------------------------------------------------

/// The engine's view of its task queues; both variants share the
/// outstanding-work termination contract (`pop` → execute → `publish` →
/// `done`, with `drained()` true only once no task is queued or in
/// flight).
enum TaskQueue {
    /// One mutex-guarded queue shared by every worker (the contended
    /// baseline). `in_flight` is incremented under the queue mutex.
    Global {
        sched: Mutex<Box<dyn Scheduler>>,
        in_flight: AtomicUsize,
    },
    /// Per-worker queues + stealing; `WorkStealing` tracks queued and
    /// in-flight work in one counter.
    Stealing(WorkStealing),
}

impl TaskQueue {
    fn new(spec: SchedSpec, num_vertices: usize, workers: usize, initial: Vec<Task>) -> Self {
        if spec.work_stealing {
            let ws = WorkStealing::new(spec.policy, num_vertices, workers, spec.seed);
            // Deal initial tasks round-robin so every worker starts with
            // local work (with one worker this preserves exact order).
            for (i, t) in initial.into_iter().enumerate() {
                ws.push(i % workers, t);
            }
            TaskQueue::Stealing(ws)
        } else {
            let mut sched = spec.policy.build(num_vertices, spec.seed);
            for t in initial {
                sched.push(t);
            }
            TaskQueue::Global {
                sched: Mutex::new(sched),
                in_flight: AtomicUsize::new(0),
            }
        }
    }

    fn pop(&self, worker: usize, rng: &mut Rng) -> Option<Task> {
        match self {
            TaskQueue::Global { sched, in_flight } => {
                let mut s = sched.lock().unwrap();
                let t = s.pop();
                if t.is_some() {
                    // Inside the lock: an observer that pops None afterwards
                    // is guaranteed to see this increment.
                    in_flight.fetch_add(1, Ordering::SeqCst);
                }
                t
            }
            TaskQueue::Stealing(ws) => ws.pop(worker, rng),
        }
    }

    /// Publish follow-up tasks scheduled by an update (before `done`).
    fn publish(&self, worker: usize, tasks: &mut Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        match self {
            TaskQueue::Global { sched, .. } => {
                let mut s = sched.lock().unwrap();
                for t in tasks.drain(..) {
                    s.push(t);
                }
            }
            TaskQueue::Stealing(ws) => {
                for t in tasks.drain(..) {
                    ws.push(worker, t);
                }
            }
        }
    }

    /// Retire a popped task (update executed — or abandoned at the cap).
    fn done(&self) {
        match self {
            TaskQueue::Global { in_flight, .. } => {
                in_flight.fetch_sub(1, Ordering::SeqCst);
            }
            TaskQueue::Stealing(ws) => ws.task_done(),
        }
    }

    /// True once no task is queued or in flight. Only meaningful right
    /// after a failed `pop` (both variants then guarantee no work can
    /// reappear without a new push, and no pusher survives quiescence).
    fn drained(&self) -> bool {
        match self {
            TaskQueue::Global { in_flight, .. } => in_flight.load(Ordering::SeqCst) == 0,
            TaskQueue::Stealing(ws) => ws.outstanding() == 0,
        }
    }

    /// Wait a beat before re-polling: yield (global) or park on the idle
    /// condvar (stealing).
    fn idle_wait(&self) {
        match self {
            TaskQueue::Global { .. } => std::thread::yield_now(),
            TaskQueue::Stealing(ws) => ws.park(),
        }
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Run `program` over `graph` starting from `initial` tasks, with sync
/// operations `syncs`, using the shared-memory engine. Returns the
/// transformed graph and run statistics (paper Alg. 2 semantics).
/// `spec` selects the scheduling policy and queue organization.
/// `ExecStats::sweeps` counts sync barriers; wire traffic is zeroed
/// (nothing crosses a network here).
pub(crate) fn run<V, E, P>(
    graph: Graph<V, E>,
    program: &P,
    initial: Vec<Task>,
    syncs: Vec<Box<dyn SyncOp<V>>>,
    spec: SchedSpec,
    opts: SharedOpts,
) -> (Graph<V, E>, ExecStats)
where
    V: Clone + Send + Sync + 'static,
    E: Send + Sync + 'static,
    P: VertexProgram<V, E>,
{
    let start = std::time::Instant::now();
    let (vdata, edata, topo) = graph.into_parts();
    let n = vdata.len();
    let vstore = crate::graph::SharedStore::new(vdata);
    let estore = crate::graph::SharedStore::new(edata);
    let locks = VertexLocks::new(n);
    let globals = GlobalValues::new();
    let consistency = program.consistency();

    let workers = opts.workers.max(1);
    let queue = TaskQueue::new(spec, n, workers, initial);
    let updates = AtomicU64::new(0);
    let syncs_run = AtomicU64::new(0);
    let gate = SyncGate::new();
    let stop = AtomicBool::new(false);

    // Interval-triggered syncs: smallest positive interval wins the trigger;
    // interval-0 syncs run only at termination.
    let min_interval = syncs
        .iter()
        .map(|s| s.interval())
        .filter(|&i| i > 0)
        .min()
        .unwrap_or(0);
    let next_sync = AtomicU64::new(if min_interval == 0 {
        u64::MAX
    } else {
        min_interval
    });

    let run_all_syncs = |upd: u64| {
        for op in &syncs {
            let mut acc = op.init();
            for v in 0..n as VertexId {
                // SAFETY: stop-the-world or post-termination — no writers.
                op.fold(&mut acc, v, unsafe { vstore.get(v as usize) });
            }
            globals.set(op.key(), op.finalize(acc));
        }
        syncs_run.fetch_add(1, Ordering::Relaxed);
        if let Some(cb) = &opts.on_sync {
            cb(upd, &globals);
        }
    };

    crate::util::ThreadPool::new(workers).scope_execute(|w| {
        let mut scope: Scope<V, E> = Scope::new_buffer(consistency);
        let mut plan: Vec<(VertexId, bool)> = Vec::new();
        let mut ctx = Ctx::new(&globals);
        // Per-worker stream for steal-victim selection (never consulted
        // with a single worker — the deterministic-oracle contract).
        let mut rng = Rng::new(0x5EED ^ w as u64);
        loop {
            gate.checkpoint();
            if stop.load(Ordering::Relaxed) {
                break;
            }

            // Interval sync trigger.
            let upd = updates.load(Ordering::Relaxed);
            if min_interval > 0 {
                let ns = next_sync.load(Ordering::Relaxed);
                if upd >= ns
                    && next_sync
                        .compare_exchange(ns, ns + min_interval, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                {
                    gate.stop_the_world(workers - 1, || run_all_syncs(upd));
                    continue;
                }
            }
            // Pull a task: local queue first, then steal (or the global
            // queue in baseline mode).
            let Some(task) = queue.pop(w, &mut rng) else {
                if queue.drained() {
                    break;
                }
                queue.idle_wait();
                continue;
            };
            if updates.load(Ordering::Relaxed) >= opts.max_updates {
                queue.done();
                stop.store(true, Ordering::Relaxed);
                break;
            }
            let v = task.vertex;
            // Acquire scope locks in ascending vertex order.
            scope_lock_plan(
                v,
                topo.adj[topo.adj_offsets[v as usize] as usize
                    ..topo.adj_offsets[v as usize + 1] as usize]
                    .iter()
                    .map(|&(u, _)| u),
                consistency,
                &mut plan,
            );
            for &(u, write) in &plan {
                if write {
                    locks.lock_write(u);
                } else {
                    locks.lock_read(u);
                }
            }
            // Assemble the scope and run the update.
            // SAFETY: the acquired locks guarantee the consistency model's
            // aliasing discipline (property-tested in rust/tests/).
            unsafe {
                scope.reset(v, vstore.get_mut(v as usize) as *mut V);
                for &(u, e) in &topo.adj[topo.adj_offsets[v as usize] as usize
                    ..topo.adj_offsets[v as usize + 1] as usize]
                {
                    scope.push_neighbor(
                        u,
                        e,
                        vstore.get_mut(u as usize) as *mut V,
                        estore.get_mut(e as usize) as *mut E,
                    );
                }
            }
            ctx.set_updates_hint(updates.load(Ordering::Relaxed));
            program.update(&mut scope, &mut ctx);
            for &(u, write) in plan.iter().rev() {
                if write {
                    locks.unlock_write(u);
                } else {
                    locks.unlock_read(u);
                }
            }
            updates.fetch_add(1, Ordering::Relaxed);
            // Publish newly scheduled tasks, then retire (publishing first
            // keeps the outstanding-work count from reaching zero early).
            queue.publish(w, &mut ctx.scheduled);
            queue.done();
        }
        // Count this worker as permanently parked for pending barriers.
        gate.retire();
    });

    // Terminal sync pass (interval-0 syncs and final refresh).
    run_all_syncs(updates.load(Ordering::Relaxed));

    let total_updates = updates.load(Ordering::Relaxed);
    let stats = ExecStats {
        updates: total_updates,
        sweeps: syncs_run.load(Ordering::Relaxed),
        seconds: start.elapsed().as_secs_f64(),
        updates_per_machine: vec![total_updates],
        bytes_sent: vec![0],
        msgs_sent: vec![0],
    };
    let graph = Graph::from_parts(vstore.into_vec(), estore.into_vec(), topo);
    (graph, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::scheduler::Policy;

    /// Each vertex stores a counter; the update increments the center and
    /// schedules neighbors until a hop budget (stored per vertex) runs out.
    struct Propagate;
    impl VertexProgram<(u64, u32), ()> for Propagate {
        fn consistency(&self) -> Consistency {
            Consistency::Edge
        }
        fn update(&self, scope: &mut Scope<(u64, u32), ()>, ctx: &mut Ctx) {
            let (count, budget) = *scope.center();
            scope.center_mut().0 = count + 1;
            if budget > 0 {
                scope.center_mut().1 = budget - 1;
                for i in 0..scope.degree() {
                    ctx.schedule(scope.nbr_id(i), 0.0);
                }
            }
        }
    }

    fn ring(n: usize) -> Graph<(u64, u32), ()> {
        let mut b = GraphBuilder::new();
        b.add_vertices(n, |_| (0, 2));
        for i in 0..n {
            b.add_edge(i as VertexId, ((i + 1) % n) as VertexId, ());
        }
        b.build()
    }

    #[test]
    fn runs_to_quiescence() {
        let g = ring(64);
        let initial = vec![Task {
            vertex: 0,
            priority: 0.0,
        }];
        let (g, stats) = run(
            g,
            &Propagate,
            initial,
            vec![],
            SchedSpec::ws(Policy::Fifo, 1),
            SharedOpts {
                workers: 4,
                ..Default::default()
            },
        );
        assert!(stats.updates > 0);
        // Vertex 0 must have been updated at least once.
        assert!(g.vertex_data(0).0 >= 1);
    }

    #[test]
    fn single_worker_equals_multi_worker_total_for_counter_app() {
        // Total update count is schedule-dependent for dynamic apps, so use
        // a static one: every vertex scheduled once, no rescheduling.
        struct Inc;
        impl VertexProgram<(u64, u32), ()> for Inc {
            fn update(&self, scope: &mut Scope<(u64, u32), ()>, _ctx: &mut Ctx) {
                scope.center_mut().0 += 1;
            }
        }
        for workers in [1, 4] {
            let g = ring(128);
            let initial: Vec<Task> = (0..128)
                .map(|v| Task {
                    vertex: v,
                    priority: 0.0,
                })
                .collect();
            let (g, stats) = run(
                g,
                &Inc,
                initial,
                vec![],
                SchedSpec::ws(Policy::Fifo, 1),
                SharedOpts {
                    workers,
                    ..Default::default()
                },
            );
            assert_eq!(stats.updates, 128);
            assert!(g.vertex_ids().all(|v| g.vertex_data(v).0 == 1));
        }
    }

    #[test]
    fn max_updates_bounds_execution() {
        struct Forever;
        impl VertexProgram<(u64, u32), ()> for Forever {
            fn update(&self, scope: &mut Scope<(u64, u32), ()>, ctx: &mut Ctx) {
                let v = scope.vertex();
                ctx.schedule(v, 0.0);
            }
        }
        let g = ring(8);
        let initial = vec![Task {
            vertex: 0,
            priority: 0.0,
        }];
        let (_g, stats) = run(
            g,
            &Forever,
            initial,
            vec![],
            SchedSpec::ws(Policy::Fifo, 1),
            SharedOpts {
                workers: 2,
                max_updates: 100,
                ..Default::default()
            },
        );
        assert!(stats.updates <= 110, "updates={}", stats.updates);
    }

    #[test]
    fn interval_syncs_fire_and_publish() {
        use crate::engine::sync::FnSync;
        struct Inc;
        impl VertexProgram<(u64, u32), ()> for Inc {
            fn update(&self, scope: &mut Scope<(u64, u32), ()>, _ctx: &mut Ctx) {
                scope.center_mut().0 += 1;
            }
        }
        let fired = std::sync::Arc::new(AtomicU64::new(0));
        let fired2 = fired.clone();
        let g = ring(256);
        let initial: Vec<Task> = (0..256)
            .map(|v| Task {
                vertex: v,
                priority: 0.0,
            })
            .collect();
        let sync: FnSync<(u64, u32)> = FnSync::new(
            "total",
            vec![0.0],
            64,
            |acc, _v, d: &(u64, u32)| acc[0] += d.0 as f64,
            |acc| acc,
        );
        let (_g, stats) = run(
            g,
            &Inc,
            initial,
            vec![Box::new(sync)],
            SchedSpec::ws(Policy::Fifo, 1),
            SharedOpts {
                workers: 4,
                max_updates: u64::MAX,
                on_sync: Some(Box::new(move |_u, g| {
                    fired2.fetch_add(1, Ordering::Relaxed);
                    assert!(g.get("total").is_some());
                })),
            },
        );
        // At least the terminal sync plus some interval syncs.
        assert!(stats.sweeps >= 2, "syncs={}", stats.sweeps);
        assert!(fired.load(Ordering::Relaxed) == stats.sweeps);
    }

    #[test]
    fn every_queue_mode_and_policy_runs_to_quiescence() {
        struct Inc;
        impl VertexProgram<(u64, u32), ()> for Inc {
            fn update(&self, scope: &mut Scope<(u64, u32), ()>, _ctx: &mut Ctx) {
                scope.center_mut().0 += 1;
            }
        }
        for policy in crate::scheduler::POLICIES {
            for spec in [SchedSpec::ws(policy, 3), SchedSpec::global(policy, 3)] {
                let g = ring(96);
                let initial: Vec<Task> = (0..96)
                    .map(|v| Task { vertex: v, priority: v as f64 })
                    .collect();
                let (g, stats) = run(
                    g,
                    &Inc,
                    initial,
                    vec![],
                    spec,
                    SharedOpts {
                        workers: 4,
                        ..Default::default()
                    },
                );
                assert_eq!(stats.updates, 96, "{}", spec.name());
                assert!(
                    g.vertex_ids().all(|v| g.vertex_data(v).0 == 1),
                    "{}",
                    spec.name()
                );
            }
        }
    }

    #[test]
    fn dynamic_propagation_quiesces_under_stealing() {
        // Dynamic rescheduling (the Propagate app) exercises the
        // outstanding-work termination check: the run may only end once no
        // task is queued or in flight anywhere.
        for workers in [1, 2, 8] {
            let g = ring(64);
            let initial = vec![Task { vertex: 0, priority: 0.0 }];
            let (g, stats) = run(
                g,
                &Propagate,
                initial,
                vec![],
                SchedSpec::ws(Policy::Fifo, 7),
                SharedOpts {
                    workers,
                    ..Default::default()
                },
            );
            // Hop budget 2 from vertex 0 reaches at least 0,1,2 (dedup can
            // merge re-schedules, so only lower-bound the count).
            assert!(stats.updates >= 3, "workers={workers}: {}", stats.updates);
            assert!(g.vertex_data(0).0 >= 1);
        }
    }
}
