//! The GraphLab abstraction: update functions, scopes, sync operations,
//! consistency models (paper Sec. 3), and the engines that execute them
//! (paper Sec. 4.2).
//!
//! * [`VertexProgram`] — the user's **update function**
//!   `(v, S_v) -> (S_v, T)`: it mutates the scope and schedules new tasks
//!   through [`Ctx`].
//! * [`Scope`] — the data of vertex `v`, its adjacent edges and vertices,
//!   with access rights determined by the [`Consistency`] model.
//! * [`SyncOp`] — the **sync operation** `(Key, Fold, Merge, Finalize,
//!   acc(0), tau)` maintaining global aggregates readable from updates.
//! * [`Engine`] — the unified execution API: pick an [`EngineKind`]
//!   ([`shared`], the multicore runtime of the UAI'10 paper that
//!   Distributed GraphLab builds on, or the two distributed engines of
//!   Sec. 4.2, [`chromatic`] and [`locking`]) at runtime, configure with
//!   builder methods, and get back one [`Exec`] with engine-independent
//!   [`ExecStats`]. The per-engine `run` functions are crate-internal
//!   implementation details behind this builder.

pub mod api;
pub mod chromatic;
pub mod locking;
pub mod shared;
pub mod sync;

pub use api::{Engine, EngineKind, Exec, ExecStats, ENGINE_KINDS};
pub use sync::{GlobalValues, SyncOp};

use crate::graph::{EdgeId, VertexId};
use crate::scheduler::Task;

/// Sequential-consistency models (paper Sec. 3.5, Fig. 3).
///
/// `Unsafe` is the paper's "adventurous user" escape hatch (end of Sec.
/// 3.5): no exclusion at all. It exists to reproduce Fig. 1's
/// consistent-vs-inconsistent ALS comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Consistency {
    /// Write center vertex only; read adjacent edges. Map-like parallelism.
    Vertex,
    /// Write center vertex + adjacent edges; read adjacent vertices.
    Edge,
    /// Write the entire scope (center, adjacent edges and vertices).
    Full,
    /// No consistency guarantee (races allowed) — for Fig. 1 only.
    Unsafe,
}

impl Consistency {
    /// Parse from a CLI/config string; unknown input is an error, not a
    /// panic (CLI misuse surfaces as a clean `bail!` at the boundary).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "vertex" => Consistency::Vertex,
            "edge" => Consistency::Edge,
            "full" => Consistency::Full,
            "unsafe" | "none" => Consistency::Unsafe,
            other => anyhow::bail!("unknown consistency '{other}' (vertex|edge|full|unsafe)"),
        })
    }
}

impl std::str::FromStr for Consistency {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Consistency::parse(s)
    }
}

/// One neighbor slot of a scope (raw pointers; the engine guarantees the
/// aliasing discipline of the active consistency model).
#[derive(Clone, Copy)]
struct NbrSlot<V, E> {
    id: VertexId,
    edge_id: EdgeId,
    vdata: *mut V,
    edata: *mut E,
}

/// The scope `S_v` handed to an update function: the data of `v`, its
/// adjacent edges, and its neighbors (paper Fig. 2). Access rights are
/// checked against the consistency model at runtime (debug assertions in
/// release-hot accessors are `debug_assert!`).
pub struct Scope<V, E> {
    vertex: VertexId,
    center: *mut V,
    nbrs: Vec<NbrSlot<V, E>>,
    consistency: Consistency,
    dirty_center: bool,
    dirty_edges: Vec<bool>,
    dirty_nbrs: Vec<bool>,
}

impl<V, E> Scope<V, E> {
    /// Empty reusable scope buffer (engines call the crate-internal
    /// `Scope::reset` per task).
    pub fn new_buffer(consistency: Consistency) -> Self {
        Scope {
            vertex: 0,
            center: std::ptr::null_mut(),
            nbrs: Vec::new(),
            consistency,
            dirty_center: false,
            dirty_edges: Vec::new(),
            dirty_nbrs: Vec::new(),
        }
    }

    /// (engine-internal) Re-point the buffer at a new center vertex.
    ///
    /// # Safety
    /// `center` must be exclusively accessible for the duration of the
    /// update per the consistency model; neighbor slots are pushed with
    /// [`Scope::push_neighbor`] under the same contract.
    pub(crate) unsafe fn reset(&mut self, vertex: VertexId, center: *mut V) {
        self.vertex = vertex;
        self.center = center;
        self.nbrs.clear();
        self.dirty_center = false;
        self.dirty_edges.clear();
        self.dirty_nbrs.clear();
    }

    /// (engine-internal) Append one neighbor slot.
    pub(crate) unsafe fn push_neighbor(
        &mut self,
        id: VertexId,
        edge_id: EdgeId,
        vdata: *mut V,
        edata: *mut E,
    ) {
        self.nbrs.push(NbrSlot {
            id,
            edge_id,
            vdata,
            edata,
        });
        self.dirty_edges.push(false);
        self.dirty_nbrs.push(false);
    }

    /// The center vertex id.
    #[inline]
    pub fn vertex(&self) -> VertexId {
        self.vertex
    }

    /// Degree of the center vertex (neighbor slot count).
    #[inline]
    pub fn degree(&self) -> usize {
        self.nbrs.len()
    }

    /// Consistency model in force.
    #[inline]
    pub fn consistency(&self) -> Consistency {
        self.consistency
    }

    /// Read the center vertex data.
    #[inline]
    pub fn center(&self) -> &V {
        unsafe { &*self.center }
    }

    /// Mutate the center vertex data (allowed under every model).
    #[inline]
    pub fn center_mut(&mut self) -> &mut V {
        self.dirty_center = true;
        unsafe { &mut *self.center }
    }

    /// Neighbor vertex id at slot `i`.
    #[inline]
    pub fn nbr_id(&self, i: usize) -> VertexId {
        self.nbrs[i].id
    }

    /// Edge id of slot `i`.
    #[inline]
    pub fn edge_id(&self, i: usize) -> EdgeId {
        self.nbrs[i].edge_id
    }

    /// Read neighbor vertex data (edge/full consistency; under vertex
    /// consistency neighbor reads are not guaranteed consistent and are
    /// rejected).
    #[inline]
    pub fn nbr(&self, i: usize) -> &V {
        debug_assert!(
            !matches!(self.consistency, Consistency::Vertex),
            "vertex consistency grants no neighbor-vertex access"
        );
        unsafe { &*self.nbrs[i].vdata }
    }

    /// Mutate neighbor vertex data (full consistency only).
    #[inline]
    pub fn nbr_mut(&mut self, i: usize) -> &mut V {
        assert!(
            matches!(self.consistency, Consistency::Full | Consistency::Unsafe),
            "neighbor-vertex writes require full consistency"
        );
        self.dirty_nbrs[i] = true;
        unsafe { &mut *self.nbrs[i].vdata }
    }

    /// Read edge data at slot `i` (all models).
    #[inline]
    pub fn edge(&self, i: usize) -> &E {
        unsafe { &*self.nbrs[i].edata }
    }

    /// Mutate edge data at slot `i` (edge/full consistency).
    #[inline]
    pub fn edge_mut(&mut self, i: usize) -> &mut E {
        debug_assert!(
            !matches!(self.consistency, Consistency::Vertex),
            "vertex consistency grants read-only edge access"
        );
        self.dirty_edges[i] = true;
        unsafe { &mut *self.nbrs[i].edata }
    }

    /// Whether the center data was mutably borrowed.
    pub fn center_dirty(&self) -> bool {
        self.dirty_center
    }

    /// Whether edge slot `i` was mutably borrowed.
    pub fn edge_dirty(&self, i: usize) -> bool {
        self.dirty_edges[i]
    }

    /// Whether neighbor slot `i` was mutably borrowed.
    pub fn nbr_dirty(&self, i: usize) -> bool {
        self.dirty_nbrs[i]
    }
}

/// Per-update context: task scheduling plus read access to sync globals
/// (the `T` and sync-read halves of the update signature).
pub struct Ctx<'g> {
    /// Tasks scheduled by this update (drained by the engine).
    pub scheduled: Vec<Task>,
    globals: &'g GlobalValues,
    num_updates_hint: u64,
}

impl<'g> Ctx<'g> {
    /// (engine-internal) fresh context.
    pub(crate) fn new(globals: &'g GlobalValues) -> Self {
        Ctx {
            scheduled: Vec::new(),
            globals,
            num_updates_hint: 0,
        }
    }

    /// Schedule `(Update, v)` with priority (merged by the scheduler).
    pub fn schedule(&mut self, vertex: VertexId, priority: f64) {
        self.scheduled.push(Task { vertex, priority });
    }

    /// Read the latest finalized value of a sync operation.
    pub fn global(&self, key: &str) -> Option<Vec<f64>> {
        self.globals.get(key)
    }

    /// Approximate count of updates executed so far (for app-side logging).
    pub fn updates_so_far(&self) -> u64 {
        self.num_updates_hint
    }

    pub(crate) fn set_updates_hint(&mut self, n: u64) {
        self.num_updates_hint = n;
    }
}

/// The user's **update function** (paper Sec. 3.2) plus an optional batched
/// form used to drive the AOT-compiled PJRT kernels.
pub trait VertexProgram<V, E>: Send + Sync {
    /// Consistency model this program requires.
    fn consistency(&self) -> Consistency {
        Consistency::Edge
    }

    /// The update: mutate the scope, schedule follow-up tasks.
    fn update(&self, scope: &mut Scope<V, E>, ctx: &mut Ctx);

    /// Preferred batch width (1 = no batching). Engines that can gather
    /// `batch_width()` same-color tasks call [`VertexProgram::update_batch`]
    /// instead of per-vertex [`VertexProgram::update`]; the default
    /// implementation degrades to the scalar path.
    fn batch_width(&self) -> usize {
        1
    }

    /// Batched update over disjoint scopes (all consistency obligations
    /// already discharged by the engine). Programs backed by PJRT
    /// artifacts override this to gather tiles and execute one compiled
    /// call per batch.
    fn update_batch(&self, scopes: &mut [&mut Scope<V, E>], ctx: &mut Ctx) {
        for scope in scopes {
            self.update(scope, ctx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scope_tracks_dirtiness_and_rights() {
        let mut center = 10i64;
        let mut nbr = 20i64;
        let mut edge = 5i64;
        let mut s: Scope<i64, i64> = Scope::new_buffer(Consistency::Edge);
        unsafe {
            s.reset(0, &mut center);
            s.push_neighbor(1, 0, &mut nbr, &mut edge);
        }
        assert_eq!(*s.center(), 10);
        assert!(!s.center_dirty());
        *s.center_mut() += 1;
        assert!(s.center_dirty());
        assert_eq!(*s.nbr(0), 20);
        *s.edge_mut(0) = 7;
        assert!(s.edge_dirty(0));
        assert!(!s.nbr_dirty(0));
        assert_eq!(center, 11);
        assert_eq!(edge, 7);
    }

    #[test]
    #[should_panic(expected = "full consistency")]
    fn edge_consistency_rejects_neighbor_writes() {
        let mut center = 0i64;
        let mut nbr = 0i64;
        let mut edge = 0i64;
        let mut s: Scope<i64, i64> = Scope::new_buffer(Consistency::Edge);
        unsafe {
            s.reset(0, &mut center);
            s.push_neighbor(1, 0, &mut nbr, &mut edge);
        }
        let _ = s.nbr_mut(0);
    }

    #[test]
    fn full_consistency_allows_neighbor_writes() {
        let mut center = 0i64;
        let mut nbr = 0i64;
        let mut edge = 0i64;
        let mut s: Scope<i64, i64> = Scope::new_buffer(Consistency::Full);
        unsafe {
            s.reset(0, &mut center);
            s.push_neighbor(1, 0, &mut nbr, &mut edge);
        }
        *s.nbr_mut(0) = 42;
        assert!(s.nbr_dirty(0));
        assert_eq!(nbr, 42);
    }

    #[test]
    fn consistency_parse_is_fallible_not_panicking() {
        assert_eq!(Consistency::parse("edge").unwrap(), Consistency::Edge);
        assert_eq!(Consistency::parse("none").unwrap(), Consistency::Unsafe);
        assert!(Consistency::parse("sorta-safe").is_err());
        assert!("full".parse::<Consistency>().is_ok());
    }

    #[test]
    fn ctx_collects_tasks() {
        let globals = GlobalValues::new();
        let mut ctx = Ctx::new(&globals);
        ctx.schedule(3, 1.5);
        ctx.schedule(7, 0.0);
        assert_eq!(ctx.scheduled.len(), 2);
        assert_eq!(ctx.scheduled[0].vertex, 3);
        assert!(ctx.global("missing").is_none());
    }
}
