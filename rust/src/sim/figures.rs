//! Figure harness: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §Experiment-index) as CSV under `results/`.
//!
//! * **Real-engine experiments** — Fig. 1 (consistent vs inconsistent ALS
//!   on a 5-machine cluster), Fig. 5(a) (RMSE vs d), Fig. 8(b) (lock
//!   pipelining under injected latency), Table 2 (dataset inventory) —
//!   run the actual distributed engines on synthetic data.
//! * **Model-scale experiments** — Figs. 6(a–d), 7(a), 8(a), 8(c), 8(d) —
//!   use the calibrated cluster model at the paper's data scale (see
//!   [`super`] and DESIGN.md §Substitutions).

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::{calibrate, dollars, grid_cut_fraction, grid_mirrors, hadoop_iter, ipb,
            random_cut_fraction, random_mirrors, ClusterModel, IterCost, WorkloadModel};

/// Chromatic iteration with the random-partition mirror factor derived
/// from the workload's average degree.
fn chrom(nodes: usize, w: &WorkloadModel) -> IterCost {
    let deg = 2.0 * w.num_edges / w.num_vertices;
    super::chromatic_iter(
        &ClusterModel::ec2_hpc(nodes), w,
        random_cut_fraction(nodes), random_mirrors(nodes, deg),
    )
}

/// Locking iteration on a frame-sliced grid.
fn lock_grid(nodes: usize, w: &WorkloadModel, frames: f64, maxpending: usize) -> IterCost {
    super::locking_iter(
        &ClusterModel::ec2_hpc(nodes), w,
        grid_cut_fraction(nodes, frames), grid_mirrors(nodes, frames), maxpending,
    )
}

/// MPI iteration with the random-partition mirror factor.
fn mpi(nodes: usize, w: &WorkloadModel) -> IterCost {
    let deg = 2.0 * w.num_edges / w.num_vertices;
    super::mpi_iter(
        &ClusterModel::ec2_hpc(nodes), w,
        random_cut_fraction(nodes), random_mirrors(nodes, deg),
    )
}
use crate::apps::{self, als, coseg, ner};
use crate::distributed::network::NetworkModel;
use crate::engine::{Consistency, Engine, EngineKind};
use crate::partition::{Coloring, Partition};
use crate::scheduler::{Policy, SchedSpec};
use crate::util::csv::{f, CsvWriter};

const NODE_SWEEP: [usize; 6] = [4, 8, 16, 24, 32, 64];

/// Run one named figure (or `all`). Writes `<out_dir>/<name>.csv`.
pub fn run_figure(name: &str, out_dir: &Path) -> Result<()> {
    match name {
        "table2" => table2(out_dir),
        "fig1" => fig1(out_dir),
        "fig5a" => fig5a(out_dir),
        "fig6a" | "fig6b" => fig6ab(out_dir),
        "fig6c" => fig6c(out_dir),
        "fig6d" => fig6d(out_dir),
        "fig7a" => fig7a(out_dir),
        "fig8a" => fig8a(out_dir),
        "fig8b" => fig8b(out_dir),
        "fig8c" => fig8c(out_dir),
        "fig8d" => fig8d(out_dir),
        "all" => {
            for n in [
                "table2", "fig1", "fig5a", "fig6a", "fig6c", "fig6d", "fig7a", "fig8a",
                "fig8b", "fig8c", "fig8d",
            ] {
                println!("=== {n} ===");
                run_figure(n, out_dir)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown figure '{other}'"),
    }
}

/// Table 2: our synthetic experiment inventory (paper-scale model column
/// + actually-run sizes).
fn table2(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("table2.csv"),
        &["exp", "verts", "edges", "vertex_bytes", "edge_bytes", "shape", "partition", "engine"],
    )?;
    let netflix = crate::datagen::netflix(3000, 1500, 40, 8, 0.15, 1);
    let g = als::build(&netflix, 20, 1);
    w.rowd(&[&"netflix", &g.num_vertices(), &g.num_edges(), &173, &16, &"bipartite", &"random", &"chromatic"])?;
    let video = crate::datagen::video(24, 24, 20, 5, 0.4, 2);
    let vg = coseg::build(&video, 0.8);
    w.rowd(&[&"coseg", &vg.num_vertices(), &vg.num_edges(), &392, &80, &"3d-grid", &"frames", &"locking"])?;
    let nerd = crate::datagen::ner(4000, 2000, 40, 8, 0.1, 3);
    let ng = ner::build(&nerd);
    w.rowd(&[&"ner", &ng.num_vertices(), &ng.num_edges(), &816, &4, &"bipartite", &"random", &"chromatic"])?;
    println!("table2 written (netflix {}v/{}e, coseg {}v/{}e, ner {}v/{}e)",
        g.num_vertices(), g.num_edges(), vg.num_vertices(), vg.num_edges(),
        ng.num_vertices(), ng.num_edges());
    w.flush()
}

/// Fig. 1: consistent (edge) vs inconsistent (unsafe) asynchronous ALS on
/// a 5-machine cluster — RMSE over updates.
fn fig1(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("fig1.csv"),
        &["mode", "epoch", "updates", "rmse"],
    )?;
    let data = crate::datagen::netflix(400, 200, 20, 5, 0.1, 11);
    for (mode, consistency) in [("consistent", Consistency::Edge), ("inconsistent", Consistency::Unsafe)] {
        let g = als::build(&data, 5, 2);
        let n = g.num_vertices();
        let machines = 5;
        let partition = Partition::random(n, machines, 7);
        let prog = AlsWithConsistency {
            inner: als::Als { d: 5, lambda: 0.05, use_pjrt: false },
            consistency,
        };
        let series = Arc::new(Mutex::new(Vec::<(u64, u64, f64)>::new()));
        let series2 = series.clone();
        let _exec = Engine::new(EngineKind::Locking)
            .machines(machines)
            .maxpending(32)
            .scheduler(SchedSpec::ws(Policy::Fifo, 1))
            .sync_period(Duration::from_millis(25))
            .max_updates(n as u64 * 25)
            .with_partition(partition)
            .sync(als::rmse_sync())
            .on_progress(move |e, u, g| {
                if let Some(r) = g.get("rmse") {
                    series2.lock().unwrap().push((e, u, r[0]));
                }
            })
            .run(g, &prog, apps::all_vertices(n))?;
        for (e, u, r) in series.lock().unwrap().iter() {
            w.rowd(&[&mode, e, u, &f(*r)])?;
        }
        let last = series.lock().unwrap().last().cloned();
        println!("fig1 {mode}: final rmse {:?}", last.map(|x| x.2));
    }
    w.flush()
}

/// Wrapper overriding the consistency model (Fig. 1's unsafe mode).
struct AlsWithConsistency {
    inner: als::Als,
    consistency: Consistency,
}

impl crate::engine::VertexProgram<als::AlsVertex, als::AlsEdge> for AlsWithConsistency {
    fn consistency(&self) -> Consistency {
        self.consistency
    }
    fn update(&self, s: &mut crate::engine::Scope<als::AlsVertex, als::AlsEdge>, c: &mut crate::engine::Ctx) {
        self.inner.update(s, c)
    }
    fn batch_width(&self) -> usize {
        self.inner.batch_width()
    }
    fn update_batch(&self, s: &mut [&mut crate::engine::Scope<als::AlsVertex, als::AlsEdge>], c: &mut crate::engine::Ctx) {
        self.inner.update_batch(s, c)
    }
}

/// Fig. 5(a): held-out RMSE after 30 sweeps vs rank d (real chromatic runs
/// on synthetic Netflix with an 80/20 train/test split).
fn fig5a(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(out.join("fig5a.csv"), &["d", "train_rmse", "test_rmse"])?;
    let mut data = crate::datagen::netflix(600, 300, 80, 16, 0.3, 21);
    // Hold out 20% of ratings for test — shuffled first, so every user and
    // movie keeps training coverage (ratings are generated per user).
    crate::util::Rng::new(99).shuffle(&mut data.ratings);
    let split = (data.ratings.len() * 4) / 5;
    let train = crate::datagen::NetflixData {
        users: data.users,
        movies: data.movies,
        ratings: data.ratings[..split].to_vec(),
        true_rank: data.true_rank,
    };
    let test = &data.ratings[split..];
    for d in [2usize, 5, 10, 20, 50] {
        let g = als::build(&train, d, 3);
        let n = g.num_vertices();
        let coloring = Coloring::bipartite(&g).expect("bipartite");
        let partition = Partition::random(n, 4, 5);
        let prog = als::Als { d, lambda: 0.2, use_pjrt: false };
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(4)
            .max_sweeps(30)
            .with_coloring(coloring)
            .with_partition(partition)
            .sync(als::rmse_sync())
            .run(g, &prog, apps::all_vertices(n))?;
        let g = exec.graph;
        let train_rmse = als::rmse_direct(&g);
        let mut sse = 0.0f64;
        for &(u, m, r) in test {
            let pu = &g.vertex_data(u).factor;
            let qm = &g.vertex_data(train.users as u32 + m).factor;
            let err = (r - crate::util::matrix::dot(pu, qm)) as f64;
            sse += err * err;
        }
        let test_rmse = (sse / test.len() as f64).sqrt();
        println!("fig5a d={d}: train={train_rmse:.4} test={test_rmse:.4}");
        w.rowd(&[&d, &f(train_rmse), &f(test_rmse)])?;
    }
    w.flush()
}

/// Fig. 6(a)+(b): modeled speedup and bytes/sec/node vs cluster size for
/// the three applications at paper scale.
fn fig6ab(out: &Path) -> Result<()> {
    let mut wa = CsvWriter::create(out.join("fig6a.csv"), &["app", "nodes", "speedup"])?;
    let mut wb = CsvWriter::create(out.join("fig6b.csv"), &["app", "nodes", "mb_per_sec_per_node"])?;
    let netflix = calibrate::netflix_workload(20);
    let nerw = calibrate::ner_workload();
    let cosegw = calibrate::coseg_workload(1740.0);
    for (app, w_, locking_engine) in [
        ("netflix", netflix, false),
        ("ner", nerw, false),
        ("coseg", cosegw, true),
    ] {
        let base = iter_time(&w_, 4, locking_engine);
        for nodes in NODE_SWEEP {
            let it = if locking_engine {
                lock_grid(nodes, &w_, 1740.0, 100)
            } else {
                chrom(nodes, &w_)
            };
            let speedup = base / it.seconds * 4.0;
            wa.rowd(&[&app, &nodes, &f(speedup)])?;
            wb.rowd(&[&app, &nodes, &f(it.bytes_per_node / it.seconds / 1e6)])?;
            if nodes == 64 {
                println!("fig6a {app}: speedup@64 = {speedup:.1}");
            }
        }
    }
    wa.flush()?;
    wb.flush()
}

fn iter_time(w: &WorkloadModel, nodes: usize, locking_engine: bool) -> f64 {
    if locking_engine {
        lock_grid(nodes, w, 1740.0, 100).seconds
    } else {
        chrom(nodes, w).seconds
    }
}

/// Fig. 6(c): Netflix speedup at 64 nodes vs d (IPB).
fn fig6c(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(out.join("fig6c.csv"), &["d", "ipb", "speedup64"])?;
    for d in [5usize, 20, 50, 100] {
        let wl = calibrate::netflix_workload(d);
        let t4 = chrom(4, &wl).seconds;
        let t64 = chrom(64, &wl).seconds;
        let speedup = t4 / t64 * 4.0;
        println!("fig6c d={d}: ipb={:.1} speedup@64={speedup:.1}", ipb(&wl));
        w.rowd(&[&d, &f(ipb(&wl)), &f(speedup)])?;
    }
    w.flush()
}

/// Fig. 6(d): one Netflix iteration (d=20): GraphLab vs Hadoop vs MPI.
fn fig6d(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("fig6d.csv"),
        &["nodes", "graphlab_s", "hadoop_s", "mpi_s"],
    )?;
    let wl = calibrate::netflix_workload(20);
    for nodes in NODE_SWEEP {
        let c = ClusterModel::ec2_hpc(nodes);
        let gl = chrom(nodes, &wl).seconds;
        let hd = hadoop_iter(&c, &wl).seconds;
        let mp = mpi(nodes, &wl).seconds;
        println!("fig6d nodes={nodes}: graphlab={gl:.2}s hadoop={hd:.1}s ({:.0}x) mpi={mp:.2}s", hd / gl);
        w.rowd(&[&nodes, &f(gl), &f(hd), &f(mp)])?;
    }
    w.flush()
}

/// Fig. 7(a): one NER/CoEM iteration: GraphLab vs Hadoop vs MPI.
fn fig7a(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("fig7a.csv"),
        &["nodes", "graphlab_s", "hadoop_s", "mpi_s"],
    )?;
    let wl = calibrate::ner_workload();
    for nodes in NODE_SWEEP {
        let c = ClusterModel::ec2_hpc(nodes);
        let gl = chrom(nodes, &wl).seconds;
        let hd = hadoop_iter(&c, &wl).seconds;
        let mp = mpi(nodes, &wl).seconds;
        println!("fig7a nodes={nodes}: graphlab={gl:.2}s hadoop={hd:.1}s ({:.0}x) mpi={mp:.2}s", hd / gl);
        w.rowd(&[&nodes, &f(gl), &f(hd), &f(mp)])?;
    }
    w.flush()
}

/// Fig. 8(a): CoSeg weak scaling — frames grow with nodes.
fn fig8a(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(out.join("fig8a.csv"), &["cpus", "frames", "runtime_s"])?;
    for nodes in NODE_SWEEP {
        let frames = 1740.0 * nodes as f64 / 64.0;
        let wl = calibrate::coseg_workload(frames);
        let t = lock_grid(nodes, &wl, frames, 100).seconds;
        println!("fig8a cpus={}: frames={frames:.0} t={t:.2}s", nodes * 8);
        w.rowd(&[&(nodes * 8), &f(frames), &f(t)])?;
    }
    w.flush()
}

/// Fig. 8(b): lock pipelining (real locking engine, injected latency,
/// optimal vs worst-case partition, maxpending sweep).
fn fig8b(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("fig8b.csv"),
        &["partition", "maxpending", "runtime_s", "updates"],
    )?;
    let data = crate::datagen::video(16, 12, 10, 5, 0.4, 5);
    for (pname, striped) in [("optimal", false), ("worst", true)] {
        for maxpending in [1usize, 10, 100, 1000] {
            let g = coseg::build(&data, 0.8);
            let n = g.num_vertices();
            let partition = if striped {
                Partition::striped(n, 4)
            } else {
                Partition::blocked(n, 4)
            };
            let prog = coseg::Coseg { labels: 5, eps: 5e-3, sigma2: 0.5, use_pjrt: false };
            // Per-machine cap of 4 sweeps' worth: the builder splits
            // max_updates evenly across the 4 machines.
            let exec = Engine::new(EngineKind::Locking)
                .machines(4)
                .maxpending(maxpending)
                .scheduler(SchedSpec::ws(Policy::Priority, 1))
                .network(NetworkModel { latency: Duration::from_micros(500) })
                .max_updates(n as u64 * 16)
                .with_partition(partition)
                .run(g, &prog, apps::all_vertices(n))?;
            let stats = exec.stats;
            println!(
                "fig8b {pname} maxpending={maxpending}: {:.2}s ({} updates)",
                stats.seconds, stats.updates
            );
            w.rowd(&[&pname, &maxpending, &f(stats.seconds), &stats.updates])?;
        }
    }
    w.flush()
}

/// Fig. 8(c): price vs runtime for 10 Netflix iterations, GraphLab vs
/// Hadoop (modeled, fine-grained billing).
fn fig8c(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("fig8c.csv"),
        &["system", "nodes", "runtime_s", "cost_usd"],
    )?;
    let wl = calibrate::netflix_workload(20);
    let iters = 10.0;
    for nodes in NODE_SWEEP {
        let c = ClusterModel::ec2_hpc(nodes);
        let _ = &c;
        let gl = chrom(nodes, &wl).seconds * iters;
        let hd = hadoop_iter(&c, &wl).seconds * iters;
        w.rowd(&[&"graphlab", &nodes, &f(gl), &f(dollars(&c, gl))])?;
        w.rowd(&[&"hadoop", &nodes, &f(hd), &f(dollars(&c, hd))])?;
    }
    println!("fig8c written (graphlab ~2 orders cheaper at iso-runtime)");
    w.flush()
}

/// Fig. 8(d): cost vs attained (held-out) RMSE for several d, 32 nodes —
/// real convergence series + modeled per-iteration cost at paper scale.
fn fig8d(out: &Path) -> Result<()> {
    let mut w = CsvWriter::create(
        out.join("fig8d.csv"),
        &["d", "sweep", "test_rmse", "cost_usd"],
    )?;
    let mut data = crate::datagen::netflix(600, 300, 80, 16, 0.3, 21);
    crate::util::Rng::new(99).shuffle(&mut data.ratings);
    let split = (data.ratings.len() * 4) / 5;
    let train = crate::datagen::NetflixData {
        users: data.users,
        movies: data.movies,
        ratings: data.ratings[..split].to_vec(),
        true_rank: data.true_rank,
    };
    let test: Vec<(u32, u32, f32)> = data.ratings[split..].to_vec();
    let c32 = ClusterModel::ec2_hpc(32);
    for d in [5usize, 10, 20, 50] {
        let wl = calibrate::netflix_workload(d);
        let iter_cost = dollars(&c32, chrom(32, &wl).seconds);
        let g0 = als::build(&train, d, 3);
        let n = g0.num_vertices();
        let coloring = Coloring::bipartite(&g0).expect("bipartite");
        let partition = Partition::random(n, 4, 5);
        let prog = als::Als { d, lambda: 0.2, use_pjrt: false };
        let users = train.users as u32;
        let test2 = test.clone();
        let rows: Arc<Mutex<Vec<(u64, f64)>>> = Arc::new(Mutex::new(Vec::new()));
        // Probe test RMSE per sweep through a sync over factors: direct
        // computation needs the graph, so probe post-hoc via per-sweep
        // snapshots is costly; instead record the train-RMSE sync and
        // compute test RMSE at the end of each d-run (end point), plus the
        // sync series for the curve shape.
        let rows2 = rows.clone();
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(4)
            .max_sweeps(30)
            .with_coloring(coloring)
            .with_partition(partition)
            .sync(als::rmse_sync())
            .on_progress(move |s, _u, gv| {
                if let Some(r) = gv.get("rmse") {
                    rows2.lock().unwrap().push((s, r[0]));
                }
            })
            .run(g0, &prog, apps::all_vertices(n))?;
        let g = exec.graph;
        // Final held-out RMSE anchors the curve; the sync series gives the
        // per-sweep shape (train RMSE scaled to end at the test value).
        let mut sse = 0.0f64;
        for &(u, m, r) in &test2 {
            let pu = &g.vertex_data(u).factor;
            let qm = &g.vertex_data(users + m).factor;
            let err = (r - crate::util::matrix::dot(pu, qm)) as f64;
            sse += err * err;
        }
        let test_rmse = (sse / test2.len() as f64).sqrt();
        let series = rows.lock().unwrap();
        let final_train = series.last().map(|x| x.1).unwrap_or(test_rmse);
        let shift = test_rmse - final_train;
        for (sweep, train_rmse) in series.iter() {
            w.rowd(&[&d, sweep, &f(train_rmse + shift), &f(iter_cost * *sweep as f64)])?;
        }
        println!("fig8d d={d}: final test rmse {test_rmse:.4}, cost/iter ${iter_cost:.2}");
    }
    w.flush()
}
