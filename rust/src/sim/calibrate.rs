//! Measured per-update compute costs feeding the cluster model.
//!
//! The paper's scaling figures depend on the ratio between per-update
//! compute and communication. We measure the *native* update cost of each
//! application on this machine (single-threaded, realistic degrees) and
//! feed it into [`super::WorkloadModel`]. This is the calibration step
//! referenced in DESIGN.md §Substitutions.

use std::time::Instant;

use crate::util::matrix::{self, Mat};
use crate::util::Rng;

/// Hardware-era scaling: the paper's testbed (2×Xeon X5570 Nehalem, 2011)
/// executes the same scalar f32 update roughly this many times slower than
/// the machine the costs are measured on (per-core IPC × clock × vector
/// width progress since 2011). Applied to measured costs so the modeled
/// compute/communication ratio — which the scaling figures hinge on —
/// matches the paper's testbed rather than ours.
pub const HW_2011_SLOWDOWN: f64 = 6.0;

/// Measured seconds per ALS vertex update at rank `d`, degree `deg`
/// (O(d^3 + d^2 deg) solve, mirroring `apps::als`).
pub fn als_update_cost(d: usize, deg: usize) -> f64 {
    let mut rng = Rng::new(7);
    let nbrs: Vec<Vec<f32>> = (0..deg)
        .map(|_| (0..d).map(|_| rng.normal() * 0.3).collect())
        .collect();
    let ratings: Vec<f32> = (0..deg).map(|_| rng.uniform(1.0, 5.0)).collect();
    let iters = (2000 / d.max(1)).max(20);
    let start = Instant::now();
    let mut sink = 0.0f32;
    for _ in 0..iters {
        let mut a = Mat::zeros(d, d);
        let mut y = vec![0.0f32; d];
        for (f, &r) in nbrs.iter().zip(&ratings) {
            a.rank1_update(f, 1.0);
            matrix::axpy(&mut y, f, r);
        }
        let x = matrix::solve_psd(&a, &y, 0.1);
        sink += x[0];
    }
    std::hint::black_box(sink);
    start.elapsed().as_secs_f64() / iters as f64
}

/// Measured seconds per CoEM vertex update with `k` types, degree `deg`.
pub fn coem_update_cost(k: usize, deg: usize) -> f64 {
    let mut rng = Rng::new(8);
    let nbrs: Vec<Vec<f32>> = (0..deg)
        .map(|_| (0..k).map(|_| rng.f32()).collect())
        .collect();
    let counts: Vec<f32> = (0..deg).map(|_| rng.uniform(1.0, 10.0)).collect();
    let iters = 5000;
    let start = Instant::now();
    let mut sink = 0.0f32;
    for _ in 0..iters {
        let mut agg = vec![0.01f32; k];
        for (nb, &c) in nbrs.iter().zip(&counts) {
            matrix::axpy(&mut agg, nb, c);
        }
        matrix::normalize(&mut agg);
        sink += agg[0];
    }
    std::hint::black_box(sink);
    start.elapsed().as_secs_f64() / iters as f64
}

/// Measured seconds per LBP vertex update with `l` labels (grid degree 6).
pub fn lbp_update_cost(l: usize) -> f64 {
    let mut rng = Rng::new(9);
    let msgs: Vec<Vec<f32>> = (0..6)
        .map(|_| {
            let mut m: Vec<f32> = (0..l).map(|_| rng.uniform(0.1, 1.0)).collect();
            matrix::normalize(&mut m);
            m
        })
        .collect();
    let npot: Vec<f32> = (0..l).map(|_| rng.uniform(0.1, 1.0)).collect();
    let iters = 5000;
    let start = Instant::now();
    let mut sink = 0.0f32;
    for _ in 0..iters {
        let mut prod = npot.clone();
        for m in &msgs {
            for (p, &mi) in prod.iter_mut().zip(m) {
                *p *= mi.max(1e-30);
            }
        }
        for m in &msgs {
            let mut cav: Vec<f32> = prod.iter().zip(m).map(|(p, &mi)| p / mi.max(1e-30)).collect();
            let s: f32 = cav.iter().sum();
            let rho = 0.45f32;
            for c in cav.iter_mut() {
                *c = rho * s + (1.0 - rho) * *c;
            }
            matrix::normalize(&mut cav);
            sink += cav[0];
        }
    }
    std::hint::black_box(sink);
    start.elapsed().as_secs_f64() / iters as f64
}

/// Paper-scale workload models with measured update costs.
pub fn netflix_workload(d: usize) -> super::WorkloadModel {
    let avg_deg = 99e6 / 0.5e6;
    super::WorkloadModel {
        num_vertices: 0.5e6,
        num_edges: 99e6,
        update_cost: als_update_cost(d, (avg_deg as usize).min(512)) * HW_2011_SLOWDOWN,
        vertex_bytes: 8.0 * d as f64 + 13.0,
        edge_bytes: 16.0,
        colors: 2.0,
        bytes_per_update: avg_deg * (16.0 + 8.0 * d as f64 + 13.0),
    }
}

/// NER at paper scale (2M vertices, 200M edges, 816-byte vertex data).
pub fn ner_workload() -> super::WorkloadModel {
    let avg_deg = 200e6 / 2e6;
    super::WorkloadModel {
        num_vertices: 2e6,
        num_edges: 200e6,
        update_cost: coem_update_cost(8, (avg_deg as usize).min(256)) * HW_2011_SLOWDOWN,
        vertex_bytes: 816.0,
        edge_bytes: 4.0,
        colors: 2.0,
        bytes_per_update: avg_deg * (4.0 + 816.0),
    }
}

/// CoSeg at paper scale (10.5M vertices, 31M edges).
pub fn coseg_workload(frames: f64) -> super::WorkloadModel {
    let verts = frames * 120.0 * 50.0;
    super::WorkloadModel {
        num_vertices: verts,
        num_edges: verts * 3.0,
        update_cost: lbp_update_cost(5) * HW_2011_SLOWDOWN,
        vertex_bytes: 392.0,
        edge_bytes: 80.0,
        colors: 0.0,
        bytes_per_update: 6.0 * 80.0 + 392.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_are_positive_and_ordered() {
        let c5 = als_update_cost(5, 32);
        let c20 = als_update_cost(20, 32);
        assert!(c5 > 0.0);
        assert!(c20 > c5, "d=20 must cost more than d=5: {c20:.2e} vs {c5:.2e}");
        assert!(coem_update_cost(8, 64) > 0.0);
        assert!(lbp_update_cost(5) > 0.0);
    }
}
