//! Cluster-scale performance model (DESIGN.md §Substitutions).
//!
//! The paper's scaling evaluation (Figs. 6–8) ran on up to 64 EC2
//! cc1.4xlarge nodes (8 cores each, 10 GbE). We cannot rent that testbed,
//! so the *shape* figures are regenerated from a calibrated analytic model
//! of the engines' per-iteration execution, with per-update compute costs
//! **measured** on this machine ([`calibrate`]) and communication volumes
//! taken from the same formulas the real engines implement (ghost
//! coherence traffic = cut edges × data sizes; Hadoop = full state
//! re-emission per iteration; MPI = synchronous alltoall of boundary
//! state). Numbers are not the paper's absolute numbers — the shape (who
//! wins, by what factor, where scaling saturates) is the reproduction
//! target.
//!
//! Real (non-modeled) experiments — Fig. 1, Fig. 5(a), Fig. 8(b) — run on
//! the actual engines; see `figures`.

pub mod calibrate;
pub mod figures;

/// Cluster hardware model (defaults = paper's EC2 HPC instances).
#[derive(Debug, Clone, Copy)]
pub struct ClusterModel {
    /// Number of nodes.
    pub nodes: usize,
    /// Cores per node (cc1.4xlarge: 8).
    pub cores_per_node: usize,
    /// Per-node NIC bandwidth, bytes/sec (10 GbE ≈ 1.25e9).
    pub net_bandwidth: f64,
    /// One-way message latency, seconds.
    pub latency: f64,
    /// Effective per-node disk bandwidth for HDFS-style writes, bytes/sec.
    pub disk_bandwidth: f64,
    /// Node price, $/hour (cc1.4xlarge 2011: $1.60).
    pub price_per_hour: f64,
}

impl ClusterModel {
    /// The paper's testbed with `nodes` nodes.
    pub fn ec2_hpc(nodes: usize) -> Self {
        ClusterModel {
            nodes,
            cores_per_node: 8,
            net_bandwidth: 1.25e9,
            latency: 100e-6,
            disk_bandwidth: 100e6,
            price_per_hour: 1.60,
        }
    }
}

/// Workload model for one application (per engine iteration).
#[derive(Debug, Clone, Copy)]
pub struct WorkloadModel {
    /// Vertices updated per iteration.
    pub num_vertices: f64,
    /// Undirected edges.
    pub num_edges: f64,
    /// Measured seconds per update (single core).
    pub update_cost: f64,
    /// Modeled vertex data bytes (ghost sync unit).
    pub vertex_bytes: f64,
    /// Modeled edge data bytes.
    pub edge_bytes: f64,
    /// Colors (chromatic barriers per sweep).
    pub colors: f64,
    /// Bytes of data accessed per update (for IPB, Fig. 6(c)).
    pub bytes_per_update: f64,
}

/// Fraction of edges crossing machines under a random (hash) cut:
/// 1 - 1/p (the paper's Netflix/NER partitioning).
pub fn random_cut_fraction(nodes: usize) -> f64 {
    if nodes <= 1 {
        0.0
    } else {
        1.0 - 1.0 / nodes as f64
    }
}

/// Expected ghost copies ("mirrors") per vertex under a random cut:
/// (p-1)(1 - (1 - 1/p)^deg). This — vertex replication growing with the
/// machine count — is what actually saturates the network for
/// high-degree/large-vertex workloads like NER (paper Sec. 6.1), since
/// every mirror must be refreshed each sweep.
pub fn random_mirrors(nodes: usize, avg_degree: f64) -> f64 {
    if nodes <= 1 {
        return 0.0;
    }
    let p = nodes as f64;
    (p - 1.0) * (1.0 - (1.0 - 1.0 / p).powf(avg_degree))
}

/// Mirrors per vertex for a frame-sliced 3-D grid: only the two boundary
/// planes of each machine are replicated.
pub fn grid_mirrors(nodes: usize, frames: f64) -> f64 {
    if nodes <= 1 {
        0.0
    } else {
        2.0 * (nodes as f64 - 1.0) / frames
    }
}

/// Cut fraction for a frame-sliced 3-D grid (CoSeg): (p-1) planes of
/// width*height edges out of ~3·V edges.
pub fn grid_cut_fraction(nodes: usize, frames: f64) -> f64 {
    if nodes <= 1 {
        0.0
    } else {
        // One cut plane per machine boundary, each 1/(3·frames) of edges.
        (nodes as f64 - 1.0) / (3.0 * frames)
    }
}

/// Per-iteration result of the model.
#[derive(Debug, Clone, Copy)]
pub struct IterCost {
    /// Wall-clock seconds for one iteration (all vertices once).
    pub seconds: f64,
    /// Network bytes sent per node during the iteration.
    pub bytes_per_node: f64,
}

/// Chromatic engine, one sweep: per color, compute and background ghost
/// sync overlap (the engine synchronizes modified data while updates run,
/// Sec. 4.2.1), then a full barrier. `mirrors` = expected ghost copies per
/// vertex ([`random_mirrors`] / [`grid_mirrors`]); each copy receives the
/// vertex's new data once per sweep, plus cut-edge data.
pub fn chromatic_iter(c: &ClusterModel, w: &WorkloadModel, cut_fraction: f64, mirrors: f64) -> IterCost {
    let p = c.nodes as f64;
    let compute = w.num_vertices * w.update_cost / (p * c.cores_per_node as f64);
    // Ghost traffic: every mirror of a modified vertex is refreshed, and
    // every cut edge syncs its (smaller) edge data.
    let ghost_bytes_total = w.num_vertices * mirrors * (w.vertex_bytes + 12.0)
        + w.num_edges * cut_fraction * (w.edge_bytes + 12.0);
    let bytes_per_node = ghost_bytes_total / p;
    let comm = bytes_per_node / c.net_bandwidth;
    // Barrier per color: latency-bound all-to-all of ColorDone markers.
    let barriers = w.colors * c.latency * (p.log2().max(1.0)) * 2.0;
    IterCost {
        seconds: compute.max(comm) + barriers,
        bytes_per_node,
    }
}

/// Locking engine, one "iteration" (every vertex updated once): lock
/// chains on boundary vertices pay round trips, hidden by pipelining.
pub fn locking_iter(
    c: &ClusterModel,
    w: &WorkloadModel,
    cut_fraction: f64,
    mirrors: f64,
    maxpending: usize,
) -> IterCost {
    let p = c.nodes as f64;
    let compute = w.num_vertices * w.update_cost / (p * c.cores_per_node as f64);
    let boundary_updates = w.num_vertices * (cut_fraction * 2.0).min(1.0);
    // Mirror refreshes piggyback on lock grants (request+grant+release
    // ≈ 57 bytes of protocol per boundary lock chain).
    let ghost_bytes_total = w.num_vertices * mirrors * (w.vertex_bytes + 12.0)
        + w.num_edges * cut_fraction * (w.edge_bytes + 57.0);
    let bytes_per_node = ghost_bytes_total / p;
    let comm_bw = bytes_per_node / c.net_bandwidth;
    // Latency cost: round trips serialized per pipeline slot.
    let pipeline = (maxpending.max(1) as f64).min(boundary_updates.max(1.0));
    let comm_lat = boundary_updates / p * 2.0 * c.latency / pipeline;
    IterCost {
        seconds: compute.max(comm_bw) + comm_lat,
        bytes_per_node,
    }
}

/// Hadoop/MapReduce, one iteration (paper Sec. 6.2's analysis): the map
/// stage re-emits the full vertex state once per edge ("over 100
/// gigabytes of HDFS writes" for NER), which is materialized to disk,
/// shuffled over the network, reduced, and written back; plus a fixed
/// per-job startup.
pub fn hadoop_iter(c: &ClusterModel, w: &WorkloadModel) -> IterCost {
    let p = c.nodes as f64;
    let startup = 25.0; // JVM spin-up + scheduling, seconds per job
    // Map emits vertex state per incident edge (both endpoints).
    let map_out = 2.0 * w.num_edges * (w.vertex_bytes + 16.0);
    let disk = map_out / (p * c.disk_bandwidth); // materialize map output
    let shuffle = map_out / (p * c.net_bandwidth);
    let reduce_write = w.num_vertices * (w.vertex_bytes + 16.0) / (p * c.disk_bandwidth);
    // Java + framework compute overhead vs native (paper: "Hadoop is
    // implemented in Java while ours is highly optimized C++").
    let compute = 4.0 * w.num_vertices * w.update_cost / (p * c.cores_per_node as f64);
    IterCost {
        seconds: startup + disk + shuffle + reduce_write + compute,
        bytes_per_node: map_out / p,
    }
}

/// Hand-tuned MPI, one iteration: synchronous collectives exchanging only
/// boundary state — the paper finds this comparable to GraphLab.
pub fn mpi_iter(c: &ClusterModel, w: &WorkloadModel, cut_fraction: f64, mirrors: f64) -> IterCost {
    let p = c.nodes as f64;
    let compute = w.num_vertices * w.update_cost / (p * c.cores_per_node as f64);
    let xchg = (w.num_vertices * mirrors * (w.vertex_bytes + 8.0)
        + w.num_edges * cut_fraction * 8.0)
        / p;
    let comm = xchg / c.net_bandwidth + c.latency * p.log2().max(1.0);
    IterCost {
        seconds: compute + comm, // synchronous: no compute/comm overlap
        bytes_per_node: xchg,
    }
}

/// Dollar cost of `seconds` on the cluster (fine-grained billing, as the
/// paper's Fig. 8(c) assumes).
pub fn dollars(c: &ClusterModel, seconds: f64) -> f64 {
    c.nodes as f64 * c.price_per_hour * seconds / 3600.0
}

/// Instructions-per-byte proxy for Fig. 6(c): update FLOPs (from the
/// measured update cost at an assumed 2 GFLOP/s/core effective rate)
/// divided by bytes accessed.
pub fn ipb(w: &WorkloadModel) -> f64 {
    (w.update_cost * 2.0e9) / w.bytes_per_update.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn netflix_like(update_cost: f64) -> WorkloadModel {
        WorkloadModel {
            num_vertices: 0.5e6,
            num_edges: 99e6,
            update_cost,
            vertex_bytes: 8.0 * 20.0 + 13.0,
            edge_bytes: 16.0,
            colors: 2.0,
            bytes_per_update: 99e6 / 0.5e6 * (16.0 + 173.0),
        }
    }

    fn ner_like() -> WorkloadModel {
        WorkloadModel {
            num_vertices: 2e6,
            num_edges: 200e6,
            update_cost: 2e-6,
            vertex_bytes: 816.0,
            edge_bytes: 4.0,
            colors: 2.0,
            bytes_per_update: 200e6 / 2e6 * 820.0,
        }
    }

    fn nf_mirrors(nodes: usize) -> f64 {
        random_mirrors(nodes, 2.0 * 99e6 / 0.5e6)
    }

    fn ner_mirrors(nodes: usize) -> f64 {
        random_mirrors(nodes, 2.0 * 200e6 / 2e6)
    }

    #[test]
    fn graphlab_beats_hadoop_by_20_to_60x() {
        // The paper's headline: 20-60x over Hadoop on Netflix (Fig. 6(d)).
        let w = netflix_like(30e-6);
        for nodes in [4, 16, 64] {
            let c = ClusterModel::ec2_hpc(nodes);
            let gl = chromatic_iter(&c, &w, random_cut_fraction(nodes), nf_mirrors(nodes)).seconds;
            let hd = hadoop_iter(&c, &w).seconds;
            let ratio = hd / gl;
            assert!(
                (10.0..2000.0).contains(&ratio),
                "nodes={nodes}: ratio {ratio:.1} out of plausible range"
            );
            assert!(ratio > 15.0, "nodes={nodes}: Hadoop must lose big: {ratio:.1}");
        }
    }

    #[test]
    fn mpi_is_comparable_to_graphlab() {
        let w = netflix_like(30e-6);
        for nodes in [4, 16, 64] {
            let c = ClusterModel::ec2_hpc(nodes);
            let gl = chromatic_iter(&c, &w, random_cut_fraction(nodes), nf_mirrors(nodes)).seconds;
            let mp = mpi_iter(&c, &w, random_cut_fraction(nodes), nf_mirrors(nodes)).seconds;
            let ratio = mp / gl;
            assert!(
                (0.3..4.0).contains(&ratio),
                "nodes={nodes}: MPI/GraphLab {ratio:.2} should be ~1"
            );
        }
    }

    #[test]
    fn ner_saturates_network_at_scale() {
        // Fig. 6(a)/(b): NER's 816-byte vertices + growing vertex
        // replication saturate the NIC beyond ~16 nodes (paper: "modest 3x
        // improvement beyond 16 or more nodes").
        let w = ner_like();
        let t4 = chromatic_iter(&ClusterModel::ec2_hpc(4), &w, random_cut_fraction(4), ner_mirrors(4)).seconds;
        let t16 = chromatic_iter(&ClusterModel::ec2_hpc(16), &w, random_cut_fraction(16), ner_mirrors(16)).seconds;
        let t64 = chromatic_iter(&ClusterModel::ec2_hpc(64), &w, random_cut_fraction(64), ner_mirrors(64)).seconds;
        let s16 = t4 / t16 * 4.0;
        let s64 = t4 / t64 * 4.0;
        assert!(s16 > 4.0, "some scaling to 16 nodes: {s16:.1}");
        assert!(
            s64 < s16 * 2.0,
            "scaling should flatten: s16={s16:.1} s64={s64:.1}"
        );
        // Bandwidth per node approaches the NIC limit.
        let bw64 =
            chromatic_iter(&ClusterModel::ec2_hpc(64), &w, random_cut_fraction(64), ner_mirrors(64));
        let rate = bw64.bytes_per_node / bw64.seconds;
        assert!(rate > 0.5e9, "NIC should be nearly saturated: {rate:.2e} B/s");
    }

    #[test]
    fn coseg_weak_scaling_is_flat() {
        // Fig. 8(a): runtime roughly constant as frames scale with nodes.
        let base_frames = 128.0;
        let mut times = Vec::new();
        for nodes in [4usize, 16, 64] {
            let scale = nodes as f64 / 4.0;
            let frames = base_frames * scale;
            let verts = frames * 120.0 * 50.0;
            let w = WorkloadModel {
                num_vertices: verts,
                num_edges: verts * 3.0,
                update_cost: 10e-6,
                vertex_bytes: 392.0,
                edge_bytes: 80.0,
                colors: 0.0,
                bytes_per_update: 6.0 * 80.0 + 392.0,
            };
            let c = ClusterModel::ec2_hpc(nodes);
            times.push(locking_iter(&c, &w, grid_cut_fraction(nodes, frames), grid_mirrors(nodes, frames), 100).seconds);
        }
        let (t0, tn) = (times[0], *times.last().unwrap());
        assert!(
            tn < t0 * 1.35,
            "weak scaling should be near-flat: {times:?}"
        );
    }

    #[test]
    fn pipelining_helps_most_on_bad_cuts() {
        // Fig. 8(b): maxpending matters little on good cuts, a lot on bad.
        let w = WorkloadModel {
            num_vertices: 192e3,
            num_edges: 550e3,
            update_cost: 10e-6,
            vertex_bytes: 392.0,
            edge_bytes: 80.0,
            colors: 0.0,
            bytes_per_update: 872.0,
        };
        let c = ClusterModel::ec2_hpc(4);
        let good = grid_cut_fraction(4, 32.0);
        let gm = grid_mirrors(4, 32.0);
        let bad = 0.9; // striped partition cuts nearly everything
        let speedup_good = locking_iter(&c, &w, good, gm, 1).seconds
            / locking_iter(&c, &w, good, gm, 100).seconds;
        let speedup_bad =
            locking_iter(&c, &w, bad, 3.0, 1).seconds / locking_iter(&c, &w, bad, 3.0, 100).seconds;
        assert!(speedup_bad > speedup_good, "bad={speedup_bad:.2} good={speedup_good:.2}");
        assert!(speedup_bad > 2.0, "pipelining should matter on bad cuts");
    }

    #[test]
    fn cost_model_is_linear_in_nodes_and_time() {
        let c = ClusterModel::ec2_hpc(8);
        assert!((dollars(&c, 3600.0) - 12.8).abs() < 1e-9);
    }

    #[test]
    fn ipb_increases_with_d() {
        let w5 = netflix_like(5e-6);
        let w50 = netflix_like(200e-6);
        assert!(ipb(&w50) > ipb(&w5) * 10.0);
    }
}
