//! The real PJRT execution backend (compiled only with `--features pjrt`).
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each
//! engine thread lazily creates its own client and executable cache via a
//! thread-local ([`exec`] hides this). Compilation is per-thread but
//! happens once per (thread, artifact) and is excluded from benchmark
//! timings by a warmup call.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use super::{artifacts_dir, Input};

thread_local! {
    static TLS: RefCell<Option<ThreadRuntime>> = const { RefCell::new(None) };
}

struct ThreadRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Execute artifact `name` on this thread's PJRT client. Inputs are f32
/// tensors; outputs are the flattened f32 elements of each tuple member.
pub fn exec(name: &str, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
    TLS.with(|tls| {
        let mut slot = tls.borrow_mut();
        if slot.is_none() {
            *slot = Some(ThreadRuntime {
                client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
                exes: HashMap::new(),
            });
        }
        let rt = slot.as_mut().unwrap();
        if !rt.exes.contains_key(name) {
            let path = artifacts_dir().join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!("artifact {} not found (run `make artifacts`)", path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = rt
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            rt.exes.insert(name.to_string(), exe);
        }
        let exe = &rt.exes[name];
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(inp.data);
                lit.reshape(inp.dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let members = result
            .to_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        members
            .into_iter()
            .map(|m| m.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    })
}
