//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` lowers the Layer-2 JAX programs (which wrap the Layer-1
//! Pallas kernels) to HLO *text* in `artifacts/`, indexed by
//! `manifest.txt`. At run time this module compiles them on the PJRT CPU
//! client (`xla` crate: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile`) and executes them from
//! the engines' hot paths. Python is never invoked.
//!
//! The execution backend is selected by the `pjrt` cargo feature:
//!
//! * `--features pjrt` — the real backend (the `pjrt` submodule) backed by
//!   the native `xla` crate;
//! * default — a pure-Rust stub with no native prerequisites:
//!   [`available`] returns `false` and [`exec`] returns a clean error, so
//!   engines and apps always take their native math paths.
//!
//! Manifest parsing ([`Manifest`]) and the [`Input`] tensor type are
//! backend-independent and always compiled.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{anyhow, Context, Result};

/// Metadata of one artifact from `manifest.txt`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (file stem).
    pub name: String,
    /// Kernel kind: `pagerank`, `als_accum`, `als_solve`, `als_update`,
    /// `lbp`, `coem`, `coem_accum`.
    pub kind: String,
    /// Static dims (`b`, `n`, `d`, `l`, `k` as present).
    pub dims: HashMap<String, usize>,
    /// Input shapes (row-major dims).
    pub in_shapes: Vec<Vec<usize>>,
    /// Output shapes.
    pub out_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    /// Dim lookup with panic-on-missing (manifest is trusted build output).
    pub fn dim(&self, key: &str) -> usize {
        self.dims[key]
    }
}

/// Parsed manifest: artifact name → metadata.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    entries: HashMap<String, ArtifactMeta>,
    dir: PathBuf,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?;
            let mut meta = ArtifactMeta {
                name: name.to_string(),
                kind: String::new(),
                dims: HashMap::new(),
                in_shapes: Vec::new(),
                out_shapes: Vec::new(),
            };
            for kv in parts {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("bad manifest field {kv}"))?;
                match k {
                    "kind" => meta.kind = v.to_string(),
                    "in" => meta.in_shapes = parse_shapes(v)?,
                    "out" => meta.out_shapes = parse_shapes(v)?,
                    dim => {
                        meta.dims.insert(dim.to_string(), v.parse()?);
                    }
                }
            }
            entries.insert(name.to_string(), meta);
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Artifact metadata by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.get(name)
    }

    /// All artifacts of a kind, sorted by name.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self.entries.values().filter(|m| m.kind == kind).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the manifest is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    s.split(';')
        .map(|shape| {
            if shape == "scalar" {
                return Ok(Vec::new());
            }
            shape.split('x').map(|d| d.parse().map_err(Into::into)).collect()
        })
        .collect()
}

static ARTIFACTS_DIR: OnceLock<PathBuf> = OnceLock::new();
static MANIFEST: OnceLock<Option<Manifest>> = OnceLock::new();

/// Set the artifact directory (default `artifacts/`, overridable by the
/// `GRAPHLAB_ARTIFACTS` env var). Must be called before first [`exec`] to
/// have effect.
pub fn set_artifacts_dir(dir: impl Into<PathBuf>) {
    let _ = ARTIFACTS_DIR.set(dir.into());
}

fn artifacts_dir() -> PathBuf {
    if let Some(d) = ARTIFACTS_DIR.get() {
        return d.clone();
    }
    if let Ok(d) = std::env::var("GRAPHLAB_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let local = PathBuf::from("artifacts");
    if local.exists() {
        return local;
    }
    // Cargo runs test/bench binaries with cwd = the package dir (rust/),
    // while `make artifacts` writes to the repository root next to the
    // workspace manifest — fall back to that location.
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("artifacts");
    if repo_root.exists() {
        return repo_root;
    }
    local
}

/// The global manifest (None if artifacts are not built). Engines fall
/// back to native math when unavailable.
pub fn manifest() -> Option<&'static Manifest> {
    MANIFEST
        .get_or_init(|| Manifest::load(&artifacts_dir()).ok())
        .as_ref()
}

/// Whether compiled artifacts can actually be executed: true only when the
/// crate was built with the `pjrt` feature *and* `make artifacts` has been
/// run. Callers use this to pick between the PJRT and native math paths.
pub fn available() -> bool {
    cfg!(feature = "pjrt") && manifest().is_some()
}

/// An input tensor for [`exec`]: row-major f32 data + dims.
pub struct Input<'a> {
    /// Row-major f32 buffer.
    pub data: &'a [f32],
    /// Dimensions.
    pub dims: &'a [i64],
}

impl<'a> Input<'a> {
    /// Construct (checks element count in debug builds).
    pub fn new(data: &'a [f32], dims: &'a [i64]) -> Self {
        debug_assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>(),
            "input data/dims mismatch"
        );
        Input { data, dims }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::exec;

/// Execute artifact `name` on this thread's PJRT client (stub backend).
///
/// The crate was built without the `pjrt` feature, so there is no PJRT
/// client to execute on: this always returns an error. Engines never reach
/// it unless an app was explicitly configured with `use_pjrt: true` while
/// [`available`] is false.
#[cfg(not(feature = "pjrt"))]
pub fn exec(name: &str, _inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
    anyhow::bail!(
        "artifact {name} requested but the PJRT runtime is not compiled in \
         (rebuild with `cargo build --features pjrt` and run `make artifacts`)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        available()
    }

    #[test]
    fn stub_backend_is_inert_without_pjrt_feature() {
        if cfg!(feature = "pjrt") {
            return;
        }
        assert!(!available(), "stub backend must report unavailable");
        let data = [0.0f32; 4];
        let err = exec("pagerank_b256_n32", &[Input::new(&data, &[2, 2])])
            .expect_err("stub exec must error");
        assert!(err.to_string().contains("pjrt"), "actionable error: {err}");
    }

    #[test]
    fn manifest_parses_without_artifacts_built() {
        // Backend-independent: parse a manifest written to a temp dir.
        let dir = std::env::temp_dir().join(format!(
            "graphlab-manifest-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "pagerank_b256_n32 kind=pagerank b=256 n=32 in=256x32;256x32;256 out=256\n\
             als_solve_b64_d5 kind=als_solve b=64 d=5 in=64x5x5;64x5;1 out=64x5\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert!(!m.is_empty());
        assert_eq!(m.dir(), dir.as_path());
        let pr = m.get("pagerank_b256_n32").unwrap();
        assert_eq!(pr.kind, "pagerank");
        assert_eq!(pr.dim("b"), 256);
        assert_eq!(pr.in_shapes, vec![vec![256, 32], vec![256, 32], vec![256]]);
        assert_eq!(pr.out_shapes, vec![vec![256]]);
        assert_eq!(m.by_kind("als_solve").len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_parses_when_built() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = manifest().unwrap();
        assert!(m.len() >= 10, "expected full artifact set, got {}", m.len());
        let pr = m.get("pagerank_b256_n32").expect("pagerank artifact");
        assert_eq!(pr.kind, "pagerank");
        assert_eq!(pr.dim("b"), 256);
        assert_eq!(pr.in_shapes[0], vec![256, 32]);
        assert_eq!(pr.out_shapes[0], vec![256]);
        assert!(!m.by_kind("als_update").is_empty());
    }

    #[test]
    fn pagerank_artifact_matches_native() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (b, n) = (256usize, 32usize);
        let mut rng = crate::util::Rng::new(1);
        let ranks: Vec<f32> = (0..b * n).map(|_| rng.f32()).collect();
        let weights: Vec<f32> = (0..b * n).map(|_| rng.f32()).collect();
        let base: Vec<f32> = (0..b).map(|_| rng.f32() * 0.1).collect();
        let out = exec(
            "pagerank_b256_n32",
            &[
                Input::new(&ranks, &[b as i64, n as i64]),
                Input::new(&weights, &[b as i64, n as i64]),
                Input::new(&base, &[b as i64]),
            ],
        )
        .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b);
        for i in 0..b {
            let want: f32 = base[i]
                + (0..n).map(|j| ranks[i * n + j] * weights[i * n + j]).sum::<f32>();
            assert!((out[0][i] - want).abs() < 1e-4, "i={i}: {} vs {want}", out[0][i]);
        }
    }

    #[test]
    fn als_update_artifact_matches_native_solver() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (b, n, d) = (64usize, 32usize, 5usize);
        let mut rng = crate::util::Rng::new(2);
        let v: Vec<f32> = (0..b * n * d).map(|_| rng.normal() * 0.5).collect();
        let r: Vec<f32> = (0..b * n).map(|_| rng.uniform(1.0, 5.0)).collect();
        let m: Vec<f32> = (0..b * n).map(|_| (rng.f32() < 0.8) as u8 as f32).collect();
        let lam = [0.3f32];
        let out = exec(
            "als_update_b64_n32_d5",
            &[
                Input::new(&v, &[b as i64, n as i64, d as i64]),
                Input::new(&r, &[b as i64, n as i64]),
                Input::new(&m, &[b as i64, n as i64]),
                Input::new(&lam, &[1]),
            ],
        )
        .unwrap();
        assert_eq!(out[0].len(), b * d);
        // Cross-check a few batch rows against the native Cholesky path.
        for bi in [0usize, 17, 63] {
            let mut a = crate::util::matrix::Mat::zeros(d, d);
            let mut y = vec![0.0f32; d];
            for j in 0..n {
                if m[bi * n + j] == 0.0 {
                    continue;
                }
                let row = &v[(bi * n + j) * d..(bi * n + j + 1) * d];
                a.rank1_update(row, 1.0);
                crate::util::matrix::axpy(&mut y, row, r[bi * n + j]);
            }
            let x = crate::util::matrix::solve_psd(&a, &y, lam[0]);
            for k in 0..d {
                let got = out[0][bi * d + k];
                assert!(
                    (got - x[k]).abs() < 2e-2,
                    "b={bi} k={k}: pjrt={got} native={}",
                    x[k]
                );
            }
        }
    }
}
