//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` lowers the Layer-2 JAX programs (which wrap the Layer-1
//! Pallas kernels) to HLO *text* in `artifacts/`, indexed by
//! `manifest.txt`. At run time this module compiles them on the PJRT CPU
//! client (`xla` crate: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile`) and executes them from
//! the engines' hot paths. Python is never invoked.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each
//! engine thread lazily creates its own client and executable cache via a
//! thread-local ([`exec`] hides this). Compilation is per-thread but
//! happens once per (thread, artifact) and is excluded from benchmark
//! timings by a warmup call.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use anyhow::{anyhow, bail, Context, Result};

/// Metadata of one artifact from `manifest.txt`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (file stem).
    pub name: String,
    /// Kernel kind: `pagerank`, `als_accum`, `als_solve`, `als_update`,
    /// `lbp`, `coem`, `coem_accum`.
    pub kind: String,
    /// Static dims (`b`, `n`, `d`, `l`, `k` as present).
    pub dims: HashMap<String, usize>,
    /// Input shapes (row-major dims).
    pub in_shapes: Vec<Vec<usize>>,
    /// Output shapes.
    pub out_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    /// Dim lookup with panic-on-missing (manifest is trusted build output).
    pub fn dim(&self, key: &str) -> usize {
        self.dims[key]
    }
}

/// Parsed manifest: artifact name → metadata.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    entries: HashMap<String, ArtifactMeta>,
    dir: PathBuf,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut entries = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().ok_or_else(|| anyhow!("empty manifest line"))?;
            let mut meta = ArtifactMeta {
                name: name.to_string(),
                kind: String::new(),
                dims: HashMap::new(),
                in_shapes: Vec::new(),
                out_shapes: Vec::new(),
            };
            for kv in parts {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("bad manifest field {kv}"))?;
                match k {
                    "kind" => meta.kind = v.to_string(),
                    "in" => meta.in_shapes = parse_shapes(v)?,
                    "out" => meta.out_shapes = parse_shapes(v)?,
                    dim => {
                        meta.dims.insert(dim.to_string(), v.parse()?);
                    }
                }
            }
            entries.insert(name.to_string(), meta);
        }
        Ok(Manifest {
            entries,
            dir: dir.to_path_buf(),
        })
    }

    /// Artifact metadata by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.get(name)
    }

    /// All artifacts of a kind, sorted by name.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self.entries.values().filter(|m| m.kind == kind).collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Artifact directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of artifacts.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the manifest is empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    s.split(';')
        .map(|shape| {
            if shape == "scalar" {
                return Ok(Vec::new());
            }
            shape.split('x').map(|d| d.parse().map_err(Into::into)).collect()
        })
        .collect()
}

static ARTIFACTS_DIR: OnceLock<PathBuf> = OnceLock::new();
static MANIFEST: OnceLock<Option<Manifest>> = OnceLock::new();

/// Set the artifact directory (default `artifacts/`, overridable by the
/// `GRAPHLAB_ARTIFACTS` env var). Must be called before first [`exec`] to
/// have effect.
pub fn set_artifacts_dir(dir: impl Into<PathBuf>) {
    let _ = ARTIFACTS_DIR.set(dir.into());
}

fn artifacts_dir() -> PathBuf {
    ARTIFACTS_DIR
        .get()
        .cloned()
        .or_else(|| std::env::var("GRAPHLAB_ARTIFACTS").ok().map(PathBuf::from))
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// The global manifest (None if artifacts are not built). Engines fall
/// back to native math when unavailable.
pub fn manifest() -> Option<&'static Manifest> {
    MANIFEST
        .get_or_init(|| Manifest::load(&artifacts_dir()).ok())
        .as_ref()
}

/// Whether compiled artifacts are available.
pub fn available() -> bool {
    manifest().is_some()
}

thread_local! {
    static TLS: RefCell<Option<ThreadRuntime>> = const { RefCell::new(None) };
}

struct ThreadRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// An input tensor for [`exec`]: row-major f32 data + dims.
pub struct Input<'a> {
    /// Row-major f32 buffer.
    pub data: &'a [f32],
    /// Dimensions.
    pub dims: &'a [i64],
}

impl<'a> Input<'a> {
    /// Construct (checks element count in debug builds).
    pub fn new(data: &'a [f32], dims: &'a [i64]) -> Self {
        debug_assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>(),
            "input data/dims mismatch"
        );
        Input { data, dims }
    }
}

/// Execute artifact `name` on this thread's PJRT client. Inputs are f32
/// tensors; outputs are the flattened f32 elements of each tuple member.
pub fn exec(name: &str, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
    TLS.with(|tls| {
        let mut slot = tls.borrow_mut();
        if slot.is_none() {
            *slot = Some(ThreadRuntime {
                client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
                exes: HashMap::new(),
            });
        }
        let rt = slot.as_mut().unwrap();
        if !rt.exes.contains_key(name) {
            let path = artifacts_dir().join(format!("{name}.hlo.txt"));
            if !path.exists() {
                bail!("artifact {} not found (run `make artifacts`)", path.display());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = rt
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            rt.exes.insert(name.to_string(), exe);
        }
        let exe = &rt.exes[name];
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(inp.data);
                lit.reshape(inp.dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let members = result
            .to_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        members
            .into_iter()
            .map(|m| m.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        available()
    }

    #[test]
    fn manifest_parses_when_built() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = manifest().unwrap();
        assert!(m.len() >= 10, "expected full artifact set, got {}", m.len());
        let pr = m.get("pagerank_b256_n32").expect("pagerank artifact");
        assert_eq!(pr.kind, "pagerank");
        assert_eq!(pr.dim("b"), 256);
        assert_eq!(pr.in_shapes[0], vec![256, 32]);
        assert_eq!(pr.out_shapes[0], vec![256]);
        assert!(!m.by_kind("als_update").is_empty());
    }

    #[test]
    fn pagerank_artifact_matches_native() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (b, n) = (256usize, 32usize);
        let mut rng = crate::util::Rng::new(1);
        let ranks: Vec<f32> = (0..b * n).map(|_| rng.f32()).collect();
        let weights: Vec<f32> = (0..b * n).map(|_| rng.f32()).collect();
        let base: Vec<f32> = (0..b).map(|_| rng.f32() * 0.1).collect();
        let out = exec(
            "pagerank_b256_n32",
            &[
                Input::new(&ranks, &[b as i64, n as i64]),
                Input::new(&weights, &[b as i64, n as i64]),
                Input::new(&base, &[b as i64]),
            ],
        )
        .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b);
        for i in 0..b {
            let want: f32 = base[i]
                + (0..n).map(|j| ranks[i * n + j] * weights[i * n + j]).sum::<f32>();
            assert!((out[0][i] - want).abs() < 1e-4, "i={i}: {} vs {want}", out[0][i]);
        }
    }

    #[test]
    fn als_update_artifact_matches_native_solver() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (b, n, d) = (64usize, 32usize, 5usize);
        let mut rng = crate::util::Rng::new(2);
        let v: Vec<f32> = (0..b * n * d).map(|_| rng.normal() * 0.5).collect();
        let r: Vec<f32> = (0..b * n).map(|_| rng.uniform(1.0, 5.0)).collect();
        let m: Vec<f32> = (0..b * n).map(|_| (rng.f32() < 0.8) as u8 as f32).collect();
        let lam = [0.3f32];
        let out = exec(
            "als_update_b64_n32_d5",
            &[
                Input::new(&v, &[b as i64, n as i64, d as i64]),
                Input::new(&r, &[b as i64, n as i64]),
                Input::new(&m, &[b as i64, n as i64]),
                Input::new(&lam, &[1]),
            ],
        )
        .unwrap();
        assert_eq!(out[0].len(), b * d);
        // Cross-check a few batch rows against the native Cholesky path.
        for bi in [0usize, 17, 63] {
            let mut a = crate::util::matrix::Mat::zeros(d, d);
            let mut y = vec![0.0f32; d];
            for j in 0..n {
                if m[bi * n + j] == 0.0 {
                    continue;
                }
                let row = &v[(bi * n + j) * d..(bi * n + j + 1) * d];
                a.rank1_update(row, 1.0);
                crate::util::matrix::axpy(&mut y, row, r[bi * n + j]);
            }
            let x = crate::util::matrix::solve_psd(&a, &y, lam[0]);
            for k in 0..d {
                let got = out[0][bi * d + k];
                assert!(
                    (got - x[k]).abs() < 2e-2,
                    "b={bi} k={k}: pjrt={got} native={}",
                    x[k]
                );
            }
        }
    }
}
