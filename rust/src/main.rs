//! `graphlab` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!
//! * `run <app>` — run one application end-to-end on synthetic data:
//!   `pagerank | als | ner | coseg | gibbs`. Every app accepts
//!   `--engine shared|chromatic|locking` (the unified `engine::Engine`
//!   builder dispatches at runtime), plus `--machines N`, `--threads N`,
//!   `--scheduler POLICY`, `--transport inproc|tcp` (real loopback
//!   sockets under the distributed engines), `--pjrt`, app-specific size
//!   flags, and `--config FILE` overlays. `POLICY` is
//!   `fifo|priority|multiqueue|sweep` (work-stealing per-worker queues on
//!   the shared engine; per-machine queues on the locking engine) or
//!   `global-<policy>` (single shared queue — the contended baseline,
//!   shared engine only). With `--cluster HOSTS` the run becomes machine
//!   0 of a real multi-process cluster (one `host:port` line per machine
//!   in HOSTS); requires `--atoms-dir` so every process derives the same
//!   placement from the stored meta-graph. `--snapshot-every K|Ns` cuts a
//!   Chandy–Lamport snapshot every K updates (or every N seconds) into
//!   `--snapshot-dir` (default: the atom-store dir); `--restore DIR`
//!   resumes from the newest complete snapshot under DIR (paper Sec. 4.3).
//! * `worker [<app>] --me N --hosts HOSTS --atoms-dir DIR` — join a
//!   multi-process cluster as machine N: build machine N's engine state
//!   by replaying its own atom journals and speak the engine protocol
//!   over TCP. (The process also replays the full store once for the
//!   global topology — coloring and result reassembly; making workers
//!   fully journal-local is a ROADMAP item.) The app is inferred from
//!   the atom store's stored type tags when omitted.
//! * `figure <name>` — regenerate a paper table/figure (`table2`, `fig1`,
//!   `fig5a`, `fig6a`..`fig8d`, or `all`) into `--out-dir` (default
//!   `results/`).
//! * `partition [<app>]` — with an app name, build that app's data graph
//!   and write it to disk as the paper's atom store (`--atoms-dir DIR`,
//!   default `atoms/`; `--atoms K` controls the over-partition size);
//!   `graphlab run <app> --atoms-dir DIR` then loads the same store on
//!   any machine count, each machine replaying only its own atom
//!   journals. Without an app: the two-phase partitioning quality demo.
//! * `calibrate` — print the measured per-update costs feeding the
//!   cluster model.
//! * `lab` — the experiment lab (`rust/src/lab/`): expand a JSON sweep
//!   config (`--config FILE` or `--preset quick|sched|engines|wire|net|
//!   serve|fig6b|fig8b|all`) into a cell matrix, supervise each cell as a
//!   child process (timeouts, retry-on-port-conflict, optional CPU
//!   pinning), ingest stdout into structured records, and append them to
//!   the JSONL run database (`artifacts/lab/runs.jsonl`). `lab report`
//!   prints per-cell medians and regression deltas against the committed
//!   baseline; `lab micro <name>` runs one micro-benchmark cell. Schema
//!   and metrics are documented in `BENCHMARKS.md`.
//! * `serve` — long-lived serving cluster (DESIGN.md §Serving): converge
//!   PageRank, then stay resident answering client queries and applying
//!   streaming graph mutations with incremental recomputation (only the
//!   dirtied neighborhood is rescheduled). In-proc by default
//!   (`--machines N`, threads), or one machine per process with
//!   `--cluster HOSTS --me N --atoms-dir DIR`. Machine 0 (the frontend)
//!   binds the client listener at `--listen` (default `127.0.0.1:7700`).
//! * `client` — one RPC against a serving frontend (`--addr HOST:PORT`):
//!   `query V`, `add-edge U V W`, `rm-edge U V`, `set-weight U V W`,
//!   `touch V`, `stats`, `shutdown`.
//! * `bench-serve` — serving-mode benchmark: mutation throughput +
//!   query latency on an in-proc cluster (the lab `serve` preset's child
//!   entry point).
//! * `bench-sched` / `bench-engines` / `bench-wire` / `bench-net` —
//!   historical one-shot benchmarks, now thin forwards onto the lab
//!   presets `sched`/`engines`/`wire`/`net` (results go to the run
//!   database, not `BENCH_prN.json`).
//!
//! Examples:
//!
//! ```text
//! graphlab run als --machines 4 --d 20 --sweeps 20 --pjrt
//! graphlab run pagerank --engine shared --threads 8 --scheduler multiqueue
//! graphlab run gibbs --engine locking --machines 4
//! graphlab run pagerank --machines 2 --transport tcp
//! graphlab partition pagerank --atoms-dir atoms/ --atoms 64
//! graphlab run pagerank --engine locking --atoms-dir atoms/
//! graphlab worker --me 1 --hosts hosts.txt --atoms-dir atoms/   # then, elsewhere:
//! graphlab run pagerank --cluster hosts.txt --atoms-dir atoms/
//! graphlab figure fig6d --out-dir results/
//! graphlab serve --machines 3 --n 100000 --listen 127.0.0.1:7700   # resident cluster
//! graphlab client query 42 --addr 127.0.0.1:7700
//! graphlab client add-edge 7 99 0.11 --addr 127.0.0.1:7700
//! graphlab lab --quick                  # 8-cell smoke matrix + report
//! graphlab lab --config configs/fig8b.json
//! graphlab lab report
//! ```

use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use graphlab::apps::{self, als, coseg, gibbs, ner, pagerank};
use graphlab::distributed::{ClusterConfig, SnapshotTrigger, TransportKind};
use graphlab::engine::{Engine, EngineKind};
use graphlab::partition::atoms::{self, AtomSet};
use graphlab::partition::Partition;
use graphlab::scheduler::SchedSpec;
use graphlab::util::cli::Args;
use graphlab::util::config::Config;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::new();
    // `lab` interprets --config itself (a JSON sweep file, not the
    // INI-style run overlay every other subcommand takes).
    if args.pos(0) != Some("lab") {
        if let Some(path) = args.get("config") {
            cfg = Config::load(path)?;
        }
    }
    cfg.overlay(args.flags());
    match args.pos(0) {
        Some("run") => {
            let app = args.pos(1).unwrap_or("pagerank").to_string();
            // --cluster HOSTS: this process is machine `--me` (default 0,
            // the driver) of a real multi-process TCP cluster.
            let cluster = match cfg.get("cluster") {
                Some(path) if path != "true" => Some(ClusterConfig {
                    me: cfg.num_or("me", 0usize)?,
                    hosts: read_hosts(path)?,
                }),
                Some(_) => bail!("--cluster needs a hosts file (one host:port per machine)"),
                None => None,
            };
            run_app(&app, &cfg, cluster)
        }
        Some("worker") => worker(&args, &cfg),
        Some("figure") => {
            let name = args.pos(1).unwrap_or("all").to_string();
            let out = cfg.str_or("out-dir", "results");
            graphlab::sim::figures::run_figure(&name, std::path::Path::new(&out))
        }
        Some("partition") => match args.pos(1) {
            Some(app) => partition_app(app, &cfg),
            None => partition_demo(&cfg),
        },
        Some("calibrate") => calibrate(&cfg),
        Some("lab") => lab_cmd(&args, &cfg),
        Some("serve") => {
            let cluster = match cfg.get("cluster") {
                Some(path) if path != "true" => Some(ClusterConfig {
                    me: cfg.num_or("me", 0usize)?,
                    hosts: read_hosts(path)?,
                }),
                Some(_) => bail!("--cluster needs a hosts file (one host:port per machine)"),
                None => None,
            };
            serve_cmd(&cfg, cluster)
        }
        Some("client") => client_cmd(&args, &cfg),
        Some("bench-serve") => bench_serve(&cfg),
        // The four historical bench subcommands forward to their lab
        // preset sweeps (see BENCHMARKS.md for the migration table).
        Some("bench-sched") => bench_forward("bench-sched", "sched", &cfg),
        Some("bench-engines") => bench_forward("bench-engines", "engines", &cfg),
        Some("bench-wire") => bench_forward("bench-wire", "wire", &cfg),
        Some("bench-net") => bench_forward("bench-net", "net", &cfg),
        _ => {
            eprintln!(
                "usage: graphlab <run|worker|serve|client|figure|partition|calibrate|lab|bench-*> [...]\n"
            );
            eprintln!("  graphlab run <pagerank|als|ner|coseg|gibbs> [--engine shared|chromatic|locking]");
            eprintln!("      [--machines N] [--threads N] [--scheduler fifo|priority|multiqueue|sweep|global-*]");
            eprintln!("      [--transport inproc|tcp] [--cluster HOSTS] [--pjrt] [--sweeps N] [--d N]");
            eprintln!("      [--eps X] [--latency-us N] [--atoms-dir DIR] [--pin-threads]");
            eprintln!("      [--snapshot-every K|Ns] [--snapshot-dir DIR] [--restore DIR] [--config FILE]");
            eprintln!("  graphlab worker [<app>] --me N --hosts HOSTS --atoms-dir DIR [--engine E]");
            eprintln!("      [--snapshot-every K|Ns] [--snapshot-dir DIR] [--restore DIR]");
            eprintln!("      (join a multi-process cluster as machine N; app inferred from the store)");
            eprintln!("  graphlab partition <pagerank|als|ner|coseg|gibbs> [--atoms-dir DIR] [--atoms K]");
            eprintln!("      (writes the app's data graph as an on-disk atom store; omit the app for the demo)");
            eprintln!("  graphlab figure <table2|fig1|fig5a|fig6a|fig6c|fig6d|fig7a|fig8a|fig8b|fig8c|fig8d|all>");
            eprintln!("      [--out-dir DIR]");
            eprintln!("  graphlab lab [--config FILE.json | --preset NAME[,NAME]|all] [--quick]");
            eprintln!("      [--db FILE] [--inproc] [--bin PATH] [--verbose]");
            eprintln!("      (run a sweep matrix; appends JSONL rows to artifacts/lab/runs.jsonl)");
            eprintln!("  graphlab lab report [--db FILE] [--baseline FILE]");
            eprintln!("      (per-cell medians + regression deltas vs the committed baseline)");
            eprintln!("  graphlab lab micro <wire-codec|atom-store|net-pingpong-inproc|");
            eprintln!("      net-pingpong-tcp|frame-pool|coalesce> [--n N] [--seed S]");
            eprintln!("  graphlab serve [--machines N] [--n N] [--listen HOST:PORT] [--eps X]");
            eprintln!("      [--transport inproc|tcp] [--cluster HOSTS --me N --atoms-dir DIR]");
            eprintln!("      (resident serving cluster: queries + streaming mutations with");
            eprintln!("       incremental recomputation; machine 0 hosts the client port)");
            eprintln!("  graphlab client <query V|add-edge U V W|rm-edge U V|set-weight U V W|");
            eprintln!("      touch V|stats|shutdown> [--addr HOST:PORT]");
            eprintln!("  graphlab bench-serve [--machines N] [--n N] [--mutrate N] [--batches N]");
            eprintln!("      [--queries N] [--transport inproc|tcp] [--eps X] [--seed S]");
            eprintln!("  graphlab bench-sched|bench-engines|bench-wire|bench-net [--quick]");
            eprintln!("      (forward to `lab --preset sched|engines|wire|net`)");
            bail!("missing subcommand");
        }
    }
}

/// Parse a hosts file: one `host:port` per line; blank lines and `#`
/// comments are skipped, so the machine id is the index among the
/// *remaining* lines — commenting a host out renumbers every machine
/// after it (keep `--me` values in sync).
fn read_hosts(path: &str) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading hosts file {path}"))?;
    let hosts: Vec<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect();
    if hosts.is_empty() {
        bail!("hosts file {path} lists no machines");
    }
    Ok(hosts)
}

/// Map an atom store's stored vertex type name to the app that wrote it,
/// so `graphlab worker` can join a cluster knowing only the store. Reads
/// only the store's type tags (`peek_types`), not the whole meta file.
fn infer_app(dir: &std::path::Path) -> Result<&'static str> {
    let (vtype, _etype) = atoms::peek_types(dir)?;
    for (needle, app) in [
        ("pagerank::PrVertex", "pagerank"),
        ("als::AlsVertex", "als"),
        ("ner::NerVertex", "ner"),
        ("coseg::CosegVertex", "coseg"),
        ("gibbs::GibbsVertex", "gibbs"),
    ] {
        if vtype.ends_with(needle) {
            return Ok(app);
        }
    }
    bail!(
        "atom store {} holds unrecognized vertex type {vtype} — name the app explicitly",
        dir.display()
    );
}

/// `graphlab worker [<app>] --me N --hosts FILE --atoms-dir DIR`: join a
/// multi-process cluster as machine N. Identical engine code path to
/// `run --cluster`; only the machine id differs.
///
/// Every process derives its engine configuration from its OWN command
/// line — the handshake validates wire version, cluster size, and app
/// type, but not runtime flags. Launch all processes with identical
/// `--engine`/`--sweeps`/`--max-updates`/`--maxpending`/`--scheduler`/
/// `--seed` values (only `--me` differs), or per-machine behavior (e.g.
/// the locking engine's per-machine update caps) silently diverges.
fn worker(args: &Args, cfg: &Config) -> Result<()> {
    let Some(me_raw) = cfg.get("me") else {
        bail!("worker requires --me N (this process's machine id)");
    };
    let me: usize = me_raw
        .parse()
        .map_err(|e| anyhow::anyhow!("--me={me_raw}: {e}"))?;
    let Some(hosts_path) = cfg.get("hosts") else {
        bail!("worker requires --hosts FILE (one host:port per machine)");
    };
    let hosts = read_hosts(hosts_path)?;
    let Some(dir) = atoms_dir_flag(cfg) else {
        bail!(
            "worker requires --atoms-dir DIR: every process must replay the same \
             atom store (write one with `graphlab partition <app>`)"
        );
    };
    let app = match args.pos(1) {
        Some(a) => a.to_string(),
        None => infer_app(&dir)?.to_string(),
    };
    run_app(&app, cfg, Some(ClusterConfig { me, hosts }))
}

fn run_app(app: &str, cfg: &Config, cluster: Option<ClusterConfig>) -> Result<()> {
    let engine: EngineKind = cfg
        .str_or("engine", "chromatic")
        .parse()
        .context("--engine")?;
    let machines = cfg.num_or("machines", 2usize)?;
    let threads = cfg.num_or("threads", 2usize)?;
    let sweeps = cfg.num_or("sweeps", 20u64)?;
    let use_pjrt = cfg.bool_or("pjrt", false);
    if use_pjrt && !graphlab::runtime::available() {
        bail!(
            "--pjrt requested but the PJRT runtime is unavailable \
             (build with `--features pjrt` and run `make artifacts`)"
        );
    }
    let seed = cfg.num_or("seed", 1u64)?;
    // When --atoms-dir is given, the data graph is loaded from the on-disk
    // atom store (written by `graphlab partition <app>`) instead of being
    // regenerated; the distributed engines additionally replay each
    // machine's own atom journals (routed via `Engine::atoms_dir`).
    let atoms_dir = atoms_dir_flag(cfg);
    if let Some(c) = &cluster {
        if atoms_dir.is_none() {
            bail!(
                "cluster mode requires --atoms-dir: every process must derive the \
                 identical graph and placement from one stored atom set \
                 (run `graphlab partition {app}` first)"
            );
        }
        println!(
            "== graphlab run {app} (engine={engine}, cluster machine {}/{} over tcp) ==",
            c.me,
            c.hosts.len()
        );
    } else {
        let transport = cfg.str_or("transport", "inproc");
        println!(
            "== graphlab run {app} (engine={engine}, machines={machines}, transport={transport}) =="
        );
    }

    match app {
        "pagerank" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let n = cfg.num_or("n", 10_000usize)?;
                    let edges =
                        graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8)?, seed);
                    pagerank::build(n, &edges, 0.15)
                }
            };
            let n = g.num_vertices();
            // --eps 0 keeps every update rescheduling its neighbors, so
            // benchmark runs execute the full capped workload (the lab's
            // convention); the default converges normally.
            let eps = cfg.num_or("eps", 1e-6f32)?;
            let prog = pagerank::PageRank { alpha: 0.15, eps, n, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(pagerank::total_rank_sync())], "total_rank")
        }
        "als" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::netflix(
                        cfg.num_or("users", 2000)?, cfg.num_or("movies", 1000)?,
                        cfg.num_or("ratings-per-user", 30)?, 8, 0.2, seed);
                    als::build(&data, cfg.num_or("d", 20usize)?, seed)
                }
            };
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            anyhow::ensure!(g.num_vertices() > 0, "empty graph: nothing to run");
            // The latent dimension travels with the stored factors.
            let d = g.vertex_data(0).factor.len();
            let prog = als::Als { d, lambda: 0.08, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(als::rmse_sync())], "rmse")
        }
        "ner" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::ner(
                        cfg.num_or("nps", 5000)?, cfg.num_or("contexts", 2500)?,
                        cfg.num_or("edges-per-np", 30)?, 8, 0.1, seed);
                    ner::build(&data)
                }
            };
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            anyhow::ensure!(g.num_vertices() > 0, "empty graph: nothing to run");
            let k = g.vertex_data(0).dist.len();
            let prog = ner::Coem { k, smoothing: 0.01, eps: 1e-4, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(ner::accuracy_sync())], "accuracy")
        }
        "coseg" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::video(
                        cfg.num_or("frames", 16)?, cfg.num_or("width", 24)?,
                        cfg.num_or("height", 20)?, 5, 0.4, seed);
                    coseg::build(&data, 0.8)
                }
            };
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            anyhow::ensure!(g.num_vertices() > 0, "empty graph: nothing to run");
            let labels = g.vertex_data(0).belief.len();
            let prog = coseg::Coseg { labels, eps: 1e-3, sigma2: 0.5, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(coseg::gmm_sync(labels)), Box::new(coseg::accuracy_sync())],
                "accuracy")
        }
        "gibbs" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::mrf(cfg.num_or("side", 64)?, 0.4, seed);
                    gibbs::build(&data)
                }
            };
            let prog = gibbs::Gibbs { coupling: 0.4, target_samples: sweeps.max(10), seed };
            run_generic(g, prog, engine, machines, threads, u64::MAX, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(gibbs::magnetization_sync())], "magnetization")
        }
        other => bail!("unknown app '{other}'"),
    }
}

/// Run a (graph, program) pair on the engine selected by `--engine`: one
/// builder call covers all three engines (and, with `cluster`, one
/// machine of a real multi-process TCP cluster).
#[allow(clippy::too_many_arguments)]
fn run_generic<V, E, P>(
    g: graphlab::graph::Graph<V, E>,
    prog: P,
    engine: EngineKind,
    machines: usize,
    threads: usize,
    sweeps: u64,
    cfg: &Config,
    atoms_dir: Option<&std::path::Path>,
    cluster: Option<ClusterConfig>,
    syncs: Vec<Box<dyn graphlab::engine::SyncOp<V>>>,
    probe_key: &'static str,
) -> Result<()>
where
    V: graphlab::distributed::DataValue,
    E: graphlab::distributed::DataValue,
    P: graphlab::engine::VertexProgram<V, E>,
{
    let n = g.num_vertices();
    let initial = apps::all_vertices(n);
    let seed = cfg.num_or("seed", 1u64)?;
    let sched_default = if engine == EngineKind::Locking { "priority" } else { "fifo" };
    let spec = SchedSpec::parse(&cfg.str_or("scheduler", sched_default), seed)
        .context("--scheduler")?;
    let transport: TransportKind = cfg
        .str_or("transport", "inproc")
        .parse()
        .context("--transport")?;
    // Update cap: a safety net for non-converging runs (the chromatic
    // engine is capped in whole sweeps via max_sweeps instead).
    let max_updates = cfg.num_or("max-updates", n as u64 * sweeps.min(10_000))?;
    let me = cluster.as_ref().map(|c| c.me);
    // The final value of the probe sync (e.g. PageRank's total_rank) —
    // printed after the run so cluster smoke tests can compare the
    // cluster result against an in-process oracle.
    let last_probe = std::sync::Arc::new(std::sync::Mutex::new(None::<f64>));
    let probe_out = last_probe.clone();
    let mut builder = Engine::new(engine)
        .workers(threads)
        .machines(machines)
        .scheduler(spec)
        .seed(seed)
        .transport(transport)
        .max_updates(max_updates)
        .max_sweeps(sweeps)
        .maxpending(cfg.num_or("maxpending", 64usize)?)
        .pin_threads(cfg.bool_or("pin-threads", false))
        .sync_period(Duration::from_millis(cfg.num_or("sync-ms", 100u64)?))
        .syncs(syncs)
        .on_progress(move |epoch, updates, gv| {
            if let Some(v) = gv.get(probe_key) {
                *probe_out.lock().unwrap() = Some(v[0]);
                println!("epoch {epoch:>3}: updates={updates:>9} {probe_key}={:.5}", v[0]);
            }
        });
    if let Some(c) = cluster {
        builder = builder.cluster(c.me, c.hosts);
    }
    if let Some(dir) = atoms_dir {
        // Distributed machines replay their own on-disk atom journals.
        builder = builder.atoms_dir(dir);
    }
    // --snapshot-every K|Ns: periodic Chandy–Lamport snapshots to
    // --snapshot-dir (default: the atom-store dir). --restore DIR resumes
    // from the newest complete snapshot under DIR after journal replay.
    if let Some(spec) = cfg.get("snapshot-every") {
        builder = builder.snapshot_every(SnapshotTrigger::parse(spec).context("--snapshot-every")?);
    }
    if let Some(dir) = cfg.get("snapshot-dir") {
        builder = builder.snapshot_to(dir);
    }
    if let Some(dir) = cfg.get("restore") {
        if dir == "true" {
            bail!("--restore needs a directory (the snapshot root)");
        }
        builder = builder.restore_from(dir);
    }
    // --latency-us N: inject one-way delivery latency (in-proc transport
    // only) — the stand-in for WAN round trips in the Fig. 8(b)
    // pipelined-locking sweep.
    let latency_us = cfg.num_or("latency-us", 0u64)?;
    if latency_us > 0 {
        builder = builder.network(graphlab::distributed::NetworkModel {
            latency: Duration::from_micros(latency_us),
        });
    }
    let exec = builder.run(g, &prog, initial)?;
    let stats = &exec.stats;
    match me {
        // Cluster mode: per-machine stats are local to this process.
        Some(me) => println!(
            "done (machine {me}): {} updates, {} epochs, {:.2}s on {engine}, \
             {} bytes sent / {} msgs over tcp",
            stats.updates,
            stats.sweeps,
            stats.seconds,
            stats.bytes_sent.get(me).copied().unwrap_or(0),
            stats.msgs_sent.get(me).copied().unwrap_or(0),
        ),
        None => {
            println!(
                "done: {} updates, {} epochs, {:.2}s on {engine} \
                 ({} machine(s), balance {:.2}, {} MB sent)",
                stats.updates,
                stats.sweeps,
                stats.seconds,
                stats.machines(),
                stats.balance(),
                stats.total_bytes() / 1_000_000
            );
            if engine.is_distributed() {
                println!("bytes sent per machine: {:?}", stats.bytes_sent);
            }
        }
    }
    // The stable machine-readable stats line the experiment lab ingests
    // (`lab-metric k=v …`; schema documented in BENCHMARKS.md).
    println!("{}", stats.lab_metric_line());
    // Machine-parseable result line: the final cluster-wide sync value.
    // Every process of a cluster prints the same number (global syncs are
    // true cluster-wide reductions), so smoke tests can diff any worker's
    // line against an in-process oracle run.
    if let Some(v) = *last_probe.lock().unwrap() {
        println!("probe {probe_key}={v:.9}");
    }
    Ok(())
}

/// `--atoms-dir [DIR]`: an explicit DIR wins; a bare flag resolves the
/// default the cwd-robust way (`GRAPHLAB_ATOMS`, `atoms/`, workspace-root
/// `atoms/`) so `run` and `partition` agree on where the store lives.
fn atoms_dir_flag(cfg: &Config) -> Option<std::path::PathBuf> {
    cfg.get("atoms-dir").map(|v| {
        if v == "true" {
            atoms::resolve_atoms_dir(None)
        } else {
            std::path::PathBuf::from(v)
        }
    })
}

/// `graphlab partition <app>`: build the app's data graph (same flags and
/// datagen as `run`) and write it to disk as the paper's atom store — one
/// journal file per atom plus `meta.bin` — ready for `run --atoms-dir` on
/// any machine count.
fn partition_app(app: &str, cfg: &Config) -> Result<()> {
    let dir = atoms_dir_flag(cfg).unwrap_or_else(|| atoms::resolve_atoms_dir(None));
    let k = cfg.num_or("atoms", 128usize)?;
    let seed = cfg.num_or("seed", 1u64)?;
    match app {
        "pagerank" => {
            let n = cfg.num_or("n", 10_000usize)?;
            let edges = graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8)?, seed);
            save_atom_store(&pagerank::build(n, &edges, 0.15), k, seed, &dir)
        }
        "als" => {
            let data = graphlab::datagen::netflix(
                cfg.num_or("users", 2000)?, cfg.num_or("movies", 1000)?,
                cfg.num_or("ratings-per-user", 30)?, 8, 0.2, seed);
            save_atom_store(&als::build(&data, cfg.num_or("d", 20usize)?, seed), k, seed, &dir)
        }
        "ner" => {
            let data = graphlab::datagen::ner(
                cfg.num_or("nps", 5000)?, cfg.num_or("contexts", 2500)?,
                cfg.num_or("edges-per-np", 30)?, 8, 0.1, seed);
            save_atom_store(&ner::build(&data), k, seed, &dir)
        }
        "coseg" => {
            let data = graphlab::datagen::video(
                cfg.num_or("frames", 16)?, cfg.num_or("width", 24)?,
                cfg.num_or("height", 20)?, 5, 0.4, seed);
            save_atom_store(&coseg::build(&data, 0.8), k, seed, &dir)
        }
        "gibbs" => {
            let data = graphlab::datagen::mrf(cfg.num_or("side", 64)?, 0.4, seed);
            save_atom_store(&gibbs::build(&data), k, seed, &dir)
        }
        other => bail!("unknown app '{other}'"),
    }
}

/// Over-partition `g` into `k` BFS atoms and persist the store to `dir`.
fn save_atom_store<V, E>(
    g: &graphlab::graph::Graph<V, E>,
    k: usize,
    seed: u64,
    dir: &std::path::Path,
) -> Result<()>
where
    V: graphlab::wire::Wire,
    E: graphlab::wire::Wire,
{
    let t0 = std::time::Instant::now();
    let atom_set = AtomSet::grow_bfs(g, k, seed);
    atom_set.save_atoms(g, dir)?;
    let sizes = atom_set.sizes();
    println!(
        "wrote {} atom journals (+meta.bin) for {} vertices / {} edges to {} in {:.2}s",
        atom_set.num_atoms(),
        g.num_vertices(),
        g.num_edges(),
        dir.display(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "atom sizes: min {} / max {} vertices; load with `graphlab run <app> --atoms-dir {}`",
        sizes.iter().min().unwrap_or(&0),
        sizes.iter().max().unwrap_or(&0),
        dir.display()
    );
    Ok(())
}

fn partition_demo(cfg: &Config) -> Result<()> {
    use graphlab::partition::atoms;
    let n = cfg.num_or("n", 20_000usize)?;
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let g = pagerank::build(n, &edges, 0.15);
    let k = cfg.num_or("atoms", 128usize)?;
    println!("two-phase partitioning: {} vertices, {} edges, {k} atoms", n, g.num_edges());
    let a = atoms::AtomSet::grow_bfs(&g, k, 2);
    let meta = atoms::MetaGraph::build(&g, &a);
    for machines in [2usize, 4, 8, 16] {
        let assign = meta.partition(machines);
        let vassign: Vec<usize> = (0..n as u32).map(|v| assign[a.atom(v)]).collect();
        let p = Partition::from_assignment(vassign, machines);
        let rand = Partition::random(n, machines, 3);
        println!(
            "  {machines:>2} machines: two-phase cut={} ({:.1}% | imbalance {:.2}) vs random cut={} ({:.1}%)",
            p.edge_cut(&g), 100.0 * p.edge_cut(&g) as f64 / g.num_edges() as f64, p.imbalance(),
            rand.edge_cut(&g), 100.0 * rand.edge_cut(&g) as f64 / g.num_edges() as f64,
        );
    }
    Ok(())
}

fn calibrate(_cfg: &Config) -> Result<()> {
    use graphlab::sim::calibrate as cal;
    println!("measured per-update costs (native path, this machine):");
    for d in [5usize, 20, 50, 100] {
        println!("  als d={d:>3}: {:>10.2} µs", cal::als_update_cost(d, 198) * 1e6);
    }
    println!("  coem k=8 deg=100: {:.2} µs", cal::coem_update_cost(8, 100) * 1e6);
    println!("  lbp  l=5 deg=6:   {:.2} µs", cal::lbp_update_cost(5) * 1e6);
    Ok(())
}

/// `graphlab lab` — the experiment lab CLI (see `rust/src/lab/`):
///
/// * `lab [--config FILE.json | --preset NAME[,NAME]|all] [--quick]` —
///   expand the sweep matrix and execute every cell, appending one JSONL
///   row per run to the run database (`--db`, default
///   `artifacts/lab/runs.jsonl`), then print the report. `--inproc`
///   runs cells inside this process (no child spawn — sandboxed
///   environments); `--bin PATH` points the executor at a different
///   `graphlab` binary; `--verbose` echoes child output.
/// * `lab report [--db FILE] [--baseline FILE]` — per-cell medians plus
///   regression deltas against the committed baseline
///   (`artifacts/lab/baseline.jsonl`).
/// * `lab micro <name> [--n N] [--seed S]` — one micro-benchmark cell
///   (the executor's child-process entry point for micro cells).
fn lab_cmd(args: &Args, cfg: &Config) -> Result<()> {
    use graphlab::lab::{micro, report, RunDb};
    use graphlab::lab::store::{DEFAULT_BASELINE, DEFAULT_DB};
    match args.pos(1) {
        Some("report") => {
            let db = RunDb::at(cfg.str_or("db", DEFAULT_DB));
            let baseline = RunDb::at(cfg.str_or("baseline", DEFAULT_BASELINE));
            print!("{}", report::report(&db, Some(&baseline))?);
            Ok(())
        }
        Some("micro") => {
            let name = args
                .pos(2)
                .context("lab micro needs a name (wire-codec|atom-store|net-pingpong-*)")?;
            micro::run_micro(name, cfg.num_or("n", 4_000u64)?, cfg.num_or("seed", 1u64)?)
        }
        Some(other) => bail!("unknown lab subcommand '{other}' (report|micro, or flags)"),
        None => {
            let mut names: Vec<String> = Vec::new();
            if let Some(list) = cfg.get("preset") {
                if list == "true" {
                    bail!(
                        "--preset needs a name: {} or 'all'",
                        graphlab::lab::config::PRESETS.join("|")
                    );
                }
                for name in list.split(',') {
                    if name == "all" {
                        names.extend(
                            graphlab::lab::config::PRESET_ALL.iter().map(|s| s.to_string()),
                        );
                    } else {
                        names.push(name.to_string());
                    }
                }
            }
            run_lab(&names, cfg)
        }
    }
}

/// Execute lab sweeps: the named presets, a `--config FILE.json`, or
/// (with neither) the `quick` preset. Appends to the run database and
/// prints the report afterwards.
fn run_lab(presets: &[String], cfg: &Config) -> Result<()> {
    use graphlab::lab::store::{DEFAULT_BASELINE, DEFAULT_DB};
    use graphlab::lab::{report, run_sweep, ExecOpts, RunDb, SweepConfig, SweepSummary};
    let quick = cfg.bool_or("quick", false);
    let mut sweeps: Vec<SweepConfig> = Vec::new();
    if let Some(path) = cfg.get("config") {
        if path == "true" {
            bail!("--config needs a JSON sweep file (see configs/*.json)");
        }
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading sweep config {path}"))?;
        sweeps.push(
            SweepConfig::from_json_text(&text, quick)
                .with_context(|| format!("sweep config {path}"))?,
        );
    }
    for name in presets {
        sweeps.push(SweepConfig::preset(name, quick)?);
    }
    if sweeps.is_empty() {
        // No config, no presets: the quick smoke matrix.
        sweeps.push(SweepConfig::preset("quick", quick)?);
    }
    let db = RunDb::at(cfg.str_or("db", DEFAULT_DB));
    let opts = ExecOpts {
        db: db.clone(),
        bin: cfg.get("bin").filter(|v| *v != "true").map(std::path::PathBuf::from),
        inproc: cfg.bool_or("inproc", false),
        echo: cfg.bool_or("verbose", false),
    };
    let mut total = SweepSummary::default();
    for sweep in &sweeps {
        let s = run_sweep(sweep, &opts)?;
        total.cells += s.cells;
        total.runs += s.runs;
        total.ok += s.ok;
        total.timeouts += s.timeouts;
        total.errors += s.errors;
    }
    println!(
        "lab: {} cell(s), {} run(s): {} ok, {} timeout, {} error -> {}",
        total.cells,
        total.runs,
        total.ok,
        total.timeouts,
        total.errors,
        db.path.display()
    );
    let baseline = RunDb::at(cfg.str_or("baseline", DEFAULT_BASELINE));
    print!("{}", report::report(&db, Some(&baseline))?);
    Ok(())
}

/// The historical `bench-sched`/`bench-engines`/`bench-wire`/`bench-net`
/// subcommands, kept as thin forwards onto their lab preset sweeps.
/// Results now land in the run database instead of `BENCH_prN.json`
/// files; BENCHMARKS.md carries the migration table.
fn bench_forward(old: &str, preset: &str, cfg: &Config) -> Result<()> {
    println!(
        "note: `graphlab {old}` now forwards to `graphlab lab --preset {preset}` — \
         results append to the run database (see BENCHMARKS.md)"
    );
    run_lab(&[preset.to_string()], cfg)
}

/// `graphlab serve`: converge a PageRank graph, then stay resident
/// serving queries and mutations over TCP (DESIGN.md §Serving).
///
/// In-proc mode (default) runs all `--machines N` machines as threads
/// and binds the client listener at `--listen` (default
/// `127.0.0.1:7700`; `:0` picks a free port). With `--cluster HOSTS
/// --me N --atoms-dir DIR` this process is machine N of a multi-process
/// cluster — machine 0 (the frontend) binds the listener, the others
/// join the worker mesh; every process must load the same atom store so
/// ownership agrees.
fn serve_cmd(cfg: &Config, cluster: Option<ClusterConfig>) -> Result<()> {
    use graphlab::serve::client::spawn_listener;
    use graphlab::serve::engine::{serve_machine, ServeOpts, ServeSession, FRONTEND};

    let seed = cfg.num_or("seed", 1u64)?;
    let listen = cfg.str_or("listen", "127.0.0.1:7700");
    let machines = match &cluster {
        Some(c) => c.hosts.len(),
        None => cfg.num_or("machines", 2usize)?,
    };
    let mut opts = ServeOpts {
        machines,
        eps: cfg.num_or("eps", 1e-8f32)?,
        scheduler: cfg.str_or("scheduler", "fifo"),
        seed,
        pin_threads: cfg.bool_or("pin-threads", false),
        ..ServeOpts::default()
    };
    opts.transport = if cluster.is_some() {
        TransportKind::Tcp
    } else {
        cfg.str_or("transport", "inproc").parse().context("--transport")?
    };
    let atoms_dir = atoms_dir_flag(cfg);
    match cluster {
        Some(c) => {
            let Some(dir) = atoms_dir else {
                bail!(
                    "serve --cluster requires --atoms-dir: every process must derive \
                     the identical graph and placement from one stored atom set \
                     (run `graphlab partition pagerank` first)"
                );
            };
            let (g, store) = atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(&dir)?;
            let (part, placement) = store.place(machines);
            println!(
                "== graphlab serve (cluster machine {}/{}, {} vertices) ==",
                c.me,
                machines,
                g.num_vertices()
            );
            if c.me == FRONTEND {
                let (tx, rx) = std::sync::mpsc::channel();
                let (addr, _accept) = spawn_listener(&listen, tx)?;
                println!("serve: frontend accepting clients on {addr}");
                serve_machine(g, &part, Some(&placement), &opts, Some(&c), Some(rx))
            } else {
                serve_machine(g, &part, Some(&placement), &opts, Some(&c), None)
            }
        }
        None => {
            let (g, part) = match &atoms_dir {
                Some(dir) => {
                    let (g, store) =
                        atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(dir)?;
                    let (part, _) = store.place(machines);
                    (g, part)
                }
                None => {
                    let n = cfg.num_or("n", 20_000usize)?;
                    let edges =
                        graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8)?, seed);
                    let g = pagerank::build(n, &edges, 0.15);
                    let part = graphlab::partition::atoms::two_phase(
                        &g,
                        cfg.num_or("atoms", (machines * 8).max(16))?,
                        machines,
                        seed,
                    );
                    (g, part)
                }
            };
            println!(
                "== graphlab serve (machines={machines}, transport={}, {} vertices, {} edges) ==",
                opts.transport.name(),
                g.num_vertices(),
                g.num_edges()
            );
            let session = ServeSession::start(g, &part, &opts)?;
            let (addr, _accept) = spawn_listener(&listen, session.feed())?;
            println!(
                "serve: accepting clients on {addr} — try `graphlab client stats --addr {addr}`"
            );
            // Resident until a client sends Shutdown.
            session.wait()
        }
    }
}

/// `graphlab client <op> [...] --addr HOST:PORT`: one request against a
/// serving frontend. Ops: `query V`, `add-edge U V W`, `rm-edge U V`,
/// `set-weight U V W`, `touch V`, `stats`, `shutdown`.
fn client_cmd(args: &Args, cfg: &Config) -> Result<()> {
    use graphlab::serve::msg::{Mutation, ServeReply};
    use graphlab::serve::ServeClient;

    let addr = cfg.str_or("addr", "127.0.0.1:7700");
    let vertex_at = |i: usize, what: &str| -> Result<u32> {
        args.pos(i)
            .with_context(|| format!("client {}: missing {what}", args.pos(1).unwrap_or("?")))?
            .parse::<u32>()
            .with_context(|| format!("client: {what} must be a vertex id"))
    };
    let weight_at = |i: usize| -> Result<f32> {
        args.pos(i)
            .context("client: missing edge weight W")?
            .parse::<f32>()
            .context("client: W must be a number")
    };
    let mut client = ServeClient::connect(&addr)?;
    let reply = match args.pos(1) {
        Some("query") => client.query(vertex_at(2, "vertex id V")?)?,
        Some("add-edge") => client.mutate(vec![Mutation::AddEdge {
            u: vertex_at(2, "vertex id U")?,
            v: vertex_at(3, "vertex id V")?,
            w: weight_at(4)?,
        }])?,
        Some("rm-edge") => client.mutate(vec![Mutation::RemoveEdge {
            u: vertex_at(2, "vertex id U")?,
            v: vertex_at(3, "vertex id V")?,
        }])?,
        Some("set-weight") => client.mutate(vec![Mutation::SetEdgeWeight {
            u: vertex_at(2, "vertex id U")?,
            v: vertex_at(3, "vertex id V")?,
            w: weight_at(4)?,
        }])?,
        Some("touch") => client.mutate(vec![Mutation::TouchVertex {
            v: vertex_at(2, "vertex id V")?,
        }])?,
        Some("stats") => client.request(&graphlab::serve::ServeReq::Stats)?,
        Some("shutdown") => client.shutdown()?,
        other => bail!(
            "client: unknown op {:?} (query|add-edge|rm-edge|set-weight|touch|stats|shutdown)",
            other.unwrap_or("")
        ),
    };
    match reply {
        ServeReply::Value { vertex, rank, epoch, converged } => println!(
            "vertex {vertex}: rank {rank:.9} (epoch {epoch}, {})",
            if converged { "converged" } else { "still converging" }
        ),
        ServeReply::MutAck { epoch, scheduled, updates, steps } => println!(
            "epoch {epoch}: applied (scheduled {scheduled} endpoint(s), \
             {updates} incremental update(s) over {steps} superstep(s))"
        ),
        ServeReply::Stats(s) => println!(
            "epoch {} ({}): {} vertices, ~{} edges, {} machine(s); updates: \
             initial {}, last epoch {}, total {}",
            s.epoch,
            if s.converged { "converged" } else { "converging" },
            s.vertices,
            s.edges,
            s.machines,
            s.initial_updates,
            s.epoch_updates,
            s.total_updates
        ),
        ServeReply::Bye => println!("cluster shutting down"),
        ServeReply::Error { kind, detail } => bail!("server refused ({kind:?}): {detail}"),
    }
    Ok(())
}

/// `graphlab bench-serve`: the serving-mode benchmark (in-proc cluster,
/// streaming mutation batches, timed queries). This is the child entry
/// point the lab's `serve` preset spawns; the printed `lab-metric` line
/// carries `mutations_per_sec` and query latency percentiles.
fn bench_serve(cfg: &Config) -> Result<()> {
    let o = graphlab::serve::bench::BenchOpts {
        n: cfg.num_or("n", 20_000usize)?,
        avg_degree: cfg.num_or("avg-degree", 8usize)?,
        machines: cfg.num_or("machines", 2usize)?,
        transport: cfg.str_or("transport", "inproc").parse().context("--transport")?,
        mutrate: cfg.num_or("mutrate", 64usize)?,
        batches: cfg.num_or("batches", 8usize)?,
        queries: cfg.num_or("queries", 200usize)?,
        eps: cfg.num_or("eps", 1e-7f32)?,
        seed: cfg.num_or("seed", 1u64)?,
    };
    println!(
        "== graphlab bench-serve (machines={}, transport={}, n={}, mutrate={}, batches={}) ==",
        o.machines,
        o.transport.name(),
        o.n,
        o.mutrate,
        o.batches
    );
    println!("{}", graphlab::serve::bench::run_bench(&o)?);
    Ok(())
}
