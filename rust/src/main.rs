//! `graphlab` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!
//! * `run <app>` — run one application end-to-end on synthetic data:
//!   `pagerank | als | ner | coseg | gibbs`. Every app accepts
//!   `--engine shared|chromatic|locking` (the unified `engine::Engine`
//!   builder dispatches at runtime), plus `--machines N`, `--threads N`,
//!   `--scheduler POLICY`, `--pjrt`, app-specific size flags, and
//!   `--config FILE` overlays. `POLICY` is `fifo|priority|multiqueue|sweep`
//!   (work-stealing per-worker queues on the shared engine; per-machine
//!   queues on the locking engine) or `global-<policy>` (single shared
//!   queue — the contended baseline, shared engine only).
//! * `figure <name>` — regenerate a paper table/figure (`table2`, `fig1`,
//!   `fig5a`, `fig6a`..`fig8d`, or `all`) into `--out-dir` (default
//!   `results/`).
//! * `partition` — two-phase partitioning demo: atoms → meta-graph →
//!   machine assignment quality report.
//! * `calibrate` — print the measured per-update costs feeding the
//!   cluster model.
//! * `bench-sched` — shared-engine PageRank updates/sec at 1/2/4/8
//!   threads, work-stealing vs single-queue, written as JSON (the
//!   `BENCH_pr2.json` perf-trajectory artifact).
//! * `bench-engines` — the same PageRank workload through all three
//!   engines (shared vs chromatic vs locking), written as JSON
//!   (`BENCH_pr3.json`; also run by CI's bench-smoke job).
//!
//! Examples:
//!
//! ```text
//! graphlab run als --machines 4 --d 20 --sweeps 20 --pjrt
//! graphlab run pagerank --engine shared --threads 8 --scheduler multiqueue
//! graphlab run gibbs --engine locking --machines 4
//! graphlab figure fig6d --out-dir results/
//! graphlab bench-engines --out BENCH_pr3.json
//! ```

use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use graphlab::apps::{self, als, coseg, gibbs, ner, pagerank};
use graphlab::engine::{Engine, EngineKind, ENGINE_KINDS};
use graphlab::partition::Partition;
use graphlab::scheduler::{Policy, SchedSpec};
use graphlab::util::cli::Args;
use graphlab::util::config::Config;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::new();
    if let Some(path) = args.get("config") {
        cfg = Config::load(path)?;
    }
    cfg.overlay(args.flags());
    match args.pos(0) {
        Some("run") => run_app(&args, &cfg),
        Some("figure") => {
            let name = args.pos(1).unwrap_or("all").to_string();
            let out = cfg.str_or("out-dir", "results");
            graphlab::sim::figures::run_figure(&name, std::path::Path::new(&out))
        }
        Some("partition") => partition_demo(&cfg),
        Some("calibrate") => calibrate(&cfg),
        Some("bench-sched") => bench_sched(&cfg),
        Some("bench-engines") => bench_engines(&cfg),
        _ => {
            eprintln!(
                "usage: graphlab <run|figure|partition|calibrate|bench-sched|bench-engines> [...]\n"
            );
            eprintln!("  graphlab run <pagerank|als|ner|coseg|gibbs> [--engine shared|chromatic|locking]");
            eprintln!("      [--machines N] [--threads N] [--scheduler fifo|priority|multiqueue|sweep|global-*]");
            eprintln!("      [--pjrt] [--sweeps N] [--d N] [--config FILE]");
            eprintln!("  graphlab figure <table2|fig1|fig5a|fig6a|fig6c|fig6d|fig7a|fig8a|fig8b|fig8c|fig8d|all>");
            eprintln!("      [--out-dir DIR]");
            eprintln!("  graphlab bench-sched [--out FILE] [--n N] [--sweeps N] [--quick]");
            eprintln!("  graphlab bench-engines [--out FILE] [--n N] [--sweeps N] [--machines N] [--quick]");
            bail!("missing subcommand");
        }
    }
}

fn run_app(args: &Args, cfg: &Config) -> Result<()> {
    let app = args.pos(1).unwrap_or("pagerank");
    let engine: EngineKind = cfg
        .str_or("engine", "chromatic")
        .parse()
        .context("--engine")?;
    let machines = cfg.num_or("machines", 2usize)?;
    let threads = cfg.num_or("threads", 2usize)?;
    let sweeps = cfg.num_or("sweeps", 20u64)?;
    let use_pjrt = cfg.bool_or("pjrt", false);
    if use_pjrt && !graphlab::runtime::available() {
        bail!(
            "--pjrt requested but the PJRT runtime is unavailable \
             (build with `--features pjrt` and run `make artifacts`)"
        );
    }
    let seed = cfg.num_or("seed", 1u64)?;
    println!("== graphlab run {app} (engine={engine}, machines={machines}) ==");

    match app {
        "pagerank" => {
            let n = cfg.num_or("n", 10_000usize)?;
            let edges = graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8)?, seed);
            let g = pagerank::build(n, &edges, 0.15);
            let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-6, n, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg,
                vec![Box::new(pagerank::total_rank_sync())], "total_rank")
        }
        "als" => {
            let d = cfg.num_or("d", 20usize)?;
            let data = graphlab::datagen::netflix(
                cfg.num_or("users", 2000)?, cfg.num_or("movies", 1000)?,
                cfg.num_or("ratings-per-user", 30)?, 8, 0.2, seed);
            let g = als::build(&data, d, seed);
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            let prog = als::Als { d, lambda: 0.08, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg,
                vec![Box::new(als::rmse_sync())], "rmse")
        }
        "ner" => {
            let data = graphlab::datagen::ner(
                cfg.num_or("nps", 5000)?, cfg.num_or("contexts", 2500)?,
                cfg.num_or("edges-per-np", 30)?, 8, 0.1, seed);
            let g = ner::build(&data);
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            let prog = ner::Coem { k: 8, smoothing: 0.01, eps: 1e-4, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg,
                vec![Box::new(ner::accuracy_sync())], "accuracy")
        }
        "coseg" => {
            let data = graphlab::datagen::video(
                cfg.num_or("frames", 16)?, cfg.num_or("width", 24)?,
                cfg.num_or("height", 20)?, 5, 0.4, seed);
            let g = coseg::build(&data, 0.8);
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            let prog = coseg::Coseg { labels: 5, eps: 1e-3, sigma2: 0.5, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg,
                vec![Box::new(coseg::gmm_sync(5)), Box::new(coseg::accuracy_sync())], "accuracy")
        }
        "gibbs" => {
            let data = graphlab::datagen::mrf(cfg.num_or("side", 64)?, 0.4, seed);
            let g = gibbs::build(&data);
            let prog = gibbs::Gibbs { coupling: 0.4, target_samples: sweeps.max(10), seed };
            run_generic(g, prog, engine, machines, threads, u64::MAX, cfg,
                vec![Box::new(gibbs::magnetization_sync())], "magnetization")
        }
        other => bail!("unknown app '{other}'"),
    }
}

/// Run a (graph, program) pair on the engine selected by `--engine`: one
/// builder call covers all three engines.
#[allow(clippy::too_many_arguments)]
fn run_generic<V, E, P>(
    g: graphlab::graph::Graph<V, E>,
    prog: P,
    engine: EngineKind,
    machines: usize,
    threads: usize,
    sweeps: u64,
    cfg: &Config,
    syncs: Vec<Box<dyn graphlab::engine::SyncOp<V>>>,
    probe_key: &'static str,
) -> Result<()>
where
    V: graphlab::distributed::DataValue,
    E: graphlab::distributed::DataValue,
    P: graphlab::engine::VertexProgram<V, E>,
{
    let n = g.num_vertices();
    let initial = apps::all_vertices(n);
    let seed = cfg.num_or("seed", 1u64)?;
    let sched_default = if engine == EngineKind::Locking { "priority" } else { "fifo" };
    let spec = SchedSpec::parse(&cfg.str_or("scheduler", sched_default), seed)
        .context("--scheduler")?;
    // Update cap: a safety net for non-converging runs (the chromatic
    // engine is capped in whole sweeps via max_sweeps instead).
    let max_updates = cfg.num_or("max-updates", n as u64 * sweeps.min(10_000))?;
    let exec = Engine::new(engine)
        .workers(threads)
        .machines(machines)
        .scheduler(spec)
        .seed(seed)
        .max_updates(max_updates)
        .max_sweeps(sweeps)
        .maxpending(cfg.num_or("maxpending", 64usize)?)
        .sync_period(Duration::from_millis(cfg.num_or("sync-ms", 100u64)?))
        .syncs(syncs)
        .on_progress(move |epoch, updates, gv| {
            if let Some(v) = gv.get(probe_key) {
                println!("epoch {epoch:>3}: updates={updates:>9} {probe_key}={:.5}", v[0]);
            }
        })
        .run(g, &prog, initial)?;
    let stats = &exec.stats;
    println!(
        "done: {} updates, {} epochs, {:.2}s on {engine} \
         ({} machine(s), balance {:.2}, {} MB sent)",
        stats.updates,
        stats.sweeps,
        stats.seconds,
        stats.machines(),
        stats.balance(),
        stats.total_bytes() / 1_000_000
    );
    Ok(())
}

fn partition_demo(cfg: &Config) -> Result<()> {
    use graphlab::partition::atoms;
    let n = cfg.num_or("n", 20_000usize)?;
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let g = pagerank::build(n, &edges, 0.15);
    let k = cfg.num_or("atoms", 128usize)?;
    println!("two-phase partitioning: {} vertices, {} edges, {k} atoms", n, g.num_edges());
    let a = atoms::AtomSet::grow_bfs(&g, k, 2);
    let meta = atoms::MetaGraph::build(&g, &a);
    for machines in [2usize, 4, 8, 16] {
        let assign = meta.partition(machines);
        let vassign: Vec<usize> = (0..n as u32).map(|v| assign[a.atom(v)]).collect();
        let p = Partition::from_assignment(vassign, machines);
        let rand = Partition::random(n, machines, 3);
        println!(
            "  {machines:>2} machines: two-phase cut={} ({:.1}% | imbalance {:.2}) vs random cut={} ({:.1}%)",
            p.edge_cut(&g), 100.0 * p.edge_cut(&g) as f64 / g.num_edges() as f64, p.imbalance(),
            rand.edge_cut(&g), 100.0 * rand.edge_cut(&g) as f64 / g.num_edges() as f64,
        );
    }
    Ok(())
}

fn calibrate(_cfg: &Config) -> Result<()> {
    use graphlab::sim::calibrate as cal;
    println!("measured per-update costs (native path, this machine):");
    for d in [5usize, 20, 50, 100] {
        println!("  als d={d:>3}: {:>10.2} µs", cal::als_update_cost(d, 198) * 1e6);
    }
    println!("  coem k=8 deg=100: {:.2} µs", cal::coem_update_cost(8, 100) * 1e6);
    println!("  lbp  l=5 deg=6:   {:.2} µs", cal::lbp_update_cost(5) * 1e6);
    Ok(())
}

/// Shared-engine PageRank scheduler sweep: updates/sec at 1/2/4/8 threads,
/// single global queue (`global-fifo`) vs work stealing (`fifo` and
/// `multiqueue`), written as JSON for the perf trajectory
/// (`BENCH_pr2.json`). `--quick` shrinks the graph/workload for CI smoke.
fn bench_sched(cfg: &Config) -> Result<()> {
    let quick = cfg.bool_or("quick", false);
    let n = cfg.num_or("n", if quick { 5_000 } else { 20_000usize })?;
    let sweeps = cfg.num_or("sweeps", if quick { 4 } else { 12u64 })?;
    let out_path = cfg.str_or("out", "BENCH_pr2.json");
    let thread_counts = [1usize, 2, 4, 8];
    let specs = [
        SchedSpec::global(Policy::Fifo, 1),
        SchedSpec::ws(Policy::Fifo, 1),
        SchedSpec::ws(Policy::MultiQueue, 1),
    ];

    let edges = graphlab::datagen::web_graph(n, 8, 1);
    println!("== bench-sched: shared-engine PageRank, n={n}, {} edges, {sweeps} sweeps ==", edges.len());

    // eps = 0 keeps every update rescheduling its neighbors, so the run is
    // scheduler-bound until the max_updates cap — exactly the contention
    // path the scheduler work changes.
    let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
    struct Row {
        scheduler: String,
        threads: usize,
        updates: u64,
        seconds: f64,
        ups: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for spec in specs {
        for &threads in &thread_counts {
            let g = pagerank::build(n, &edges, 0.15);
            let exec = Engine::new(EngineKind::Shared)
                .workers(threads)
                .scheduler(spec)
                .max_updates(n as u64 * sweeps)
                .run(g, &prog, apps::all_vertices(n))?;
            let stats = exec.stats;
            let ups = stats.updates_per_sec();
            println!(
                "  {:<16} threads={threads}: {:>9} updates in {:.3}s = {:>12.0} updates/s",
                spec.name(), stats.updates, stats.seconds, ups
            );
            rows.push(Row {
                scheduler: spec.name(),
                threads,
                updates: stats.updates,
                seconds: stats.seconds,
                ups,
            });
        }
    }

    let ups_at = |sched: &str, threads: usize| -> f64 {
        rows.iter()
            .find(|r| r.scheduler == sched && r.threads == threads)
            .map(|r| r.ups)
            .unwrap_or(0.0)
    };
    let improved = ups_at("fifo", 4) > ups_at("global-fifo", 4);
    println!(
        "work-stealing vs single-queue at 4 threads: {}",
        if improved { "IMPROVED" } else { "NOT improved" }
    );

    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"scheduler\": \"{}\", \"threads\": {}, \"updates\": {}, \"seconds\": {:.6}, \"updates_per_sec\": {:.1}}}",
                r.scheduler, r.threads, r.updates, r.seconds, r.ups
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"shared-engine PageRank scheduler sweep (PR 2)\",\n  \
         \"command\": \"graphlab bench-sched\",\n  \"n\": {n},\n  \"avg_degree\": 8,\n  \
         \"sweeps\": {sweeps},\n  \"quick\": {quick},\n  \
         \"ws_beats_global_at_4_threads\": {improved},\n  \"results\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write(&out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Cross-engine PageRank comparison through the unified `Engine` builder:
/// the same workload on shared vs chromatic vs locking, updates/sec per
/// engine, written as JSON (`BENCH_pr3.json`, reusing the `bench-sched`
/// schema). `--quick` shrinks the workload for CI smoke.
fn bench_engines(cfg: &Config) -> Result<()> {
    let quick = cfg.bool_or("quick", false);
    let n = cfg.num_or("n", if quick { 3_000 } else { 10_000usize })?;
    let sweeps = cfg.num_or("sweeps", if quick { 3 } else { 10u64 })?;
    let machines = cfg.num_or("machines", 4usize)?;
    let threads = cfg.num_or("threads", 4usize)?;
    let out_path = cfg.str_or("out", "BENCH_pr3.json");

    let edges = graphlab::datagen::web_graph(n, 8, 1);
    println!(
        "== bench-engines: PageRank, n={n}, {} edges, {sweeps} sweeps, all engines ==",
        edges.len()
    );
    // eps = 0: every update reschedules its neighbors, so every engine
    // executes a full `sweeps`-worth of updates before hitting its cap —
    // the same amount of numeric work on every engine.
    let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
    struct Row {
        engine: &'static str,
        parallelism: usize,
        updates: u64,
        seconds: f64,
        ups: f64,
        mbytes: u64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for kind in ENGINE_KINDS {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(kind)
            .workers(if kind == EngineKind::Shared { threads } else { 1 })
            .machines(machines)
            .seed(1)
            .max_updates(n as u64 * sweeps)
            .max_sweeps(sweeps)
            .maxpending(256)
            .run(g, &prog, apps::all_vertices(n))?;
        let stats = exec.stats;
        let parallelism = if kind == EngineKind::Shared { threads } else { machines };
        let ups = stats.updates_per_sec();
        println!(
            "  {:<10} x{parallelism}: {:>9} updates in {:.3}s = {:>12.0} updates/s, \
             balance {:.2}, {} MB sent",
            kind.name(),
            stats.updates,
            stats.seconds,
            ups,
            stats.balance(),
            stats.total_bytes() / 1_000_000
        );
        rows.push(Row {
            engine: kind.name(),
            parallelism,
            updates: stats.updates,
            seconds: stats.seconds,
            ups,
            mbytes: stats.total_bytes() / 1_000_000,
        });
    }

    let fastest = rows
        .iter()
        .max_by(|a, b| a.ups.partial_cmp(&b.ups).unwrap())
        .map(|r| r.engine)
        .unwrap_or("none");
    println!("fastest engine on this workload: {fastest}");

    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"engine\": \"{}\", \"threads\": {}, \"updates\": {}, \"seconds\": {:.6}, \"updates_per_sec\": {:.1}, \"mb_sent\": {}}}",
                r.engine, r.parallelism, r.updates, r.seconds, r.ups, r.mbytes
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"cross-engine PageRank comparison (PR 3, unified Engine API)\",\n  \
         \"command\": \"graphlab bench-engines\",\n  \"n\": {n},\n  \"avg_degree\": 8,\n  \
         \"sweeps\": {sweeps},\n  \"machines\": {machines},\n  \"quick\": {quick},\n  \
         \"fastest_engine\": \"{fastest}\",\n  \"results\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write(&out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}
