//! `graphlab` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!
//! * `run <app>` — run one application end-to-end on synthetic data:
//!   `pagerank | als | ner | coseg | gibbs`, with
//!   `--engine shared|chromatic|locking`, `--machines N`, `--threads N`,
//!   `--pjrt`, app-specific size flags, and `--config FILE` overlays.
//! * `figure <name>` — regenerate a paper table/figure (`table2`, `fig1`,
//!   `fig5a`, `fig6a`..`fig8d`, or `all`) into `--out-dir` (default
//!   `results/`).
//! * `partition` — two-phase partitioning demo: atoms → meta-graph →
//!   machine assignment quality report.
//! * `calibrate` — print the measured per-update costs feeding the
//!   cluster model.
//!
//! Examples:
//!
//! ```text
//! graphlab run als --machines 4 --d 20 --sweeps 20 --pjrt
//! graphlab figure fig6d --out-dir results/
//! graphlab run coseg --engine locking --machines 4 --maxpending 100
//! ```

use std::time::Duration;

use anyhow::{bail, Result};

use graphlab::apps::{self, als, coseg, gibbs, ner, pagerank};
use graphlab::engine::chromatic::{self, ChromaticOpts};
use graphlab::engine::locking::{self, LockingOpts};
use graphlab::engine::shared::{self, SharedOpts};
use graphlab::partition::Partition;
use graphlab::scheduler;
use graphlab::util::cli::Args;
use graphlab::util::config::Config;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::new();
    if let Some(path) = args.get("config") {
        cfg = Config::load(path)?;
    }
    cfg.overlay(args.flags());
    match args.pos(0) {
        Some("run") => run_app(&args, &cfg),
        Some("figure") => {
            let name = args.pos(1).unwrap_or("all").to_string();
            let out = cfg.str_or("out-dir", "results");
            graphlab::sim::figures::run_figure(&name, std::path::Path::new(&out))
        }
        Some("partition") => partition_demo(&cfg),
        Some("calibrate") => calibrate(&cfg),
        _ => {
            eprintln!("usage: graphlab <run|figure|partition|calibrate> [...]\n");
            eprintln!("  graphlab run <pagerank|als|ner|coseg|gibbs> [--engine chromatic|locking|shared]");
            eprintln!("      [--machines N] [--threads N] [--pjrt] [--sweeps N] [--d N] [--config FILE]");
            eprintln!("  graphlab figure <table2|fig1|fig5a|fig6a|fig6c|fig6d|fig7a|fig8a|fig8b|fig8c|fig8d|all>");
            eprintln!("      [--out-dir DIR]");
            bail!("missing subcommand");
        }
    }
}

fn run_app(args: &Args, cfg: &Config) -> Result<()> {
    let app = args.pos(1).unwrap_or("pagerank");
    let engine = cfg.str_or("engine", "chromatic");
    let machines = cfg.num_or("machines", 2usize);
    let threads = cfg.num_or("threads", 2usize);
    let sweeps = cfg.num_or("sweeps", 20u64);
    let use_pjrt = cfg.bool_or("pjrt", false);
    if use_pjrt && !graphlab::runtime::available() {
        bail!(
            "--pjrt requested but the PJRT runtime is unavailable \
             (build with `--features pjrt` and run `make artifacts`)"
        );
    }
    let seed = cfg.num_or("seed", 1u64);
    println!("== graphlab run {app} (engine={engine}, machines={machines}) ==");

    match app {
        "pagerank" => {
            let n = cfg.num_or("n", 10_000usize);
            let edges = graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8), seed);
            let g = pagerank::build(n, &edges, 0.15);
            let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-6, n, use_pjrt };
            run_generic(g, prog, engine.as_str(), machines, threads, sweeps, cfg,
                vec![Box::new(pagerank::total_rank_sync())], "total_rank")
        }
        "als" => {
            let d = cfg.num_or("d", 20usize);
            let data = graphlab::datagen::netflix(
                cfg.num_or("users", 2000), cfg.num_or("movies", 1000),
                cfg.num_or("ratings-per-user", 30), 8, 0.2, seed);
            let g = als::build(&data, d, seed);
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            let prog = als::Als { d, lambda: 0.08, use_pjrt };
            run_generic(g, prog, engine.as_str(), machines, threads, sweeps, cfg,
                vec![Box::new(als::rmse_sync())], "rmse")
        }
        "ner" => {
            let data = graphlab::datagen::ner(
                cfg.num_or("nps", 5000), cfg.num_or("contexts", 2500),
                cfg.num_or("edges-per-np", 30), 8, 0.1, seed);
            let g = ner::build(&data);
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            let prog = ner::Coem { k: 8, smoothing: 0.01, eps: 1e-4, use_pjrt };
            run_generic(g, prog, engine.as_str(), machines, threads, sweeps, cfg,
                vec![Box::new(ner::accuracy_sync())], "accuracy")
        }
        "coseg" => {
            let data = graphlab::datagen::video(
                cfg.num_or("frames", 16), cfg.num_or("width", 24),
                cfg.num_or("height", 20), 5, 0.4, seed);
            let g = coseg::build(&data, 0.8);
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            let prog = coseg::Coseg { labels: 5, eps: 1e-3, sigma2: 0.5, use_pjrt };
            run_generic(g, prog, engine.as_str(), machines, threads, sweeps, cfg,
                vec![Box::new(coseg::gmm_sync(5)), Box::new(coseg::accuracy_sync())], "accuracy")
        }
        "gibbs" => {
            let data = graphlab::datagen::mrf(cfg.num_or("side", 64), 0.4, seed);
            let g = gibbs::build(&data);
            let _n = g.num_vertices();
            let prog = gibbs::Gibbs { coupling: 0.4, target_samples: sweeps.max(10), seed };
            run_generic(g, prog, engine.as_str(), machines, threads, u64::MAX, cfg,
                vec![Box::new(gibbs::magnetization_sync())], "magnetization")
        }
        other => bail!("unknown app '{other}'"),
    }
}

/// Dispatch a (graph, program) pair to the selected engine.
#[allow(clippy::too_many_arguments)]
fn run_generic<V, E, P>(
    g: graphlab::graph::Graph<V, E>,
    prog: P,
    engine: &str,
    machines: usize,
    threads: usize,
    sweeps: u64,
    cfg: &Config,
    syncs: Vec<Box<dyn graphlab::engine::SyncOp<V>>>,
    probe_key: &'static str,
) -> Result<()>
where
    V: graphlab::distributed::DataValue,
    E: graphlab::distributed::DataValue,
    P: graphlab::engine::VertexProgram<V, E>,
{
    let n = g.num_vertices();
    let initial = apps::all_vertices(n);
    match engine {
        "chromatic" => {
            let coloring = chromatic::color_for(&g, prog.consistency());
            println!("coloring: {} colors", coloring.num_colors());
            let partition = Partition::random(n, machines, 7);
            let (_g, stats) = chromatic::run(
                g, &coloring, &partition, &prog, initial, syncs,
                ChromaticOpts {
                    machines,
                    threads_per_machine: threads,
                    max_sweeps: sweeps,
                    on_sweep: Some(Box::new(move |s, u, gv| {
                        if let Some(v) = gv.get(probe_key) {
                            println!("sweep {s:>3}: updates={u:>9} {probe_key}={:.5}", v[0]);
                        }
                    })),
                    ..Default::default()
                },
            );
            println!("done: {} updates, {} sweeps, {:.2}s, {} MB sent",
                stats.updates, stats.sweeps, stats.seconds,
                stats.bytes_sent.iter().sum::<u64>() / 1_000_000);
        }
        "locking" => {
            let partition = Partition::blocked(n, machines);
            let cap = cfg.num_or("max-updates", n as u64 * sweeps.min(1000)) / machines as u64;
            let (_g, stats) = locking::run(
                g, &partition, &prog, initial, syncs,
                LockingOpts {
                    machines,
                    maxpending: cfg.num_or("maxpending", 64usize),
                    scheduler: cfg.str_or("scheduler", "priority"),
                    sync_period: Some(Duration::from_millis(cfg.num_or("sync-ms", 100u64))),
                    max_updates_per_machine: cap,
                    on_sync: Some(Box::new(move |e, u, gv| {
                        if let Some(v) = gv.get(probe_key) {
                            println!("epoch {e:>3}: updates={u:>9} {probe_key}={:.5}", v[0]);
                        }
                    })),
                    ..Default::default()
                },
            );
            println!("done: {} updates, {} epochs, {:.2}s, {} MB sent",
                stats.updates, stats.sweeps, stats.seconds,
                stats.bytes_sent.iter().sum::<u64>() / 1_000_000);
        }
        "shared" => {
            let sched = scheduler::by_name(&cfg.str_or("scheduler", "fifo"), n, 1);
            let (_g, stats) = shared::run(
                g, &prog, initial, syncs, sched,
                SharedOpts {
                    workers: threads.max(machines),
                    max_updates: n as u64 * sweeps.min(10_000),
                    on_sync: Some(Box::new(move |u, gv| {
                        if let Some(v) = gv.get(probe_key) {
                            println!("updates={u:>9} {probe_key}={:.5}", v[0]);
                        }
                    })),
                },
            );
            println!("done: {} updates, {:.2}s", stats.updates, stats.seconds);
        }
        other => bail!("unknown engine '{other}'"),
    }
    Ok(())
}

fn partition_demo(cfg: &Config) -> Result<()> {
    use graphlab::partition::atoms;
    let n = cfg.num_or("n", 20_000usize);
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let g = pagerank::build(n, &edges, 0.15);
    let k = cfg.num_or("atoms", 128usize);
    println!("two-phase partitioning: {} vertices, {} edges, {k} atoms", n, g.num_edges());
    let a = atoms::AtomSet::grow_bfs(&g, k, 2);
    let meta = atoms::MetaGraph::build(&g, &a);
    for machines in [2usize, 4, 8, 16] {
        let assign = meta.partition(machines);
        let vassign: Vec<usize> = (0..n as u32).map(|v| assign[a.atom(v)]).collect();
        let p = Partition::from_assignment(vassign, machines);
        let rand = Partition::random(n, machines, 3);
        println!(
            "  {machines:>2} machines: two-phase cut={} ({:.1}% | imbalance {:.2}) vs random cut={} ({:.1}%)",
            p.edge_cut(&g), 100.0 * p.edge_cut(&g) as f64 / g.num_edges() as f64, p.imbalance(),
            rand.edge_cut(&g), 100.0 * rand.edge_cut(&g) as f64 / g.num_edges() as f64,
        );
    }
    Ok(())
}

fn calibrate(_cfg: &Config) -> Result<()> {
    use graphlab::sim::calibrate as cal;
    println!("measured per-update costs (native path, this machine):");
    for d in [5usize, 20, 50, 100] {
        println!("  als d={d:>3}: {:>10.2} µs", cal::als_update_cost(d, 198) * 1e6);
    }
    println!("  coem k=8 deg=100: {:.2} µs", cal::coem_update_cost(8, 100) * 1e6);
    println!("  lbp  l=5 deg=6:   {:.2} µs", cal::lbp_update_cost(5) * 1e6);
    Ok(())
}
