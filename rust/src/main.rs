//! `graphlab` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!
//! * `run <app>` — run one application end-to-end on synthetic data:
//!   `pagerank | als | ner | coseg | gibbs`. Every app accepts
//!   `--engine shared|chromatic|locking` (the unified `engine::Engine`
//!   builder dispatches at runtime), plus `--machines N`, `--threads N`,
//!   `--scheduler POLICY`, `--transport inproc|tcp` (real loopback
//!   sockets under the distributed engines), `--pjrt`, app-specific size
//!   flags, and `--config FILE` overlays. `POLICY` is
//!   `fifo|priority|multiqueue|sweep` (work-stealing per-worker queues on
//!   the shared engine; per-machine queues on the locking engine) or
//!   `global-<policy>` (single shared queue — the contended baseline,
//!   shared engine only). With `--cluster HOSTS` the run becomes machine
//!   0 of a real multi-process cluster (one `host:port` line per machine
//!   in HOSTS); requires `--atoms-dir` so every process derives the same
//!   placement from the stored meta-graph. `--snapshot-every K|Ns` cuts a
//!   Chandy–Lamport snapshot every K updates (or every N seconds) into
//!   `--snapshot-dir` (default: the atom-store dir); `--restore DIR`
//!   resumes from the newest complete snapshot under DIR (paper Sec. 4.3).
//! * `worker [<app>] --me N --hosts HOSTS --atoms-dir DIR` — join a
//!   multi-process cluster as machine N: build machine N's engine state
//!   by replaying its own atom journals and speak the engine protocol
//!   over TCP. (The process also replays the full store once for the
//!   global topology — coloring and result reassembly; making workers
//!   fully journal-local is a ROADMAP item.) The app is inferred from
//!   the atom store's stored type tags when omitted.
//! * `figure <name>` — regenerate a paper table/figure (`table2`, `fig1`,
//!   `fig5a`, `fig6a`..`fig8d`, or `all`) into `--out-dir` (default
//!   `results/`).
//! * `partition [<app>]` — with an app name, build that app's data graph
//!   and write it to disk as the paper's atom store (`--atoms-dir DIR`,
//!   default `atoms/`; `--atoms K` controls the over-partition size);
//!   `graphlab run <app> --atoms-dir DIR` then loads the same store on
//!   any machine count, each machine replaying only its own atom
//!   journals. Without an app: the two-phase partitioning quality demo.
//! * `calibrate` — print the measured per-update costs feeding the
//!   cluster model.
//! * `bench-sched` — shared-engine PageRank updates/sec at 1/2/4/8
//!   threads, work-stealing vs single-queue, written as JSON (the
//!   `BENCH_pr2.json` perf-trajectory artifact).
//! * `bench-engines` — the same PageRank workload through all three
//!   engines (shared vs chromatic vs locking), written as JSON
//!   (`BENCH_pr3.json`; also run by CI's bench-smoke job).
//! * `bench-wire` — wire-codec encode/decode throughput plus atom-store
//!   save/load timings, written as JSON (`BENCH_pr4.json`; also run by
//!   CI's bench-smoke job).
//! * `bench-net` — transport comparison: in-proc vs loopback-TCP frame
//!   round-trip latency/throughput plus a 2-machine PageRank on each
//!   backend, written as JSON (`BENCH_pr5.json`; also run by CI's
//!   bench-smoke job).
//!
//! Examples:
//!
//! ```text
//! graphlab run als --machines 4 --d 20 --sweeps 20 --pjrt
//! graphlab run pagerank --engine shared --threads 8 --scheduler multiqueue
//! graphlab run gibbs --engine locking --machines 4
//! graphlab run pagerank --machines 2 --transport tcp
//! graphlab partition pagerank --atoms-dir atoms/ --atoms 64
//! graphlab run pagerank --engine locking --atoms-dir atoms/
//! graphlab worker --me 1 --hosts hosts.txt --atoms-dir atoms/   # then, elsewhere:
//! graphlab run pagerank --cluster hosts.txt --atoms-dir atoms/
//! graphlab figure fig6d --out-dir results/
//! graphlab bench-engines --out BENCH_pr3.json
//! ```

use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use graphlab::apps::{self, als, coseg, gibbs, ner, pagerank};
use graphlab::distributed::{ClusterConfig, SnapshotTrigger, TransportKind};
use graphlab::engine::{Engine, EngineKind, ENGINE_KINDS};
use graphlab::partition::atoms::{self, AtomSet};
use graphlab::partition::Partition;
use graphlab::scheduler::{Policy, SchedSpec};
use graphlab::util::cli::Args;
use graphlab::util::config::Config;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = Config::new();
    if let Some(path) = args.get("config") {
        cfg = Config::load(path)?;
    }
    cfg.overlay(args.flags());
    match args.pos(0) {
        Some("run") => {
            let app = args.pos(1).unwrap_or("pagerank").to_string();
            // --cluster HOSTS: this process is machine `--me` (default 0,
            // the driver) of a real multi-process TCP cluster.
            let cluster = match cfg.get("cluster") {
                Some(path) if path != "true" => Some(ClusterConfig {
                    me: cfg.num_or("me", 0usize)?,
                    hosts: read_hosts(path)?,
                }),
                Some(_) => bail!("--cluster needs a hosts file (one host:port per machine)"),
                None => None,
            };
            run_app(&app, &cfg, cluster)
        }
        Some("worker") => worker(&args, &cfg),
        Some("figure") => {
            let name = args.pos(1).unwrap_or("all").to_string();
            let out = cfg.str_or("out-dir", "results");
            graphlab::sim::figures::run_figure(&name, std::path::Path::new(&out))
        }
        Some("partition") => match args.pos(1) {
            Some(app) => partition_app(app, &cfg),
            None => partition_demo(&cfg),
        },
        Some("calibrate") => calibrate(&cfg),
        Some("bench-sched") => bench_sched(&cfg),
        Some("bench-engines") => bench_engines(&cfg),
        Some("bench-wire") => bench_wire(&cfg),
        Some("bench-net") => bench_net(&cfg),
        _ => {
            eprintln!(
                "usage: graphlab <run|worker|figure|partition|calibrate|bench-sched|bench-engines|bench-wire|bench-net> [...]\n"
            );
            eprintln!("  graphlab run <pagerank|als|ner|coseg|gibbs> [--engine shared|chromatic|locking]");
            eprintln!("      [--machines N] [--threads N] [--scheduler fifo|priority|multiqueue|sweep|global-*]");
            eprintln!("      [--transport inproc|tcp] [--cluster HOSTS] [--pjrt] [--sweeps N] [--d N]");
            eprintln!("      [--atoms-dir DIR] [--snapshot-every K|Ns] [--snapshot-dir DIR] [--restore DIR]");
            eprintln!("      [--config FILE]");
            eprintln!("  graphlab worker [<app>] --me N --hosts HOSTS --atoms-dir DIR [--engine E]");
            eprintln!("      [--snapshot-every K|Ns] [--snapshot-dir DIR] [--restore DIR]");
            eprintln!("      (join a multi-process cluster as machine N; app inferred from the store)");
            eprintln!("  graphlab partition <pagerank|als|ner|coseg|gibbs> [--atoms-dir DIR] [--atoms K]");
            eprintln!("      (writes the app's data graph as an on-disk atom store; omit the app for the demo)");
            eprintln!("  graphlab figure <table2|fig1|fig5a|fig6a|fig6c|fig6d|fig7a|fig8a|fig8b|fig8c|fig8d|all>");
            eprintln!("      [--out-dir DIR]");
            eprintln!("  graphlab bench-sched [--out FILE] [--n N] [--sweeps N] [--quick]");
            eprintln!("  graphlab bench-engines [--out FILE] [--n N] [--sweeps N] [--machines N] [--quick]");
            eprintln!("  graphlab bench-wire [--out FILE] [--n N] [--quick]");
            eprintln!("  graphlab bench-net [--out FILE] [--n N] [--quick]");
            bail!("missing subcommand");
        }
    }
}

/// Parse a hosts file: one `host:port` per line; blank lines and `#`
/// comments are skipped, so the machine id is the index among the
/// *remaining* lines — commenting a host out renumbers every machine
/// after it (keep `--me` values in sync).
fn read_hosts(path: &str) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading hosts file {path}"))?;
    let hosts: Vec<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect();
    if hosts.is_empty() {
        bail!("hosts file {path} lists no machines");
    }
    Ok(hosts)
}

/// Map an atom store's stored vertex type name to the app that wrote it,
/// so `graphlab worker` can join a cluster knowing only the store. Reads
/// only the store's type tags (`peek_types`), not the whole meta file.
fn infer_app(dir: &std::path::Path) -> Result<&'static str> {
    let (vtype, _etype) = atoms::peek_types(dir)?;
    for (needle, app) in [
        ("pagerank::PrVertex", "pagerank"),
        ("als::AlsVertex", "als"),
        ("ner::NerVertex", "ner"),
        ("coseg::CosegVertex", "coseg"),
        ("gibbs::GibbsVertex", "gibbs"),
    ] {
        if vtype.ends_with(needle) {
            return Ok(app);
        }
    }
    bail!(
        "atom store {} holds unrecognized vertex type {vtype} — name the app explicitly",
        dir.display()
    );
}

/// `graphlab worker [<app>] --me N --hosts FILE --atoms-dir DIR`: join a
/// multi-process cluster as machine N. Identical engine code path to
/// `run --cluster`; only the machine id differs.
///
/// Every process derives its engine configuration from its OWN command
/// line — the handshake validates wire version, cluster size, and app
/// type, but not runtime flags. Launch all processes with identical
/// `--engine`/`--sweeps`/`--max-updates`/`--maxpending`/`--scheduler`/
/// `--seed` values (only `--me` differs), or per-machine behavior (e.g.
/// the locking engine's per-machine update caps) silently diverges.
fn worker(args: &Args, cfg: &Config) -> Result<()> {
    let Some(me_raw) = cfg.get("me") else {
        bail!("worker requires --me N (this process's machine id)");
    };
    let me: usize = me_raw
        .parse()
        .map_err(|e| anyhow::anyhow!("--me={me_raw}: {e}"))?;
    let Some(hosts_path) = cfg.get("hosts") else {
        bail!("worker requires --hosts FILE (one host:port per machine)");
    };
    let hosts = read_hosts(hosts_path)?;
    let Some(dir) = atoms_dir_flag(cfg) else {
        bail!(
            "worker requires --atoms-dir DIR: every process must replay the same \
             atom store (write one with `graphlab partition <app>`)"
        );
    };
    let app = match args.pos(1) {
        Some(a) => a.to_string(),
        None => infer_app(&dir)?.to_string(),
    };
    run_app(&app, cfg, Some(ClusterConfig { me, hosts }))
}

fn run_app(app: &str, cfg: &Config, cluster: Option<ClusterConfig>) -> Result<()> {
    let engine: EngineKind = cfg
        .str_or("engine", "chromatic")
        .parse()
        .context("--engine")?;
    let machines = cfg.num_or("machines", 2usize)?;
    let threads = cfg.num_or("threads", 2usize)?;
    let sweeps = cfg.num_or("sweeps", 20u64)?;
    let use_pjrt = cfg.bool_or("pjrt", false);
    if use_pjrt && !graphlab::runtime::available() {
        bail!(
            "--pjrt requested but the PJRT runtime is unavailable \
             (build with `--features pjrt` and run `make artifacts`)"
        );
    }
    let seed = cfg.num_or("seed", 1u64)?;
    // When --atoms-dir is given, the data graph is loaded from the on-disk
    // atom store (written by `graphlab partition <app>`) instead of being
    // regenerated; the distributed engines additionally replay each
    // machine's own atom journals (routed via `Engine::atoms_dir`).
    let atoms_dir = atoms_dir_flag(cfg);
    if let Some(c) = &cluster {
        if atoms_dir.is_none() {
            bail!(
                "cluster mode requires --atoms-dir: every process must derive the \
                 identical graph and placement from one stored atom set \
                 (run `graphlab partition {app}` first)"
            );
        }
        println!(
            "== graphlab run {app} (engine={engine}, cluster machine {}/{} over tcp) ==",
            c.me,
            c.hosts.len()
        );
    } else {
        let transport = cfg.str_or("transport", "inproc");
        println!(
            "== graphlab run {app} (engine={engine}, machines={machines}, transport={transport}) =="
        );
    }

    match app {
        "pagerank" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let n = cfg.num_or("n", 10_000usize)?;
                    let edges =
                        graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8)?, seed);
                    pagerank::build(n, &edges, 0.15)
                }
            };
            let n = g.num_vertices();
            let prog = pagerank::PageRank { alpha: 0.15, eps: 1e-6, n, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(pagerank::total_rank_sync())], "total_rank")
        }
        "als" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::netflix(
                        cfg.num_or("users", 2000)?, cfg.num_or("movies", 1000)?,
                        cfg.num_or("ratings-per-user", 30)?, 8, 0.2, seed);
                    als::build(&data, cfg.num_or("d", 20usize)?, seed)
                }
            };
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            anyhow::ensure!(g.num_vertices() > 0, "empty graph: nothing to run");
            // The latent dimension travels with the stored factors.
            let d = g.vertex_data(0).factor.len();
            let prog = als::Als { d, lambda: 0.08, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(als::rmse_sync())], "rmse")
        }
        "ner" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::ner(
                        cfg.num_or("nps", 5000)?, cfg.num_or("contexts", 2500)?,
                        cfg.num_or("edges-per-np", 30)?, 8, 0.1, seed);
                    ner::build(&data)
                }
            };
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            anyhow::ensure!(g.num_vertices() > 0, "empty graph: nothing to run");
            let k = g.vertex_data(0).dist.len();
            let prog = ner::Coem { k, smoothing: 0.01, eps: 1e-4, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(ner::accuracy_sync())], "accuracy")
        }
        "coseg" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::video(
                        cfg.num_or("frames", 16)?, cfg.num_or("width", 24)?,
                        cfg.num_or("height", 20)?, 5, 0.4, seed);
                    coseg::build(&data, 0.8)
                }
            };
            println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());
            anyhow::ensure!(g.num_vertices() > 0, "empty graph: nothing to run");
            let labels = g.vertex_data(0).belief.len();
            let prog = coseg::Coseg { labels, eps: 1e-3, sigma2: 0.5, use_pjrt };
            run_generic(g, prog, engine, machines, threads, sweeps, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(coseg::gmm_sync(labels)), Box::new(coseg::accuracy_sync())],
                "accuracy")
        }
        "gibbs" => {
            let g = match &atoms_dir {
                Some(dir) => atoms::load_graph(dir)?.0,
                None => {
                    let data = graphlab::datagen::mrf(cfg.num_or("side", 64)?, 0.4, seed);
                    gibbs::build(&data)
                }
            };
            let prog = gibbs::Gibbs { coupling: 0.4, target_samples: sweeps.max(10), seed };
            run_generic(g, prog, engine, machines, threads, u64::MAX, cfg, atoms_dir.as_deref(), cluster,
                vec![Box::new(gibbs::magnetization_sync())], "magnetization")
        }
        other => bail!("unknown app '{other}'"),
    }
}

/// Run a (graph, program) pair on the engine selected by `--engine`: one
/// builder call covers all three engines (and, with `cluster`, one
/// machine of a real multi-process TCP cluster).
#[allow(clippy::too_many_arguments)]
fn run_generic<V, E, P>(
    g: graphlab::graph::Graph<V, E>,
    prog: P,
    engine: EngineKind,
    machines: usize,
    threads: usize,
    sweeps: u64,
    cfg: &Config,
    atoms_dir: Option<&std::path::Path>,
    cluster: Option<ClusterConfig>,
    syncs: Vec<Box<dyn graphlab::engine::SyncOp<V>>>,
    probe_key: &'static str,
) -> Result<()>
where
    V: graphlab::distributed::DataValue,
    E: graphlab::distributed::DataValue,
    P: graphlab::engine::VertexProgram<V, E>,
{
    let n = g.num_vertices();
    let initial = apps::all_vertices(n);
    let seed = cfg.num_or("seed", 1u64)?;
    let sched_default = if engine == EngineKind::Locking { "priority" } else { "fifo" };
    let spec = SchedSpec::parse(&cfg.str_or("scheduler", sched_default), seed)
        .context("--scheduler")?;
    let transport: TransportKind = cfg
        .str_or("transport", "inproc")
        .parse()
        .context("--transport")?;
    // Update cap: a safety net for non-converging runs (the chromatic
    // engine is capped in whole sweeps via max_sweeps instead).
    let max_updates = cfg.num_or("max-updates", n as u64 * sweeps.min(10_000))?;
    let me = cluster.as_ref().map(|c| c.me);
    // The final value of the probe sync (e.g. PageRank's total_rank) —
    // printed after the run so cluster smoke tests can compare the
    // cluster result against an in-process oracle.
    let last_probe = std::sync::Arc::new(std::sync::Mutex::new(None::<f64>));
    let probe_out = last_probe.clone();
    let mut builder = Engine::new(engine)
        .workers(threads)
        .machines(machines)
        .scheduler(spec)
        .seed(seed)
        .transport(transport)
        .max_updates(max_updates)
        .max_sweeps(sweeps)
        .maxpending(cfg.num_or("maxpending", 64usize)?)
        .sync_period(Duration::from_millis(cfg.num_or("sync-ms", 100u64)?))
        .syncs(syncs)
        .on_progress(move |epoch, updates, gv| {
            if let Some(v) = gv.get(probe_key) {
                *probe_out.lock().unwrap() = Some(v[0]);
                println!("epoch {epoch:>3}: updates={updates:>9} {probe_key}={:.5}", v[0]);
            }
        });
    if let Some(c) = cluster {
        builder = builder.cluster(c.me, c.hosts);
    }
    if let Some(dir) = atoms_dir {
        // Distributed machines replay their own on-disk atom journals.
        builder = builder.atoms_dir(dir);
    }
    // --snapshot-every K|Ns: periodic Chandy–Lamport snapshots to
    // --snapshot-dir (default: the atom-store dir). --restore DIR resumes
    // from the newest complete snapshot under DIR after journal replay.
    if let Some(spec) = cfg.get("snapshot-every") {
        builder = builder.snapshot_every(SnapshotTrigger::parse(spec).context("--snapshot-every")?);
    }
    if let Some(dir) = cfg.get("snapshot-dir") {
        builder = builder.snapshot_to(dir);
    }
    if let Some(dir) = cfg.get("restore") {
        if dir == "true" {
            bail!("--restore needs a directory (the snapshot root)");
        }
        builder = builder.restore_from(dir);
    }
    let exec = builder.run(g, &prog, initial)?;
    let stats = &exec.stats;
    match me {
        // Cluster mode: per-machine stats are local to this process.
        Some(me) => println!(
            "done (machine {me}): {} updates, {} epochs, {:.2}s on {engine}, \
             {} bytes sent / {} msgs over tcp",
            stats.updates,
            stats.sweeps,
            stats.seconds,
            stats.bytes_sent.get(me).copied().unwrap_or(0),
            stats.msgs_sent.get(me).copied().unwrap_or(0),
        ),
        None => {
            println!(
                "done: {} updates, {} epochs, {:.2}s on {engine} \
                 ({} machine(s), balance {:.2}, {} MB sent)",
                stats.updates,
                stats.sweeps,
                stats.seconds,
                stats.machines(),
                stats.balance(),
                stats.total_bytes() / 1_000_000
            );
            if engine.is_distributed() {
                println!("bytes sent per machine: {:?}", stats.bytes_sent);
            }
        }
    }
    // Machine-parseable result line: the final cluster-wide sync value.
    // Every process of a cluster prints the same number (global syncs are
    // true cluster-wide reductions), so smoke tests can diff any worker's
    // line against an in-process oracle run.
    if let Some(v) = *last_probe.lock().unwrap() {
        println!("probe {probe_key}={v:.9}");
    }
    Ok(())
}

/// `--atoms-dir [DIR]`: an explicit DIR wins; a bare flag resolves the
/// default the cwd-robust way (`GRAPHLAB_ATOMS`, `atoms/`, workspace-root
/// `atoms/`) so `run` and `partition` agree on where the store lives.
fn atoms_dir_flag(cfg: &Config) -> Option<std::path::PathBuf> {
    cfg.get("atoms-dir").map(|v| {
        if v == "true" {
            atoms::resolve_atoms_dir(None)
        } else {
            std::path::PathBuf::from(v)
        }
    })
}

/// `graphlab partition <app>`: build the app's data graph (same flags and
/// datagen as `run`) and write it to disk as the paper's atom store — one
/// journal file per atom plus `meta.bin` — ready for `run --atoms-dir` on
/// any machine count.
fn partition_app(app: &str, cfg: &Config) -> Result<()> {
    let dir = atoms_dir_flag(cfg).unwrap_or_else(|| atoms::resolve_atoms_dir(None));
    let k = cfg.num_or("atoms", 128usize)?;
    let seed = cfg.num_or("seed", 1u64)?;
    match app {
        "pagerank" => {
            let n = cfg.num_or("n", 10_000usize)?;
            let edges = graphlab::datagen::web_graph(n, cfg.num_or("avg-degree", 8)?, seed);
            save_atom_store(&pagerank::build(n, &edges, 0.15), k, seed, &dir)
        }
        "als" => {
            let data = graphlab::datagen::netflix(
                cfg.num_or("users", 2000)?, cfg.num_or("movies", 1000)?,
                cfg.num_or("ratings-per-user", 30)?, 8, 0.2, seed);
            save_atom_store(&als::build(&data, cfg.num_or("d", 20usize)?, seed), k, seed, &dir)
        }
        "ner" => {
            let data = graphlab::datagen::ner(
                cfg.num_or("nps", 5000)?, cfg.num_or("contexts", 2500)?,
                cfg.num_or("edges-per-np", 30)?, 8, 0.1, seed);
            save_atom_store(&ner::build(&data), k, seed, &dir)
        }
        "coseg" => {
            let data = graphlab::datagen::video(
                cfg.num_or("frames", 16)?, cfg.num_or("width", 24)?,
                cfg.num_or("height", 20)?, 5, 0.4, seed);
            save_atom_store(&coseg::build(&data, 0.8), k, seed, &dir)
        }
        "gibbs" => {
            let data = graphlab::datagen::mrf(cfg.num_or("side", 64)?, 0.4, seed);
            save_atom_store(&gibbs::build(&data), k, seed, &dir)
        }
        other => bail!("unknown app '{other}'"),
    }
}

/// Over-partition `g` into `k` BFS atoms and persist the store to `dir`.
fn save_atom_store<V, E>(
    g: &graphlab::graph::Graph<V, E>,
    k: usize,
    seed: u64,
    dir: &std::path::Path,
) -> Result<()>
where
    V: graphlab::wire::Wire,
    E: graphlab::wire::Wire,
{
    let t0 = std::time::Instant::now();
    let atom_set = AtomSet::grow_bfs(g, k, seed);
    atom_set.save_atoms(g, dir)?;
    let sizes = atom_set.sizes();
    println!(
        "wrote {} atom journals (+meta.bin) for {} vertices / {} edges to {} in {:.2}s",
        atom_set.num_atoms(),
        g.num_vertices(),
        g.num_edges(),
        dir.display(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "atom sizes: min {} / max {} vertices; load with `graphlab run <app> --atoms-dir {}`",
        sizes.iter().min().unwrap_or(&0),
        sizes.iter().max().unwrap_or(&0),
        dir.display()
    );
    Ok(())
}

fn partition_demo(cfg: &Config) -> Result<()> {
    use graphlab::partition::atoms;
    let n = cfg.num_or("n", 20_000usize)?;
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let g = pagerank::build(n, &edges, 0.15);
    let k = cfg.num_or("atoms", 128usize)?;
    println!("two-phase partitioning: {} vertices, {} edges, {k} atoms", n, g.num_edges());
    let a = atoms::AtomSet::grow_bfs(&g, k, 2);
    let meta = atoms::MetaGraph::build(&g, &a);
    for machines in [2usize, 4, 8, 16] {
        let assign = meta.partition(machines);
        let vassign: Vec<usize> = (0..n as u32).map(|v| assign[a.atom(v)]).collect();
        let p = Partition::from_assignment(vassign, machines);
        let rand = Partition::random(n, machines, 3);
        println!(
            "  {machines:>2} machines: two-phase cut={} ({:.1}% | imbalance {:.2}) vs random cut={} ({:.1}%)",
            p.edge_cut(&g), 100.0 * p.edge_cut(&g) as f64 / g.num_edges() as f64, p.imbalance(),
            rand.edge_cut(&g), 100.0 * rand.edge_cut(&g) as f64 / g.num_edges() as f64,
        );
    }
    Ok(())
}

fn calibrate(_cfg: &Config) -> Result<()> {
    use graphlab::sim::calibrate as cal;
    println!("measured per-update costs (native path, this machine):");
    for d in [5usize, 20, 50, 100] {
        println!("  als d={d:>3}: {:>10.2} µs", cal::als_update_cost(d, 198) * 1e6);
    }
    println!("  coem k=8 deg=100: {:.2} µs", cal::coem_update_cost(8, 100) * 1e6);
    println!("  lbp  l=5 deg=6:   {:.2} µs", cal::lbp_update_cost(5) * 1e6);
    Ok(())
}

/// Shared-engine PageRank scheduler sweep: updates/sec at 1/2/4/8 threads,
/// single global queue (`global-fifo`) vs work stealing (`fifo` and
/// `multiqueue`), written as JSON for the perf trajectory
/// (`BENCH_pr2.json`). `--quick` shrinks the graph/workload for CI smoke.
fn bench_sched(cfg: &Config) -> Result<()> {
    let quick = cfg.bool_or("quick", false);
    let n = cfg.num_or("n", if quick { 5_000 } else { 20_000usize })?;
    let sweeps = cfg.num_or("sweeps", if quick { 4 } else { 12u64 })?;
    let out_path = cfg.str_or("out", "BENCH_pr2.json");
    let thread_counts = [1usize, 2, 4, 8];
    let specs = [
        SchedSpec::global(Policy::Fifo, 1),
        SchedSpec::ws(Policy::Fifo, 1),
        SchedSpec::ws(Policy::MultiQueue, 1),
    ];

    let edges = graphlab::datagen::web_graph(n, 8, 1);
    println!("== bench-sched: shared-engine PageRank, n={n}, {} edges, {sweeps} sweeps ==", edges.len());

    // eps = 0 keeps every update rescheduling its neighbors, so the run is
    // scheduler-bound until the max_updates cap — exactly the contention
    // path the scheduler work changes.
    let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
    struct Row {
        scheduler: String,
        threads: usize,
        updates: u64,
        seconds: f64,
        ups: f64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for spec in specs {
        for &threads in &thread_counts {
            let g = pagerank::build(n, &edges, 0.15);
            let exec = Engine::new(EngineKind::Shared)
                .workers(threads)
                .scheduler(spec)
                .max_updates(n as u64 * sweeps)
                .run(g, &prog, apps::all_vertices(n))?;
            let stats = exec.stats;
            let ups = stats.updates_per_sec();
            println!(
                "  {:<16} threads={threads}: {:>9} updates in {:.3}s = {:>12.0} updates/s",
                spec.name(), stats.updates, stats.seconds, ups
            );
            rows.push(Row {
                scheduler: spec.name(),
                threads,
                updates: stats.updates,
                seconds: stats.seconds,
                ups,
            });
        }
    }

    let ups_at = |sched: &str, threads: usize| -> f64 {
        rows.iter()
            .find(|r| r.scheduler == sched && r.threads == threads)
            .map(|r| r.ups)
            .unwrap_or(0.0)
    };
    let improved = ups_at("fifo", 4) > ups_at("global-fifo", 4);
    println!(
        "work-stealing vs single-queue at 4 threads: {}",
        if improved { "IMPROVED" } else { "NOT improved" }
    );

    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"scheduler\": \"{}\", \"threads\": {}, \"updates\": {}, \"seconds\": {:.6}, \"updates_per_sec\": {:.1}}}",
                r.scheduler, r.threads, r.updates, r.seconds, r.ups
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"shared-engine PageRank scheduler sweep (PR 2)\",\n  \
         \"command\": \"graphlab bench-sched\",\n  \"n\": {n},\n  \"avg_degree\": 8,\n  \
         \"sweeps\": {sweeps},\n  \"quick\": {quick},\n  \
         \"ws_beats_global_at_4_threads\": {improved},\n  \"results\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write(&out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Cross-engine PageRank comparison through the unified `Engine` builder:
/// the same workload on shared vs chromatic vs locking, updates/sec per
/// engine, written as JSON (`BENCH_pr3.json`, reusing the `bench-sched`
/// schema). `--quick` shrinks the workload for CI smoke.
fn bench_engines(cfg: &Config) -> Result<()> {
    let quick = cfg.bool_or("quick", false);
    let n = cfg.num_or("n", if quick { 3_000 } else { 10_000usize })?;
    let sweeps = cfg.num_or("sweeps", if quick { 3 } else { 10u64 })?;
    let machines = cfg.num_or("machines", 4usize)?;
    let threads = cfg.num_or("threads", 4usize)?;
    let out_path = cfg.str_or("out", "BENCH_pr3.json");

    let edges = graphlab::datagen::web_graph(n, 8, 1);
    println!(
        "== bench-engines: PageRank, n={n}, {} edges, {sweeps} sweeps, all engines ==",
        edges.len()
    );
    // eps = 0: every update reschedules its neighbors, so every engine
    // executes a full `sweeps`-worth of updates before hitting its cap —
    // the same amount of numeric work on every engine.
    let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
    struct Row {
        engine: &'static str,
        parallelism: usize,
        updates: u64,
        seconds: f64,
        ups: f64,
        mbytes: u64,
    }
    let mut rows: Vec<Row> = Vec::new();
    for kind in ENGINE_KINDS {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(kind)
            .workers(if kind == EngineKind::Shared { threads } else { 1 })
            .machines(machines)
            .seed(1)
            .max_updates(n as u64 * sweeps)
            .max_sweeps(sweeps)
            .maxpending(256)
            .run(g, &prog, apps::all_vertices(n))?;
        let stats = exec.stats;
        let parallelism = if kind == EngineKind::Shared { threads } else { machines };
        let ups = stats.updates_per_sec();
        println!(
            "  {:<10} x{parallelism}: {:>9} updates in {:.3}s = {:>12.0} updates/s, \
             balance {:.2}, {} MB sent",
            kind.name(),
            stats.updates,
            stats.seconds,
            ups,
            stats.balance(),
            stats.total_bytes() / 1_000_000
        );
        rows.push(Row {
            engine: kind.name(),
            parallelism,
            updates: stats.updates,
            seconds: stats.seconds,
            ups,
            mbytes: stats.total_bytes() / 1_000_000,
        });
    }

    let fastest = rows
        .iter()
        .max_by(|a, b| a.ups.partial_cmp(&b.ups).unwrap())
        .map(|r| r.engine)
        .unwrap_or("none");
    println!("fastest engine on this workload: {fastest}");

    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"engine\": \"{}\", \"threads\": {}, \"updates\": {}, \"seconds\": {:.6}, \"updates_per_sec\": {:.1}, \"mb_sent\": {}}}",
                r.engine, r.parallelism, r.updates, r.seconds, r.ups, r.mbytes
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"cross-engine PageRank comparison (PR 3, unified Engine API)\",\n  \
         \"command\": \"graphlab bench-engines\",\n  \"n\": {n},\n  \"avg_degree\": 8,\n  \
         \"sweeps\": {sweeps},\n  \"machines\": {machines},\n  \"quick\": {quick},\n  \
         \"fastest_engine\": \"{fastest}\",\n  \"results\": [\n{}\n  ]\n}}\n",
        body.join(",\n")
    );
    std::fs::write(&out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Wire-codec + atom-store benchmark: encode/decode throughput over a
/// ghost-flush-shaped payload, then save/load timings for an on-disk
/// PageRank atom store, written as JSON (`BENCH_pr4.json`; CI's
/// bench-smoke job runs the `--quick` variant).
fn bench_wire(cfg: &Config) -> Result<()> {
    use graphlab::wire::{self, Wire};
    let quick = cfg.bool_or("quick", false);
    let n = cfg.num_or("n", if quick { 4_000 } else { 20_000usize })?;
    let out_path = cfg.str_or("out", "BENCH_pr4.json");
    println!("== bench-wire: codec throughput + atom-store load, n={n} ==");

    // --- codec throughput over a realistic payload ---------------------
    // The shape of a chromatic ghost flush: (vertex, version, data)
    // triples with ALS d=20 factors (the heaviest common vertex type).
    let d = 20usize;
    let payload: Vec<(u32, u64, als::AlsVertex)> = (0..1024u32)
        .map(|i| {
            (i, i as u64, als::AlsVertex {
                factor: vec![0.1; d],
                sse: 1.0,
                cnt: 3.0,
                is_user: i % 2 == 0,
            })
        })
        .collect();
    let mut buf = Vec::new();
    payload.encode(&mut buf);
    let frame_bytes = buf.len();
    let reps = if quick { 50usize } else { 400 };
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        buf.clear();
        payload.encode(&mut buf);
    }
    let encode_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let mut decoded_elems = 0usize;
    for _ in 0..reps {
        let v: Vec<(u32, u64, als::AlsVertex)> = wire::from_bytes(&buf)?;
        decoded_elems += v.len();
    }
    let decode_s = t0.elapsed().as_secs_f64();
    let encode_mbps = (frame_bytes * reps) as f64 / encode_s.max(1e-9) / 1e6;
    let decode_mbps = (frame_bytes * reps) as f64 / decode_s.max(1e-9) / 1e6;
    println!(
        "  codec: {frame_bytes} B payload x {reps}: encode {encode_mbps:.0} MB/s, \
         decode {decode_mbps:.0} MB/s ({decoded_elems} elements decoded)"
    );

    // --- atom store: save, per-machine load, full replay ----------------
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    let g = pagerank::build(n, &edges, 0.15);
    let k = if quick { 32usize } else { 128 };
    let machines = 4usize;
    let dir = std::env::temp_dir().join(format!("graphlab-bench-wire-{}", std::process::id()));
    let atom_set = AtomSet::grow_bfs(&g, k, 1);
    let t0 = std::time::Instant::now();
    atom_set.save_atoms(&g, &dir)?;
    let save_s = t0.elapsed().as_secs_f64();
    let store = atoms::AtomStore::open(&dir)?;
    let (_partition, placement) = store.place(machines);
    let t0 = std::time::Instant::now();
    let lg: graphlab::distributed::LocalGraph<pagerank::PrVertex, pagerank::PrEdge> =
        graphlab::distributed::LocalGraph::from_atom_files(
            &dir,
            &placement.atom_to_machine,
            0,
        )?;
    let local_load_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let (g2, _) = atoms::load_graph::<pagerank::PrVertex, pagerank::PrEdge>(&dir)?;
    let full_load_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        g2.num_vertices() == g.num_vertices() && g2.num_edges() == g.num_edges(),
        "atom-store round trip changed the graph shape"
    );
    std::fs::remove_dir_all(&dir).ok();
    println!(
        "  atoms: {k} journals for n={n}: save {save_s:.3}s, machine-0 load \
         {local_load_s:.3}s ({} owned vertices), full replay {full_load_s:.3}s",
        lg.owned
    );

    let json = format!(
        "{{\n  \"bench\": \"wire codec + on-disk atom store (PR 4)\",\n  \
         \"command\": \"graphlab bench-wire\",\n  \"n\": {n},\n  \"atoms\": {k},\n  \
         \"machines\": {machines},\n  \"quick\": {quick},\n  \"results\": {{\n    \
         \"codec_payload_bytes\": {frame_bytes},\n    \"codec_reps\": {reps},\n    \
         \"encode_mb_per_sec\": {encode_mbps:.1},\n    \"decode_mb_per_sec\": {decode_mbps:.1},\n    \
         \"atoms_save_seconds\": {save_s:.6},\n    \"machine0_load_seconds\": {local_load_s:.6},\n    \
         \"full_replay_seconds\": {full_load_s:.6}\n  }}\n}}\n"
    );
    std::fs::write(&out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}

/// Transport comparison: in-proc channels vs real loopback-TCP sockets —
/// framing-layer ping-pong round trips, then a 2-machine chromatic
/// PageRank on each backend — written as JSON (`BENCH_pr5.json`; CI's
/// bench-smoke job runs the `--quick` variant).
fn bench_net(cfg: &Config) -> Result<()> {
    use graphlab::distributed::{Network, NetworkModel};
    let quick = cfg.bool_or("quick", false);
    let n = cfg.num_or("n", if quick { 3_000 } else { 10_000usize })?;
    let sweeps = cfg.num_or("sweeps", if quick { 3 } else { 10u64 })?;
    let reps = cfg.num_or("reps", if quick { 500usize } else { 5_000 })?;
    let out_path = cfg.str_or("out", "BENCH_pr5.json");
    println!("== bench-net: in-proc vs loopback-TCP, {reps} round trips + PageRank n={n} ==");

    // --- framing-layer ping-pong: 4 KiB frames between 2 machines -------
    let payload = vec![7u8; 4096];
    // The bytes NetStats actually counts per frame: 4-byte frame prefix
    // + the Vec codec's own length prefix + the payload.
    let frame_bytes = graphlab::wire::encoded_len(&payload) + 4;
    struct RtRow {
        transport: &'static str,
        rt_us: f64,
        mbps: f64,
    }
    let mut rt_rows: Vec<RtRow> = Vec::new();
    for transport in [TransportKind::InProc, TransportKind::Tcp] {
        let net: Network<Vec<u8>> = match transport {
            TransportKind::InProc => Network::new(2, NetworkModel::default()),
            TransportKind::Tcp => Network::tcp_loopback(2)?,
        };
        let mut eps = net.into_endpoints();
        let ep1 = eps.pop().unwrap();
        let mut ep0 = eps.pop().unwrap();
        let echo = std::thread::spawn(move || {
            let mut ep1 = ep1;
            for _ in 0..reps {
                let r = ep1.recv_timeout(Duration::from_secs(30)).expect("ping lost");
                ep1.send(0, r.msg);
            }
        });
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            ep0.send(1, payload.clone());
            ep0.recv_timeout(Duration::from_secs(30)).expect("pong lost");
        }
        let secs = t0.elapsed().as_secs_f64();
        echo.join().map_err(|_| anyhow::anyhow!("echo thread panicked"))?;
        let rt_us = secs / reps as f64 * 1e6;
        let mbps = (frame_bytes * 2 * reps) as f64 / secs.max(1e-9) / 1e6;
        println!(
            "  {:<7} frame round trip: {rt_us:>8.1} µs ({mbps:>8.1} MB/s both ways)",
            transport.name()
        );
        rt_rows.push(RtRow { transport: transport.name(), rt_us, mbps });
    }

    // --- 2-machine chromatic PageRank: same workload, both backends -----
    let edges = graphlab::datagen::web_graph(n, 8, 1);
    // eps = 0: every update reschedules its neighbors, so both backends
    // execute identical work; only the substrate differs.
    let prog = pagerank::PageRank { alpha: 0.15, eps: 0.0, n, use_pjrt: false };
    struct PrRow {
        transport: &'static str,
        updates: u64,
        seconds: f64,
        ups: f64,
        bytes: u64,
    }
    let mut pr_rows: Vec<PrRow> = Vec::new();
    for transport in [TransportKind::InProc, TransportKind::Tcp] {
        let g = pagerank::build(n, &edges, 0.15);
        let exec = Engine::new(EngineKind::Chromatic)
            .machines(2)
            .transport(transport)
            .max_sweeps(sweeps)
            .sync(pagerank::total_rank_sync())
            .run(g, &prog, apps::all_vertices(n))?;
        let s = exec.stats;
        let ups = s.updates_per_sec();
        println!(
            "  {:<7} pagerank x2 machines: {:>9} updates in {:.3}s = {:>12.0} updates/s, \
             {} bytes sent",
            transport.name(),
            s.updates,
            s.seconds,
            ups,
            s.total_bytes()
        );
        pr_rows.push(PrRow {
            transport: transport.name(),
            updates: s.updates,
            seconds: s.seconds,
            ups,
            bytes: s.total_bytes(),
        });
    }

    let rt_body: Vec<String> = rt_rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"transport\": \"{}\", \"round_trip_us\": {:.2}, \"mb_per_sec\": {:.1}}}",
                r.transport, r.rt_us, r.mbps
            )
        })
        .collect();
    let pr_body: Vec<String> = pr_rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"transport\": \"{}\", \"updates\": {}, \"seconds\": {:.6}, \"updates_per_sec\": {:.1}, \"bytes_sent\": {}}}",
                r.transport, r.updates, r.seconds, r.ups, r.bytes
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"transport comparison: in-proc vs loopback TCP (PR 5)\",\n  \
         \"command\": \"graphlab bench-net\",\n  \"n\": {n},\n  \"sweeps\": {sweeps},\n  \
         \"frame_bytes\": {frame_bytes},\n  \"round_trips\": {reps},\n  \"quick\": {quick},\n  \
         \"frame_round_trips\": [\n{}\n  ],\n  \"pagerank_2_machines\": [\n{}\n  ]\n}}\n",
        rt_body.join(",\n"),
        pr_body.join(",\n")
    );
    std::fs::write(&out_path, json).with_context(|| format!("writing {out_path}"))?;
    println!("wrote {out_path}");
    Ok(())
}
