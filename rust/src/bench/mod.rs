//! Micro-benchmark harness (no `criterion` offline).
//!
//! Provides warmup + timed iterations + robust statistics, used by the
//! `benches/` targets (built with `harness = false`) and the §Perf pass.
//! Results print in a criterion-like one-line format and can be exported
//! as CSV.

use std::time::Instant;

use crate::util::stats::{median, percentile};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// p10 seconds.
    pub p10_s: f64,
    /// p90 seconds.
    pub p90_s: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl BenchResult {
    /// criterion-like display line.
    pub fn line(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  ({} iters)",
            self.name,
            fmt_time(self.p10_s),
            fmt_time(self.median_s),
            fmt_time(self.p90_s),
            self.iters
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.2} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark `f`, auto-scaling iteration count to `target_time`.
pub fn bench(name: &str, target_time_s: f64, mut f: impl FnMut()) -> BenchResult {
    // Warmup + estimate.
    let warm_start = Instant::now();
    f();
    let one = warm_start.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_time_s / one) as usize).clamp(5, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let r = BenchResult {
        name: name.to_string(),
        median_s: median(&samples),
        p10_s: percentile(&samples, 10.0),
        p90_s: percentile(&samples, 90.0),
        iters,
    };
    println!("{}", r.line());
    r
}

/// Benchmark a batch operation, reporting per-item time.
pub fn bench_throughput(
    name: &str,
    target_time_s: f64,
    items_per_call: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    let mut r = bench(name, target_time_s, &mut f);
    let per_item = r.median_s / items_per_call.max(1) as f64;
    println!(
        "{:<44}   -> {} per item ({:.0} items/s)",
        "", fmt_time(per_item), 1.0 / per_item
    );
    r.median_s = per_item;
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let r = bench("noop-ish", 0.02, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.median_s > 0.0);
        assert!(r.p10_s <= r.p90_s);
        assert!(r.iters >= 5);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains(" s"));
    }
}
