//! Runtime metrics: counters, timers, histograms.
//!
//! Every distributed component (network, lock manager, engines) records
//! into a [`Metrics`] registry; the figure harnesses read them out (e.g.
//! bytes/sec/node for Fig. 6(b), lock latencies for Fig. 8(b)).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A concurrent metrics registry.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
}

/// Handle to a single counter (cheap to clone, lock-free to bump).
pub type Counter = std::sync::Arc<AtomicU64>;

impl Metrics {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create a counter by name.
    pub fn counter(&self, name: &str) -> Counter {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(AtomicU64::new(0)))
            .clone()
    }

    /// Add to a counter by name (slow path; hot paths hold a [`Counter`]).
    pub fn add(&self, name: &str, delta: u64) {
        self.counter(name).fetch_add(delta, Ordering::Relaxed);
    }

    /// Read a counter (0 if absent).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Reset every counter to zero.
    pub fn reset(&self) {
        for c in self.counters.lock().unwrap().values() {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// Scope timer that adds elapsed nanoseconds to a counter on drop.
pub struct ScopedTimer {
    start: Instant,
    counter: Counter,
}

impl ScopedTimer {
    /// Start timing into `counter`.
    pub fn new(counter: Counter) -> Self {
        ScopedTimer {
            start: Instant::now(),
            counter,
        }
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        self.counter
            .fetch_add(self.start.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Fixed-bucket log-scale histogram (powers of two, nanosecond scale).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// 64 power-of-two buckets.
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record a value.
    pub fn record(&self, value: u64) {
        let b = (64 - value.max(1).leading_zeros() as usize).min(63);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    /// Total recorded count.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate quantile (bucket upper bound), `q` in [0,1].
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return 1u64 << i;
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_concurrently() {
        let m = Metrics::new();
        let c = m.counter("bytes");
        crate::util::ThreadPool::new(8).parallel_for(1000, 10, |_| {
            c.fetch_add(3, Ordering::Relaxed);
        });
        assert_eq!(m.get("bytes"), 3000);
        m.reset();
        assert_eq!(m.get("bytes"), 0);
    }

    #[test]
    fn snapshot_lists_all() {
        let m = Metrics::new();
        m.add("a", 1);
        m.add("b", 2);
        let s = m.snapshot();
        assert_eq!(s["a"], 1);
        assert_eq!(s["b"], 2);
    }

    #[test]
    fn timer_records_positive_elapsed() {
        let m = Metrics::new();
        {
            let _t = ScopedTimer::new(m.counter("t"));
            std::hint::black_box(0);
        }
        assert!(m.get("t") > 0);
    }

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) >= 512);
    }
}
