//! Per-worker task queues with work stealing (the shared-memory engine's
//! multi-worker execution structure).
//!
//! The original shared engine funneled every `push`/`pop` through one
//! mutex-guarded scheduler, so the hot path serialized exactly where the
//! paper parallelizes (Sec. 4.2.2: workers pull update tasks with minimal
//! contention). [`WorkStealing`] gives each worker its own local queue —
//! any [`super::Policy`] (FIFO deque, exact-priority heap, multiqueue,
//! sweep) — and a worker whose queue runs dry steals from a random victim.
//! Local pushes and pops touch only the worker's own lock, which is
//! contended only while a steal is in progress.
//!
//! **Global dedup.** GraphLab task-set semantics (`T ∪ T'`) must hold
//! across queues, not just within one: a `home` array records which queue
//! (if any) currently holds each vertex. A push for a vertex homed in
//! queue `q` merges into `q` under `q`'s lock (keeping the max priority,
//! like the single-queue schedulers); a push for an un-homed vertex claims
//! it for the pusher's own queue. Claim (CAS) and un-claim (store in
//! `pop`) both happen while holding the owning queue's lock, so the
//! home array and queue contents can never disagree — the property tests
//! in `rust/tests/scheduler_props.rs` hammer this.
//!
//! **Termination.** `outstanding` counts queued *plus in-flight* tasks:
//! incremented when a push inserts a new task, decremented by
//! [`WorkStealing::task_done`] only after the update has executed *and*
//! published its follow-up tasks. It therefore never reads 0 while work
//! can still appear, giving the engine a race-free global termination
//! check (replacing the old pop-then-spin heuristic). Idle workers park in
//! [`WorkStealing::park`] on a condvar (with a timeout backstop) instead
//! of spinning; pushes and the final `task_done` wake them.
//!
//! With `workers == 1` no stealing or randomness occurs and the structure
//! degenerates to exactly the underlying policy's single-queue semantics —
//! preserving the sequential oracle used by the equivalence tests.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::{Policy, Scheduler, Task};
use crate::util::Rng;

/// Sentinel: vertex is in no queue.
const NONE: u32 = u32::MAX;

/// Per-worker queues + stealing over a fixed vertex universe.
pub struct WorkStealing {
    queues: Vec<Mutex<Box<dyn Scheduler>>>,
    /// `home[v]`: index of the queue currently holding `v`, or `NONE`.
    home: Vec<AtomicU32>,
    /// Queued + in-flight tasks (see module docs).
    outstanding: AtomicUsize,
    /// Idle-worker parking lot.
    idle: Mutex<()>,
    idle_cv: Condvar,
}

impl WorkStealing {
    /// One `policy` queue per worker over `num_vertices` vertices.
    /// Randomized policies derive per-queue seeds from `seed`.
    pub fn new(policy: Policy, num_vertices: usize, workers: usize, seed: u64) -> Self {
        let workers = workers.max(1);
        assert!(workers < NONE as usize, "worker count overflows home array");
        WorkStealing {
            queues: (0..workers)
                .map(|w| {
                    Mutex::new(policy.build(
                        num_vertices,
                        seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
                    ))
                })
                .collect(),
            home: (0..num_vertices).map(|_| AtomicU32::new(NONE)).collect(),
            outstanding: AtomicUsize::new(0),
            idle: Mutex::new(()),
            idle_cv: Condvar::new(),
        }
    }

    /// Number of worker queues.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// Queued + in-flight task count (0 ⇔ the run has quiesced, provided
    /// every popped task is matched by a [`WorkStealing::task_done`]).
    pub fn outstanding(&self) -> usize {
        self.outstanding.load(Ordering::SeqCst)
    }

    /// Add (or merge) a task from `worker`. New tasks go to `worker`'s own
    /// queue; tasks already queued elsewhere merge in place (max priority,
    /// exactly the single-queue dedup semantics).
    pub fn push(&self, worker: usize, task: Task) {
        let v = task.vertex as usize;
        loop {
            let h = self.home[v].load(Ordering::Acquire);
            if h == NONE {
                let mut q = self.queues[worker].lock().unwrap();
                // Claim under our own queue's lock: a pop of this vertex is
                // impossible (it is in no queue), and a racing claimer
                // makes our CAS fail, sending us around to merge.
                if self.home[v]
                    .compare_exchange(NONE, worker as u32, Ordering::AcqRel, Ordering::Acquire)
                    .is_err()
                {
                    continue;
                }
                q.push(task);
                // Increment before releasing the lock: a thief cannot pop
                // this task (and `task_done` it) until the lock drops, so
                // `outstanding` can never transiently undercount.
                self.outstanding.fetch_add(1, Ordering::SeqCst);
                drop(q);
                self.idle_cv.notify_one();
                return;
            }
            // Merge into the homing queue. Its pop clears `home[v]` while
            // holding the same lock, so the recheck below is race-free.
            let mut q = self.queues[h as usize].lock().unwrap();
            if self.home[v].load(Ordering::Acquire) != h {
                continue; // popped (or re-homed) meanwhile — retry
            }
            q.push(task);
            return;
        }
    }

    fn try_pop_from(&self, qi: usize) -> Option<Task> {
        let mut q = self.queues[qi].lock().unwrap();
        let t = q.pop()?;
        self.home[t.vertex as usize].store(NONE, Ordering::Release);
        Some(t)
    }

    /// Remove the next task for `worker`: its own queue first, then steal
    /// from victims in random rotation. `None` means every queue was empty
    /// at the moment it was inspected — check [`WorkStealing::outstanding`]
    /// before concluding the run is over.
    pub fn pop(&self, worker: usize, rng: &mut Rng) -> Option<Task> {
        if let Some(t) = self.try_pop_from(worker) {
            return Some(t);
        }
        let k = self.queues.len();
        if k == 1 {
            return None;
        }
        let start = rng.gen_range(k);
        for i in 0..k {
            let victim = (start + i) % k;
            if victim == worker {
                continue;
            }
            if let Some(t) = self.try_pop_from(victim) {
                return Some(t);
            }
        }
        None
    }

    /// Report a popped task finished (its update executed — or was
    /// abandoned — and its follow-up pushes are published). Decrementing
    /// only here keeps `outstanding` from reading 0 while an in-flight
    /// update could still schedule work.
    pub fn task_done(&self) {
        if self.outstanding.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Reached zero: wake every parked worker so they observe
            // termination.
            self.idle_cv.notify_all();
        }
    }

    /// Park briefly while there is outstanding work this worker cannot
    /// reach (all of it in flight on other workers). Returns immediately
    /// once the pool has drained. The timeout bounds any missed-wakeup
    /// window.
    pub fn park(&self) {
        let guard = self.idle.lock().unwrap();
        if self.outstanding.load(Ordering::SeqCst) == 0 {
            return;
        }
        let (_guard, _timed_out) = self
            .idle_cv
            .wait_timeout(guard, Duration::from_micros(100))
            .unwrap();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: u32, p: f64) -> Task {
        Task { vertex: v, priority: p }
    }

    #[test]
    fn single_worker_matches_plain_fifo_semantics() {
        let ws = WorkStealing::new(Policy::Fifo, 16, 1, 0);
        let mut rng = Rng::new(1);
        for v in [3u32, 1, 3, 7] {
            ws.push(0, t(v, 0.0));
        }
        assert_eq!(ws.outstanding(), 3);
        let order: Vec<u32> = std::iter::from_fn(|| ws.pop(0, &mut rng))
            .map(|x| x.vertex)
            .collect();
        assert_eq!(order, vec![3, 1, 7]);
        for _ in 0..3 {
            ws.task_done();
        }
        assert_eq!(ws.outstanding(), 0);
    }

    #[test]
    fn stealing_finds_remote_tasks() {
        let ws = WorkStealing::new(Policy::Fifo, 64, 4, 9);
        let mut rng = Rng::new(2);
        for v in 0..32u32 {
            ws.push((v % 4) as usize, t(v, 0.0));
        }
        // Worker 2 alone can drain everything via steals.
        let mut got: Vec<u32> = std::iter::from_fn(|| ws.pop(2, &mut rng))
            .map(|x| x.vertex)
            .collect();
        got.sort_unstable();
        assert_eq!(got, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn merge_keeps_max_priority_across_workers() {
        let ws = WorkStealing::new(Policy::Priority, 8, 2, 0);
        let mut rng = Rng::new(3);
        ws.push(0, t(5, 1.0));
        ws.push(1, t(5, 9.0)); // merges into worker 0's queue
        ws.push(1, t(5, 0.5)); // ignored (lower)
        assert_eq!(ws.outstanding(), 1);
        let task = ws.pop(1, &mut rng).unwrap();
        assert_eq!(task.vertex, 5);
        assert_eq!(task.priority, 9.0);
        assert!(ws.pop(0, &mut rng).is_none());
    }

    #[test]
    fn outstanding_counts_in_flight_tasks() {
        let ws = WorkStealing::new(Policy::Fifo, 8, 2, 0);
        let mut rng = Rng::new(4);
        ws.push(0, t(1, 0.0));
        let task = ws.pop(0, &mut rng).unwrap();
        assert_eq!(task.vertex, 1);
        // Popped but not done: still outstanding (in flight).
        assert_eq!(ws.outstanding(), 1);
        ws.push(0, t(2, 0.0)); // follow-up published before done
        ws.task_done();
        assert_eq!(ws.outstanding(), 1);
        assert_eq!(ws.pop(1, &mut rng).unwrap().vertex, 2);
        ws.task_done();
        assert_eq!(ws.outstanding(), 0);
    }
}
