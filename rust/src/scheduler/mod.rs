//! Task schedulers: the `RemoveNext(T)` policies of the execution model
//! (paper Alg. 2, Sec. 3.4).
//!
//! GraphLab leaves the order of task removal to the implementation; ours
//! provides the same menu as the paper's runtime:
//!
//! * [`SweepScheduler`] — fixed canonical order (the Chromatic engine's
//!   static schedule is a color-stratified sweep built on this),
//! * [`FifoScheduler`] — approximate FIFO,
//! * [`PriorityScheduler`] — exact max-priority (binary heap),
//! * [`MultiQueueScheduler`] — the *approximate* priority queue the paper
//!   uses in the distributed Locking engine (per-worker heaps with random
//!   two-choice popping, trading strict order for lower contention).
//!
//! All schedulers deduplicate: scheduling an already-queued vertex merges
//! the task, keeping the maximum priority (GraphLab task-set semantics:
//! `T <- T u T'`).
//!
//! The types above are single-consumer queues (`&mut self`); the
//! shared-memory engine's multi-worker execution path wraps them in
//! [`work_stealing::WorkStealing`] — one local queue per worker plus
//! stealing — so the hot pop path never serializes on one shared lock.

pub mod work_stealing;

pub use work_stealing::WorkStealing;

use anyhow::bail;

use crate::graph::VertexId;
use crate::wire::Wire;
use crate::util::Rng;
use std::collections::{BinaryHeap, VecDeque};

/// A schedulable update task: target vertex + priority.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Task {
    /// Vertex the update function will run on.
    pub vertex: VertexId,
    /// Priority (higher runs earlier under priority scheduling).
    pub priority: f64,
}

/// Tasks cross machines inside the distributed engines' ghost/release
/// frames: 12 bytes (vertex + priority).
impl Wire for Task {
    fn encode(&self, out: &mut Vec<u8>) {
        self.vertex.encode(out);
        self.priority.encode(out);
    }
    fn decode(input: &mut &[u8]) -> crate::wire::Result<Self> {
        Ok(Task {
            vertex: VertexId::decode(input)?,
            priority: f64::decode(input)?,
        })
    }
}

/// Common scheduler interface (single consumer; engines wrap in a mutex
/// per machine, mirroring the paper's per-node schedulers).
pub trait Scheduler: Send {
    /// Add (or merge) a task.
    fn push(&mut self, task: Task);
    /// Remove the next task per this scheduler's policy.
    fn pop(&mut self) -> Option<Task>;
    /// Number of pending tasks.
    fn len(&self) -> usize;
    /// Whether no tasks are pending.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Bulk-push tasks injected from outside the local update loop —
    /// remote reschedules carried in ghost frames (serving mode's
    /// dirtied-neighborhood propagation) land here. Same merge
    /// semantics as [`Scheduler::push`], applied per task.
    fn inject(&mut self, tasks: &[Task]) {
        for t in tasks {
            self.push(*t);
        }
    }
}

/// `RemoveNext(T)` policy names (CLI/config selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Approximate first-in-first-out.
    Fifo,
    /// Exact max-priority.
    Priority,
    /// Approximate priority via multiple internal heaps.
    MultiQueue,
    /// Fixed canonical (ascending vertex id) order.
    Sweep,
}

/// Every policy, in CLI listing order.
pub const POLICIES: [Policy; 4] = [
    Policy::Fifo,
    Policy::Priority,
    Policy::MultiQueue,
    Policy::Sweep,
];

impl Policy {
    /// Parse a policy name; unknown names are an error, not a panic.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        Ok(match s {
            "fifo" => Policy::Fifo,
            "priority" => Policy::Priority,
            "multiqueue" => Policy::MultiQueue,
            "sweep" => Policy::Sweep,
            other => bail!("unknown scheduler '{other}' (fifo|priority|multiqueue|sweep)"),
        })
    }

    /// The CLI name of this policy.
    pub fn name(self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Priority => "priority",
            Policy::MultiQueue => "multiqueue",
            Policy::Sweep => "sweep",
        }
    }

    /// Build a single-consumer scheduler implementing this policy.
    pub fn build(self, num_vertices: usize, seed: u64) -> Box<dyn Scheduler> {
        match self {
            Policy::Fifo => Box::new(FifoScheduler::new(num_vertices)),
            Policy::Priority => Box::new(PriorityScheduler::new(num_vertices)),
            Policy::MultiQueue => Box::new(MultiQueueScheduler::new(num_vertices, 4, seed)),
            Policy::Sweep => Box::new(SweepScheduler::new(num_vertices)),
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Build a scheduler by name. Returns an error (not a panic) on unknown
/// names so CLI/config misuse surfaces as a clean `bail!`.
pub fn by_name(name: &str, num_vertices: usize, seed: u64) -> anyhow::Result<Box<dyn Scheduler>> {
    Ok(Policy::parse(name)?.build(num_vertices, seed))
}

/// How the shared-memory engine should organize task queues.
///
/// * `work_stealing = true` (the default): one local queue per worker with
///   stealing — the paper's low-contention multiqueue direction.
/// * `work_stealing = false`: the single mutex-guarded global queue (the
///   pre-work-stealing baseline, kept for A/B benchmarking as
///   `global-<policy>` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedSpec {
    /// Pop policy of each queue.
    pub policy: Policy,
    /// Per-worker queues + stealing vs one shared queue.
    pub work_stealing: bool,
    /// Seed for randomized policies (multiqueue) and victim selection.
    pub seed: u64,
}

impl SchedSpec {
    /// Work-stealing spec (the default execution mode).
    pub fn ws(policy: Policy, seed: u64) -> Self {
        SchedSpec { policy, work_stealing: true, seed }
    }

    /// Single-global-queue spec (the contended baseline).
    pub fn global(policy: Policy, seed: u64) -> Self {
        SchedSpec { policy, work_stealing: false, seed }
    }

    /// Parse `fifo|priority|multiqueue|sweep` (work-stealing) or
    /// `global-fifo|...` (single shared queue).
    pub fn parse(s: &str, seed: u64) -> anyhow::Result<Self> {
        match s.strip_prefix("global-") {
            Some(rest) => Ok(SchedSpec::global(Policy::parse(rest)?, seed)),
            None => Ok(SchedSpec::ws(Policy::parse(s)?, seed)),
        }
    }

    /// The CLI name (`fifo`, `global-fifo`, ...).
    pub fn name(&self) -> String {
        if self.work_stealing {
            self.policy.name().to_string()
        } else {
            format!("global-{}", self.policy.name())
        }
    }
}

impl Default for SchedSpec {
    fn default() -> Self {
        SchedSpec::ws(Policy::Fifo, 0)
    }
}

// ---------------------------------------------------------------------------
// FIFO
// ---------------------------------------------------------------------------

/// First-in-first-out with membership dedup.
pub struct FifoScheduler {
    queue: VecDeque<VertexId>,
    queued: Vec<bool>,
}

impl FifoScheduler {
    /// FIFO over a vertex universe of `num_vertices`.
    pub fn new(num_vertices: usize) -> Self {
        FifoScheduler {
            queue: VecDeque::new(),
            queued: vec![false; num_vertices],
        }
    }
}

impl Scheduler for FifoScheduler {
    fn push(&mut self, task: Task) {
        let q = &mut self.queued[task.vertex as usize];
        if !*q {
            *q = true;
            self.queue.push_back(task.vertex);
        }
    }

    fn pop(&mut self) -> Option<Task> {
        let v = self.queue.pop_front()?;
        self.queued[v as usize] = false;
        Some(Task {
            vertex: v,
            priority: 0.0,
        })
    }

    fn len(&self) -> usize {
        self.queue.len()
    }
}

// ---------------------------------------------------------------------------
// Exact priority
// ---------------------------------------------------------------------------

#[derive(PartialEq)]
struct HeapEntry {
    priority: f64,
    vertex: VertexId,
}

impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.priority
            .partial_cmp(&o.priority)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.vertex.cmp(&o.vertex))
    }
}

/// Exact max-priority scheduler (lazy-deletion binary heap).
pub struct PriorityScheduler {
    heap: BinaryHeap<HeapEntry>,
    /// Current merged priority per vertex; NAN = not queued.
    current: Vec<f64>,
    live: usize,
}

impl PriorityScheduler {
    /// Priority scheduler over `num_vertices`.
    pub fn new(num_vertices: usize) -> Self {
        PriorityScheduler {
            heap: BinaryHeap::new(),
            current: vec![f64::NAN; num_vertices],
            live: 0,
        }
    }
}

impl Scheduler for PriorityScheduler {
    fn push(&mut self, task: Task) {
        let cur = &mut self.current[task.vertex as usize];
        if cur.is_nan() {
            *cur = task.priority;
            self.live += 1;
            self.heap.push(HeapEntry {
                priority: task.priority,
                vertex: task.vertex,
            });
        } else if task.priority > *cur {
            *cur = task.priority;
            self.heap.push(HeapEntry {
                priority: task.priority,
                vertex: task.vertex,
            });
        }
        // Lower priority merges into the existing (higher) entry: no-op.
    }

    fn pop(&mut self) -> Option<Task> {
        while let Some(top) = self.heap.pop() {
            let cur = self.current[top.vertex as usize];
            if !cur.is_nan() && cur == top.priority {
                self.current[top.vertex as usize] = f64::NAN;
                self.live -= 1;
                return Some(Task {
                    vertex: top.vertex,
                    priority: top.priority,
                });
            }
            // else: stale lazy-deleted entry
        }
        None
    }

    fn len(&self) -> usize {
        self.live
    }
}

// ---------------------------------------------------------------------------
// Approximate priority (multi-queue)
// ---------------------------------------------------------------------------

/// Approximate priority via `q` internal heaps: pushes go to a random heap,
/// pops take the better top of two random heaps ("power of two choices").
/// This is the low-contention structure the paper's distributed locking
/// engine uses ("efficient approximate FIFO/priority task-queues").
pub struct MultiQueueScheduler {
    queues: Vec<PriorityScheduler>,
    /// Which internal queue a vertex currently lives in (for dedup).
    home: Vec<u8>,
    rng: Rng,
    live: usize,
}

impl MultiQueueScheduler {
    /// `q` internal heaps over `num_vertices`.
    pub fn new(num_vertices: usize, q: usize, seed: u64) -> Self {
        let q = q.clamp(1, 255);
        MultiQueueScheduler {
            queues: (0..q).map(|_| PriorityScheduler::new(num_vertices)).collect(),
            home: vec![u8::MAX; num_vertices],
            rng: Rng::new(seed),
            live: 0,
        }
    }
}

impl Scheduler for MultiQueueScheduler {
    fn push(&mut self, task: Task) {
        let h = self.home[task.vertex as usize];
        if h != u8::MAX {
            // Already queued: merge within its home queue.
            self.queues[h as usize].push(task);
            return;
        }
        let q = self.rng.gen_range(self.queues.len());
        self.home[task.vertex as usize] = q as u8;
        let before = self.queues[q].len();
        self.queues[q].push(task);
        self.live += self.queues[q].len() - before;
    }

    fn pop(&mut self) -> Option<Task> {
        if self.live == 0 {
            return None;
        }
        let k = self.queues.len();
        let a = self.rng.gen_range(k);
        let b = self.rng.gen_range(k);
        let pick = |qs: &Vec<PriorityScheduler>, i: usize, j: usize| {
            let pi = qs[i].heap.peek().map(|e| e.priority);
            let pj = qs[j].heap.peek().map(|e| e.priority);
            match (pi, pj) {
                (Some(x), Some(y)) if y > x => j,
                (None, Some(_)) => j,
                _ => i,
            }
        };
        let mut q = pick(&self.queues, a, b);
        // Fall back to a scan if both sampled queues are empty.
        if self.queues[q].is_empty() {
            q = (0..k).find(|&i| !self.queues[i].is_empty())?;
        }
        let t = self.queues[q].pop()?;
        self.home[t.vertex as usize] = u8::MAX;
        self.live -= 1;
        Some(t)
    }

    fn len(&self) -> usize {
        self.live
    }
}

// ---------------------------------------------------------------------------
// Sweep
// ---------------------------------------------------------------------------

/// Fixed canonical-order scheduler: pops scheduled vertices in ascending
/// vertex id, wrapping around (the Chromatic engine's static order).
pub struct SweepScheduler {
    flagged: Vec<bool>,
    cursor: usize,
    live: usize,
}

impl SweepScheduler {
    /// Sweep over `num_vertices`.
    pub fn new(num_vertices: usize) -> Self {
        SweepScheduler {
            flagged: vec![false; num_vertices],
            cursor: 0,
            live: 0,
        }
    }
}

impl Scheduler for SweepScheduler {
    fn push(&mut self, task: Task) {
        let f = &mut self.flagged[task.vertex as usize];
        if !*f {
            *f = true;
            self.live += 1;
        }
    }

    fn pop(&mut self) -> Option<Task> {
        if self.live == 0 {
            return None;
        }
        let n = self.flagged.len();
        for _ in 0..n {
            let v = self.cursor;
            self.cursor = (self.cursor + 1) % n;
            if self.flagged[v] {
                self.flagged[v] = false;
                self.live -= 1;
                return Some(Task {
                    vertex: v as VertexId,
                    priority: 0.0,
                });
            }
        }
        None
    }

    fn len(&self) -> usize {
        self.live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: VertexId, p: f64) -> Task {
        Task {
            vertex: v,
            priority: p,
        }
    }

    #[test]
    fn fifo_order_and_dedup() {
        let mut s = FifoScheduler::new(10);
        s.push(t(3, 0.0));
        s.push(t(1, 0.0));
        s.push(t(3, 0.0)); // dup
        s.push(t(7, 0.0));
        assert_eq!(s.len(), 3);
        let order: Vec<VertexId> = std::iter::from_fn(|| s.pop()).map(|x| x.vertex).collect();
        assert_eq!(order, vec![3, 1, 7]);
    }

    #[test]
    fn inject_merges_like_push() {
        let mut s = PriorityScheduler::new(10);
        s.push(t(2, 1.0));
        s.inject(&[t(4, 5.0), t(2, 9.0), t(4, 3.0)]); // dup of 2, dup of 4
        assert_eq!(s.len(), 2);
        assert_eq!(s.pop().map(|x| (x.vertex, x.priority)), Some((2, 9.0)));
        assert_eq!(s.pop().map(|x| x.vertex), Some(4));
        assert!(s.pop().is_none());
    }

    #[test]
    fn priority_pops_in_descending_order() {
        let mut s = PriorityScheduler::new(10);
        for (v, p) in [(0, 1.0), (1, 5.0), (2, 3.0), (3, 4.0)] {
            s.push(t(v, p));
        }
        let ps: Vec<f64> = std::iter::from_fn(|| s.pop()).map(|x| x.priority).collect();
        assert_eq!(ps, vec![5.0, 4.0, 3.0, 1.0]);
    }

    #[test]
    fn priority_merge_keeps_max() {
        let mut s = PriorityScheduler::new(4);
        s.push(t(0, 2.0));
        s.push(t(0, 5.0)); // raise
        s.push(t(0, 1.0)); // ignored
        assert_eq!(s.len(), 1);
        let x = s.pop().unwrap();
        assert_eq!(x.priority, 5.0);
        assert!(s.pop().is_none());
    }

    #[test]
    fn sweep_wraps_in_id_order() {
        let mut s = SweepScheduler::new(5);
        s.push(t(4, 0.0));
        s.push(t(1, 0.0));
        assert_eq!(s.pop().unwrap().vertex, 1);
        // Cursor is now past 1; pushing 0 pops after wrap.
        s.push(t(0, 0.0));
        assert_eq!(s.pop().unwrap().vertex, 4);
        assert_eq!(s.pop().unwrap().vertex, 0);
        assert!(s.pop().is_none());
    }

    #[test]
    fn multiqueue_conserves_tasks_and_dedups() {
        let mut s = MultiQueueScheduler::new(100, 4, 7);
        for v in 0..50u32 {
            s.push(t(v, v as f64));
            s.push(t(v, v as f64 / 2.0)); // dup, lower
        }
        assert_eq!(s.len(), 50);
        let mut got: Vec<VertexId> = std::iter::from_fn(|| s.pop()).map(|x| x.vertex).collect();
        got.sort_unstable();
        assert_eq!(got, (0..50).collect::<Vec<_>>());
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn multiqueue_is_approximately_ordered() {
        // Not exact, but high-priority tasks should come out early on
        // average: check the mean rank of the top decile.
        let mut s = MultiQueueScheduler::new(1000, 4, 3);
        for v in 0..1000u32 {
            s.push(t(v, v as f64));
        }
        let order: Vec<f64> = std::iter::from_fn(|| s.pop()).map(|x| x.priority).collect();
        let top_decile_mean_rank: f64 = order
            .iter()
            .enumerate()
            .filter(|(_, &p)| p >= 900.0)
            .map(|(i, _)| i as f64)
            .sum::<f64>()
            / 100.0;
        assert!(
            top_decile_mean_rank < 400.0,
            "mean rank of top decile = {top_decile_mean_rank}"
        );
    }

    #[test]
    fn by_name_builds_all() {
        for name in ["fifo", "priority", "multiqueue", "sweep"] {
            let mut s = by_name(name, 10, 1).unwrap();
            s.push(t(5, 1.0));
            assert_eq!(s.pop().unwrap().vertex, 5);
        }
    }

    #[test]
    fn by_name_rejects_unknown_without_panicking() {
        assert!(by_name("lifo", 10, 1).is_err());
        assert!(Policy::parse("").is_err());
    }

    #[test]
    fn sched_spec_parses_both_modes() {
        let ws = SchedSpec::parse("multiqueue", 7).unwrap();
        assert_eq!(ws, SchedSpec::ws(Policy::MultiQueue, 7));
        assert_eq!(ws.name(), "multiqueue");
        let gl = SchedSpec::parse("global-priority", 7).unwrap();
        assert_eq!(gl, SchedSpec::global(Policy::Priority, 7));
        assert_eq!(gl.name(), "global-priority");
        assert!(SchedSpec::parse("global-lifo", 0).is_err());
    }
}
