//! # graphlab — a reproduction of distributed GraphLab (Low et al., 2011)
//!
//! This crate implements the GraphLab abstraction — data graph, update
//! functions, sync operations, and sequential-consistency models — together
//! with the paper's two distributed engines (Chromatic and Locking), the
//! distributed data-graph substrate (two-phase partitioning, ghosts,
//! versioned cache coherence, distributed locks, termination detection), a
//! discrete-event cluster simulator standing in for the paper's 64-node EC2
//! testbed, and the three evaluation applications (Netflix-ALS, CoSeg-LBP,
//! NER-CoEM) plus PageRank and Gibbs sampling.
//!
//! Numeric vertex-update hot spots are AOT-compiled from JAX/Pallas to HLO
//! text (`artifacts/*.hlo.txt`, built by `make artifacts`) and executed from
//! Rust through the PJRT CPU client (`runtime` module). Python never runs at
//! execution time.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod apps;
pub mod bench;
pub mod datagen;
pub mod distributed;
pub mod engine;
pub mod graph;
pub mod lab;
pub mod metrics;
pub mod partition;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod util;
pub mod wire;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
