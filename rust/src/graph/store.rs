//! Shared mutable storage for parallel engines.
//!
//! The GraphLab engines hand out overlapping scopes to worker threads and
//! enforce exclusion themselves (via coloring or locks, Sec. 3.5/4.2). Rust
//! cannot see those protocol-level guarantees, so the data vectors are held
//! in [`SharedStore`], an `UnsafeCell`-backed slice whose unsafe accessors
//! put the aliasing obligation on the engine.
//!
//! # Safety contract
//! A caller of [`SharedStore::get_mut`] must guarantee that no other thread
//! concurrently accesses the same index (readers included); a caller of
//! [`SharedStore::get`] must guarantee no concurrent writer to that index.
//! The Chromatic engine discharges this with a proper vertex coloring; the
//! Locking engine with reader-writer scope locks; both are property-tested
//! in `rust/tests/`.

use std::cell::UnsafeCell;

/// A fixed-length slice of `T` allowing engine-managed concurrent access.
pub struct SharedStore<T> {
    data: Vec<UnsafeCell<T>>,
}

// SAFETY: access discipline is delegated to the engines per the module
// contract above.
unsafe impl<T: Send> Sync for SharedStore<T> {}
unsafe impl<T: Send> Send for SharedStore<T> {}

impl<T> SharedStore<T> {
    /// Wrap a vector.
    pub fn new(data: Vec<T>) -> Self {
        SharedStore {
            data: data.into_iter().map(UnsafeCell::new).collect(),
        }
    }

    /// Length of the store.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the store is empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Shared access to element `i`.
    ///
    /// # Safety
    /// No concurrent mutable access to index `i` may exist.
    #[inline]
    #[allow(clippy::missing_safety_doc)]
    pub unsafe fn get(&self, i: usize) -> &T {
        &*self.data[i].get()
    }

    /// Exclusive access to element `i`.
    ///
    /// # Safety
    /// No concurrent access (shared or mutable) to index `i` may exist.
    #[inline]
    #[allow(clippy::mut_from_ref, clippy::missing_safety_doc)]
    pub unsafe fn get_mut(&self, i: usize) -> &mut T {
        &mut *self.data[i].get()
    }

    /// Consume into the underlying vector (single-threaded epilogue).
    pub fn into_vec(self) -> Vec<T> {
        self.data.into_iter().map(UnsafeCell::into_inner).collect()
    }

    /// Exclusive iteration when holding `&mut self` (no races possible).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.data.iter_mut().map(|c| unsafe { &mut *c.get() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ThreadPool;

    #[test]
    fn disjoint_parallel_writes_are_visible() {
        let n = 1000;
        let store = SharedStore::new(vec![0u64; n]);
        ThreadPool::new(8).parallel_for(n, 16, |i| {
            // SAFETY: each index is visited exactly once (threadpool test
            // proves this), so access is exclusive.
            unsafe { *store.get_mut(i) = i as u64 * 3 };
        });
        let v = store.into_vec();
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as u64 * 3));
    }

    #[test]
    fn roundtrip_preserves_order() {
        let store = SharedStore::new(vec![1, 2, 3]);
        assert_eq!(store.len(), 3);
        assert_eq!(store.into_vec(), vec![1, 2, 3]);
    }
}
