//! The GraphLab **data graph** (paper Sec. 3.1).
//!
//! `Graph<V, E>` stores arbitrary user data on the vertices and edges of an
//! undirected graph with a *static* structure (the paper fixes structure
//! during execution; mutation is limited to the data). Adjacency is CSR so
//! scope assembly in the engines is a contiguous scan.
//!
//! Directed edge data (e.g. PageRank link weights) is supported the way the
//! paper describes: each undirected edge carries one `E` which the
//! application partitions into its two directions (every app in `apps/`
//! that needs direction does this; see `apps::pagerank::PrEdge`).

pub mod store;

pub use store::SharedStore;

/// Vertex identifier (index into the data graph).
pub type VertexId = u32;
/// Edge identifier (index into the edge data).
pub type EdgeId = u32;

/// Mutable-data, static-structure undirected graph.
#[derive(Debug, Clone)]
pub struct Graph<V, E> {
    vertex_data: Vec<V>,
    edge_data: Vec<E>,
    endpoints: Vec<(VertexId, VertexId)>,
    adj_offsets: Vec<u32>,
    adj: Vec<(VertexId, EdgeId)>,
}

/// Incremental builder; `build()` freezes the structure into CSR form.
#[derive(Debug)]
pub struct GraphBuilder<V, E> {
    vertex_data: Vec<V>,
    edges: Vec<(VertexId, VertexId, E)>,
}

impl<V, E> Default for GraphBuilder<V, E> {
    fn default() -> Self {
        GraphBuilder {
            vertex_data: Vec::new(),
            edges: Vec::new(),
        }
    }
}

impl<V, E> GraphBuilder<V, E> {
    /// Empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder with preallocated capacity.
    pub fn with_capacity(vertices: usize, edges: usize) -> Self {
        GraphBuilder {
            vertex_data: Vec::with_capacity(vertices),
            edges: Vec::with_capacity(edges),
        }
    }

    /// Add a vertex carrying `data`; returns its id.
    pub fn add_vertex(&mut self, data: V) -> VertexId {
        self.vertex_data.push(data);
        (self.vertex_data.len() - 1) as VertexId
    }

    /// Add `n` vertices produced by `f(local_index)`.
    pub fn add_vertices(&mut self, n: usize, mut f: impl FnMut(usize) -> V) -> VertexId {
        let first = self.vertex_data.len() as VertexId;
        for i in 0..n {
            self.vertex_data.push(f(i));
        }
        first
    }

    /// Add an undirected edge `{u, v}` carrying `data`; returns its id.
    /// Self-loops and duplicate edges are rejected by debug assertion only
    /// (the paper's apps never produce them; checking duplicates globally
    /// would need a set per vertex).
    pub fn add_edge(&mut self, u: VertexId, v: VertexId, data: E) -> EdgeId {
        debug_assert!(u != v, "self loops are not part of the GraphLab model");
        debug_assert!((u as usize) < self.vertex_data.len());
        debug_assert!((v as usize) < self.vertex_data.len());
        self.edges.push((u, v, data));
        (self.edges.len() - 1) as EdgeId
    }

    /// Current vertex count.
    pub fn num_vertices(&self) -> usize {
        self.vertex_data.len()
    }

    /// Freeze into CSR form.
    pub fn build(self) -> Graph<V, E> {
        let n = self.vertex_data.len();
        let m = self.edges.len();
        let mut degrees = vec![0u32; n];
        for &(u, v, _) in &self.edges {
            degrees[u as usize] += 1;
            degrees[v as usize] += 1;
        }
        let mut adj_offsets = vec![0u32; n + 1];
        for i in 0..n {
            adj_offsets[i + 1] = adj_offsets[i] + degrees[i];
        }
        let mut adj: Vec<(VertexId, EdgeId)> = vec![(0, 0); 2 * m];
        let mut cursor: Vec<u32> = adj_offsets[..n].to_vec();
        let mut endpoints = Vec::with_capacity(m);
        let mut edge_data = Vec::with_capacity(m);
        for (eid, (u, v, data)) in self.edges.into_iter().enumerate() {
            let eid = eid as EdgeId;
            adj[cursor[u as usize] as usize] = (v, eid);
            cursor[u as usize] += 1;
            adj[cursor[v as usize] as usize] = (u, eid);
            cursor[v as usize] += 1;
            endpoints.push((u, v));
            edge_data.push(data);
        }
        Graph {
            vertex_data: self.vertex_data,
            edge_data,
            endpoints,
            adj_offsets,
            adj,
        }
    }
}

impl<V, E> Graph<V, E> {
    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.vertex_data.len()
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.edge_data.len()
    }

    /// Degree of `v`.
    pub fn degree(&self, v: VertexId) -> usize {
        let v = v as usize;
        (self.adj_offsets[v + 1] - self.adj_offsets[v]) as usize
    }

    /// Maximum degree over all vertices.
    pub fn max_degree(&self) -> usize {
        (0..self.num_vertices() as VertexId)
            .map(|v| self.degree(v))
            .max()
            .unwrap_or(0)
    }

    /// Neighbors of `v` as `(neighbor, edge_id)` pairs.
    pub fn neighbors(&self, v: VertexId) -> &[(VertexId, EdgeId)] {
        let v = v as usize;
        &self.adj[self.adj_offsets[v] as usize..self.adj_offsets[v + 1] as usize]
    }

    /// Vertex data (shared).
    pub fn vertex_data(&self, v: VertexId) -> &V {
        &self.vertex_data[v as usize]
    }

    /// Vertex data (exclusive).
    pub fn vertex_data_mut(&mut self, v: VertexId) -> &mut V {
        &mut self.vertex_data[v as usize]
    }

    /// Edge data (shared).
    pub fn edge_data(&self, e: EdgeId) -> &E {
        &self.edge_data[e as usize]
    }

    /// Edge data (exclusive).
    pub fn edge_data_mut(&mut self, e: EdgeId) -> &mut E {
        &mut self.edge_data[e as usize]
    }

    /// The two endpoints of edge `e` in insertion order.
    pub fn endpoints(&self, e: EdgeId) -> (VertexId, VertexId) {
        self.endpoints[e as usize]
    }

    /// Given one endpoint of `e`, return the other.
    pub fn other_end(&self, e: EdgeId, v: VertexId) -> VertexId {
        let (a, b) = self.endpoints[e as usize];
        if a == v {
            b
        } else {
            debug_assert_eq!(b, v);
            a
        }
    }

    /// Whether `u` and `v` are adjacent (linear scan of the smaller list).
    pub fn adjacent(&self, u: VertexId, v: VertexId) -> bool {
        let (a, b) = if self.degree(u) <= self.degree(v) {
            (u, v)
        } else {
            (v, u)
        };
        self.neighbors(a).iter().any(|&(w, _)| w == b)
    }

    /// Iterate all vertex ids.
    pub fn vertex_ids(&self) -> impl Iterator<Item = VertexId> {
        0..self.vertex_data.len() as VertexId
    }

    /// Take ownership of vertex and edge data, leaving structure intact is
    /// impossible; instead expose consuming decomposition for the
    /// distributed loader.
    pub fn into_parts(self) -> (Vec<V>, Vec<E>, GraphTopology) {
        (
            self.vertex_data,
            self.edge_data,
            GraphTopology {
                endpoints: self.endpoints,
                adj_offsets: self.adj_offsets,
                adj: self.adj,
            },
        )
    }

    /// Rebuild a graph from parts produced by [`Graph::into_parts`].
    pub fn from_parts(vertex_data: Vec<V>, edge_data: Vec<E>, topo: GraphTopology) -> Self {
        debug_assert_eq!(vertex_data.len() + 1, topo.adj_offsets.len());
        debug_assert_eq!(edge_data.len(), topo.endpoints.len());
        Graph {
            vertex_data,
            edge_data,
            endpoints: topo.endpoints,
            adj_offsets: topo.adj_offsets,
            adj: topo.adj,
        }
    }

    /// Borrow the structure alone.
    pub fn topology(&self) -> GraphTopologyRef<'_> {
        GraphTopologyRef {
            endpoints: &self.endpoints,
            adj_offsets: &self.adj_offsets,
            adj: &self.adj,
        }
    }
}

/// Owned structure of a graph without its data (distributed loader).
#[derive(Debug, Clone)]
pub struct GraphTopology {
    /// Edge endpoints by edge id.
    pub endpoints: Vec<(VertexId, VertexId)>,
    /// CSR offsets.
    pub adj_offsets: Vec<u32>,
    /// CSR neighbor list.
    pub adj: Vec<(VertexId, EdgeId)>,
}

/// Borrowed structure of a graph.
#[derive(Debug, Clone, Copy)]
pub struct GraphTopologyRef<'a> {
    /// Edge endpoints by edge id.
    pub endpoints: &'a [(VertexId, VertexId)],
    /// CSR offsets.
    pub adj_offsets: &'a [u32],
    /// CSR neighbor list.
    pub adj: &'a [(VertexId, EdgeId)],
}

impl GraphTopologyRef<'_> {
    /// Neighbors of `v`.
    pub fn neighbors(&self, v: VertexId) -> &[(VertexId, EdgeId)] {
        let v = v as usize;
        &self.adj[self.adj_offsets[v] as usize..self.adj_offsets[v + 1] as usize]
    }

    /// Degree of `v`.
    pub fn degree(&self, v: VertexId) -> usize {
        let v = v as usize;
        (self.adj_offsets[v + 1] - self.adj_offsets[v]) as usize
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.adj_offsets.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> Graph<u32, u32> {
        let mut b = GraphBuilder::new();
        b.add_vertices(n, |i| i as u32);
        for i in 0..n - 1 {
            b.add_edge(i as VertexId, (i + 1) as VertexId, 100 + i as u32);
        }
        b.build()
    }

    #[test]
    fn path_structure() {
        let g = path_graph(5);
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.max_degree(), 2);
        let n2: Vec<VertexId> = g.neighbors(2).iter().map(|&(v, _)| v).collect();
        assert_eq!(n2, vec![1, 3]);
        assert!(g.adjacent(1, 2));
        assert!(!g.adjacent(0, 2));
    }

    #[test]
    fn edge_data_roundtrip() {
        let mut g = path_graph(4);
        let (_, eid) = g.neighbors(1)[1]; // edge 1-2
        assert_eq!(*g.edge_data(eid), 101);
        *g.edge_data_mut(eid) = 999;
        assert_eq!(*g.edge_data(eid), 999);
        assert_eq!(g.other_end(eid, 1), 2);
        assert_eq!(g.other_end(eid, 2), 1);
    }

    #[test]
    fn adjacency_is_symmetric() {
        let mut b = GraphBuilder::new();
        b.add_vertices(10, |_| 0u8);
        b.add_edge(0, 5, ());
        b.add_edge(5, 9, ());
        b.add_edge(0, 9, ());
        let g = b.build();
        for v in g.vertex_ids() {
            for &(u, e) in g.neighbors(v) {
                assert!(g.neighbors(u).iter().any(|&(w, e2)| w == v && e2 == e));
            }
        }
    }

    #[test]
    fn star_degrees() {
        let mut b = GraphBuilder::new();
        let hub = b.add_vertex(0u8);
        for _ in 0..20 {
            let v = b.add_vertex(0u8);
            b.add_edge(hub, v, ());
        }
        let g = b.build();
        assert_eq!(g.degree(hub), 20);
        assert_eq!(g.max_degree(), 20);
        for v in 1..=20 {
            assert_eq!(g.degree(v), 1);
        }
    }
}
