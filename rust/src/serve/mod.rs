//! `graphlab serve`: a long-lived serving cluster with streaming
//! mutations and incremental recomputation (ROADMAP's serving north
//! star; DESIGN.md §Serving).
//!
//! The batch engines converge once and exit; this subsystem keeps the
//! cluster resident afterwards. Clients stream **queries** (read a
//! vertex's rank, routed to its owner) and **mutations** (add/remove an
//! edge, reweight, touch a vertex); each mutation batch schedules
//! exactly the dirtied neighborhood and dynamic eps-gated propagation
//! re-converges only what actually moved — the paper's §3.2 argument
//! for prioritized dynamic scheduling, kept warm between requests.
//!
//! * [`msg`] — the wire grammar: client RPCs ([`ServeReq`]/[`ServeReply`])
//!   and the machine-mesh protocol ([`PeerMsg`]).
//! * [`engine`] — resident machine loops, the frontend coordinator, the
//!   in-proc [`ServeSession`] harness, and the per-process
//!   [`engine::serve_machine`] entry point.
//! * [`client`] — the frontend's TCP listener and the [`ServeClient`]
//!   connector (`graphlab client`).
//! * [`bench`] — the `bench-serve` driver (lab preset `serve`).

pub mod bench;
pub mod client;
pub mod engine;
pub mod msg;

pub use client::{ServeClient, CLIENT_TAG};
pub use engine::{ServeOpts, ServeSession, FRONTEND};
pub use msg::{Mutation, PeerMsg, ServeReply, ServeReq, ServeStats};
