//! The socket boundary of serving mode: the frontend's client listener
//! and the matching [`ServeClient`] connector (`graphlab client`).
//!
//! Clients speak the same handshake as the worker mesh — magic, wire
//! version, tag, and the PR-8 **role byte** ([`ROLE_CLIENT`]) — so a
//! client dialing a worker port (or a worker dialing the client port)
//! gets an explicit reject reason instead of undefined framing. After
//! the one-byte ack, the connection carries `[u32 len][ServeReq]` frames
//! up and `[u32 len][ServeReply]` frames down ([`crate::wire`] codec).
//!
//! Totality at the boundary: a well-framed payload that fails to decode
//! is answered with a typed [`ServeReply::Error`] and the connection
//! stays open; a broken frame (oversized length, short read) closes the
//! connection after a best-effort error reply. Nothing a client sends
//! can panic the cluster.

use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use crate::distributed::transport::{
    read_ack, read_handshake, read_reject_reason, write_handshake, ROLE_CLIENT,
};
use crate::graph::VertexId;
use crate::wire::{self, Wire, WIRE_VERSION};

use super::engine::ClientCmd;
use super::msg::{ErrorKind, Mutation, ServeReply, ServeReq, ServeStats};

/// The serve handshake's app-type tag (a new tag, so batch-engine
/// workers and serve clients can never cross-connect silently).
pub const CLIENT_TAG: &str = "graphlab-serve/pagerank";

/// Client frames above this are treated as hostile and close the
/// connection (a mutation batch of ~1M edges fits comfortably).
pub const MAX_CLIENT_FRAME: u32 = 16 << 20;

/// How long one queued request may wait on the frontend.
const REPLY_TIMEOUT: Duration = Duration::from_secs(120);

/// Bind the frontend's client listener and accept forever, spawning one
/// handler thread per connection; every decoded request lands on `feed`
/// (the same queue the in-proc harness writes). Returns the bound
/// address (so `--listen 127.0.0.1:0` works) and the acceptor handle.
pub fn spawn_listener(
    addr: &str,
    feed: mpsc::Sender<ClientCmd>,
) -> Result<(SocketAddr, std::thread::JoinHandle<()>)> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("serve listener bind {addr}"))?;
    let local = listener.local_addr()?;
    let handle = std::thread::Builder::new()
        .name("serve-accept".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(stream) = conn else { continue };
                let feed = feed.clone();
                let _ = std::thread::Builder::new()
                    .name("serve-client".to_string())
                    .spawn(move || handle_connection(stream, feed));
            }
        })?;
    Ok((local, handle))
}

/// Validate one client handshake, then pump request frames until the
/// client hangs up (or sends something unframeable).
fn handle_connection(mut stream: TcpStream, feed: mpsc::Sender<ClientCmd>) {
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    let Ok(hs) = read_handshake(&mut stream) else {
        return; // garbage greeting: drop
    };
    let reject = if hs.wire_version != WIRE_VERSION {
        Some(format!(
            "wire version {} != this build's {WIRE_VERSION}",
            hs.wire_version
        ))
    } else if hs.role != ROLE_CLIENT {
        Some("worker-role connection on the client port (dial the mesh instead)".to_string())
    } else if hs.tag != CLIENT_TAG {
        Some(format!("client tag {:?} != expected {CLIENT_TAG:?}", hs.tag))
    } else {
        None
    };
    if let Some(reason) = reject {
        let mut buf = Vec::with_capacity(reason.len() + 8);
        buf.push(0u8);
        reason.encode(&mut buf);
        let _ = stream.write_all(&buf);
        return;
    }
    if stream.write_all(&[1u8]).is_err() {
        return;
    }
    stream.set_read_timeout(None).ok();
    stream.set_nodelay(true).ok();
    loop {
        let mut len4 = [0u8; 4];
        if stream.read_exact(&mut len4).is_err() {
            return; // client hung up
        }
        let len = u32::from_le_bytes(len4);
        if len == 0 || len > MAX_CLIENT_FRAME {
            let _ = write_frame(
                &mut stream,
                &ServeReply::Error {
                    kind: ErrorKind::BadRequest,
                    detail: format!("frame length {len} out of range"),
                },
            );
            return; // framing is lost: close
        }
        let mut buf = vec![0u8; len as usize];
        if stream.read_exact(&mut buf).is_err() {
            return;
        }
        let req: ServeReq = match wire::from_bytes(&buf) {
            Ok(req) => req,
            Err(e) => {
                // Well-framed garbage: typed refusal, connection lives.
                if write_frame(
                    &mut stream,
                    &ServeReply::Error {
                        kind: ErrorKind::BadRequest,
                        detail: format!("request failed to decode: {e}"),
                    },
                )
                .is_err()
                {
                    return;
                }
                continue;
            }
        };
        let closing = matches!(req, ServeReq::Shutdown);
        let (tx, rx) = mpsc::channel();
        let reply = if feed.send(ClientCmd { req, reply: tx }).is_ok() {
            rx.recv_timeout(REPLY_TIMEOUT).unwrap_or(ServeReply::Error {
                kind: ErrorKind::BadRequest,
                detail: "cluster did not answer (shutting down?)".to_string(),
            })
        } else {
            ServeReply::Error {
                kind: ErrorKind::BadRequest,
                detail: "cluster is down".to_string(),
            }
        };
        if write_frame(&mut stream, &reply).is_err() || closing {
            return;
        }
    }
}

fn write_frame<W: Wire>(stream: &mut TcpStream, msg: &W) -> std::io::Result<()> {
    let body = wire::to_bytes(msg);
    let mut frame = Vec::with_capacity(body.len() + 4);
    (body.len() as u32).encode(&mut frame);
    frame.extend_from_slice(&body);
    stream.write_all(&frame)
}

fn read_frame<W: Wire>(stream: &mut TcpStream, max: u32) -> Result<W> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4).context("reading reply frame")?;
    let len = u32::from_le_bytes(len4);
    if len == 0 || len > max {
        bail!("reply frame length {len} out of range");
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf).context("reading reply frame")?;
    wire::from_bytes(&buf).context("decoding reply frame")
}

/// A blocking TCP client for a serving frontend — the transport behind
/// `graphlab client` and the multi-process serve smoke test.
pub struct ServeClient {
    stream: TcpStream,
}

impl ServeClient {
    /// Dial `addr`, handshake with [`ROLE_CLIENT`], and fail with the
    /// frontend's reject reason if refused.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let mut stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to frontend {addr}"))?;
        write_handshake(&mut stream, 0, 0, WIRE_VERSION, CLIENT_TAG, ROLE_CLIENT)
            .context("sending client handshake")?;
        stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
        let accepted = read_ack(&mut stream).context("frontend closed during handshake")?;
        if !accepted {
            let why = read_reject_reason(&mut stream)
                .unwrap_or_else(|| "(no reason sent)".to_string());
            bail!("frontend {addr} rejected the connection: {why}");
        }
        stream.set_read_timeout(Some(REPLY_TIMEOUT)).ok();
        stream.set_nodelay(true).ok();
        Ok(ServeClient { stream })
    }

    /// Send one request and block for the reply.
    pub fn request(&mut self, req: &ServeReq) -> Result<ServeReply> {
        write_frame(&mut self.stream, req).context("sending request")?;
        read_frame(&mut self.stream, MAX_CLIENT_FRAME)
    }

    /// Read one vertex's rank (with its staleness tag).
    pub fn query(&mut self, vertex: VertexId) -> Result<ServeReply> {
        self.request(&ServeReq::Query { vertex })
    }

    /// Apply a mutation batch; blocks until the epoch re-converges.
    pub fn mutate(&mut self, muts: Vec<Mutation>) -> Result<ServeReply> {
        self.request(&ServeReq::Mutate { muts })
    }

    /// Serving counters.
    pub fn stats(&mut self) -> Result<ServeStats> {
        match self.request(&ServeReq::Stats)? {
            ServeReply::Stats(s) => Ok(s),
            other => bail!("stats request answered with {other:?}"),
        }
    }

    /// Ask the cluster to stop.
    pub fn shutdown(&mut self) -> Result<ServeReply> {
        self.request(&ServeReq::Shutdown)
    }
}
