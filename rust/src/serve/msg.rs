//! The serving-mode wire grammar (DESIGN.md §Serving).
//!
//! Two independent message families share the PR-4 `Wire` codec:
//!
//! * **Client RPC** — [`ServeReq`] / [`ServeReply`], framed as
//!   `[u32 len][payload]` on a dedicated frontend listener socket (or
//!   passed directly through the in-proc harness). Decoding is *total*:
//!   any malformed frame becomes a typed [`crate::wire::WireError`],
//!   which the frontend answers with [`ServeReply::Error`] — a hostile
//!   client can never panic the cluster.
//! * **Mesh protocol** — [`PeerMsg`], carried by the ordinary
//!   [`crate::distributed::Endpoint`] full mesh between the serving
//!   machines (same substrate the batch engines use, so the handshake's
//!   tag/version/role validation applies unchanged).
//!
//! Every enum encodes as one discriminant byte followed by the variant's
//! fields in declaration order, the repo-wide convention.

use crate::graph::VertexId;
use crate::scheduler::Task;
use crate::wire::{self, Wire, WireError};

/// A client-requested graph mutation. Vertex ids are global; the vertex
/// set itself is fixed at load time (mutations rewire and reweight the
/// topology, they do not grow it — the atom placement stays valid).
#[derive(Debug, Clone, PartialEq)]
pub enum Mutation {
    /// Insert an undirected edge `u — v` carrying weight `w` in *both*
    /// directions. Serving-mode weights are raw (no degree
    /// renormalization happens on mutation — see DESIGN.md §Serving).
    AddEdge { u: VertexId, v: VertexId, w: f32 },
    /// Remove the first edge `u — v` (no-op if absent).
    RemoveEdge { u: VertexId, v: VertexId },
    /// Set both directed weights of edge `u — v` to `w` (no-op if
    /// absent).
    SetEdgeWeight { u: VertexId, v: VertexId, w: f32 },
    /// Mark `v` dirty without changing the topology (forces its rank to
    /// be recomputed — the "touch vertex data" RPC).
    TouchVertex { v: VertexId },
}

impl Mutation {
    /// The endpoints this mutation dirties, in `(u, v)` order
    /// (`TouchVertex` has a single endpoint).
    pub fn endpoints(&self) -> (VertexId, Option<VertexId>) {
        match *self {
            Mutation::AddEdge { u, v, .. }
            | Mutation::RemoveEdge { u, v }
            | Mutation::SetEdgeWeight { u, v, .. } => (u, Some(v)),
            Mutation::TouchVertex { v } => (v, None),
        }
    }
}

impl Wire for Mutation {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Mutation::AddEdge { u, v, w } => {
                out.push(0);
                u.encode(out);
                v.encode(out);
                w.encode(out);
            }
            Mutation::RemoveEdge { u, v } => {
                out.push(1);
                u.encode(out);
                v.encode(out);
            }
            Mutation::SetEdgeWeight { u, v, w } => {
                out.push(2);
                u.encode(out);
                v.encode(out);
                w.encode(out);
            }
            Mutation::TouchVertex { v } => {
                out.push(3);
                v.encode(out);
            }
        }
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => Mutation::AddEdge {
                u: VertexId::decode(input)?,
                v: VertexId::decode(input)?,
                w: f32::decode(input)?,
            },
            1 => Mutation::RemoveEdge {
                u: VertexId::decode(input)?,
                v: VertexId::decode(input)?,
            },
            2 => Mutation::SetEdgeWeight {
                u: VertexId::decode(input)?,
                v: VertexId::decode(input)?,
                w: f32::decode(input)?,
            },
            3 => Mutation::TouchVertex {
                v: VertexId::decode(input)?,
            },
            tag => {
                return Err(WireError::BadTag {
                    what: "Mutation",
                    tag,
                })
            }
        })
    }
}

/// A client request to the serving frontend.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeReq {
    /// Read one vertex's current rank (answered from possibly
    /// still-converging state; the reply carries the staleness tag).
    Query { vertex: VertexId },
    /// Apply a batch of mutations as one epoch and re-converge the
    /// dirtied neighborhood. The reply reports the epoch's work.
    Mutate { muts: Vec<Mutation> },
    /// Read the cluster's serving counters.
    Stats,
    /// Stop the cluster (frontend broadcasts `Stop` to every machine).
    Shutdown,
}

impl Wire for ServeReq {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ServeReq::Query { vertex } => {
                out.push(0);
                vertex.encode(out);
            }
            ServeReq::Mutate { muts } => {
                out.push(1);
                muts.encode(out);
            }
            ServeReq::Stats => out.push(2),
            ServeReq::Shutdown => out.push(3),
        }
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => ServeReq::Query {
                vertex: VertexId::decode(input)?,
            },
            1 => ServeReq::Mutate {
                muts: Vec::<Mutation>::decode(input)?,
            },
            2 => ServeReq::Stats,
            3 => ServeReq::Shutdown,
            tag => {
                return Err(WireError::BadTag {
                    what: "ServeReq",
                    tag,
                })
            }
        })
    }
}

/// Why a request was refused (always a reply, never a panic or a dropped
/// connection).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// A vertex id outside `0..n`.
    UnknownVertex,
    /// The request frame failed to decode (or was semantically invalid,
    /// e.g. a self-loop mutation).
    BadRequest,
}

impl Wire for ErrorKind {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(match self {
            ErrorKind::UnknownVertex => 0,
            ErrorKind::BadRequest => 1,
        });
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => ErrorKind::UnknownVertex,
            1 => ErrorKind::BadRequest,
            tag => {
                return Err(WireError::BadTag {
                    what: "ErrorKind",
                    tag,
                })
            }
        })
    }
}

/// Serving counters, readable any time via [`ServeReq::Stats`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// Completed epochs (epoch 0 is the initial convergence).
    pub epoch: u64,
    /// Whether the last epoch has fully converged (quiescent cluster).
    pub converged: bool,
    /// Updates executed by the initial convergence (epoch 0).
    pub initial_updates: u64,
    /// Updates executed by the most recent epoch.
    pub epoch_updates: u64,
    /// Updates executed since the cluster started, all epochs.
    pub total_updates: u64,
    /// Global vertex count (fixed for the session's lifetime).
    pub vertices: u64,
    /// Live global edge count (initial edges + adds − removes).
    pub edges: u64,
    /// Cluster size.
    pub machines: u32,
}

impl Wire for ServeStats {
    fn encode(&self, out: &mut Vec<u8>) {
        self.epoch.encode(out);
        self.converged.encode(out);
        self.initial_updates.encode(out);
        self.epoch_updates.encode(out);
        self.total_updates.encode(out);
        self.vertices.encode(out);
        self.edges.encode(out);
        self.machines.encode(out);
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(ServeStats {
            epoch: u64::decode(input)?,
            converged: bool::decode(input)?,
            initial_updates: u64::decode(input)?,
            epoch_updates: u64::decode(input)?,
            total_updates: u64::decode(input)?,
            vertices: u64::decode(input)?,
            edges: u64::decode(input)?,
            machines: u32::decode(input)?,
        })
    }
}

/// The frontend's reply to one [`ServeReq`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServeReply {
    /// Query answer. `epoch`/`converged` are the staleness tag: the
    /// value is exact when `converged`, otherwise it is the owning
    /// machine's in-flight estimate during epoch `epoch`.
    Value {
        vertex: VertexId,
        rank: f32,
        epoch: u64,
        converged: bool,
    },
    /// Mutation batch applied and re-converged: `updates` vertex-update
    /// executions over `steps` supersteps (the incremental-recomputation
    /// cost of the batch), `scheduled` initially-dirtied vertices.
    MutAck {
        epoch: u64,
        scheduled: u64,
        updates: u64,
        steps: u64,
    },
    /// Stats snapshot.
    Stats(ServeStats),
    /// Acknowledges shutdown; the cluster is draining.
    Bye,
    /// Typed refusal (unknown vertex, malformed frame, …).
    Error { kind: ErrorKind, detail: String },
}

impl Wire for ServeReply {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ServeReply::Value {
                vertex,
                rank,
                epoch,
                converged,
            } => {
                out.push(0);
                vertex.encode(out);
                rank.encode(out);
                epoch.encode(out);
                converged.encode(out);
            }
            ServeReply::MutAck {
                epoch,
                scheduled,
                updates,
                steps,
            } => {
                out.push(1);
                epoch.encode(out);
                scheduled.encode(out);
                updates.encode(out);
                steps.encode(out);
            }
            ServeReply::Stats(s) => {
                out.push(2);
                s.encode(out);
            }
            ServeReply::Bye => out.push(3),
            ServeReply::Error { kind, detail } => {
                out.push(4);
                kind.encode(out);
                detail.encode(out);
            }
        }
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => ServeReply::Value {
                vertex: VertexId::decode(input)?,
                rank: f32::decode(input)?,
                epoch: u64::decode(input)?,
                converged: bool::decode(input)?,
            },
            1 => ServeReply::MutAck {
                epoch: u64::decode(input)?,
                scheduled: u64::decode(input)?,
                updates: u64::decode(input)?,
                steps: u64::decode(input)?,
            },
            2 => ServeReply::Stats(ServeStats::decode(input)?),
            3 => ServeReply::Bye,
            4 => ServeReply::Error {
                kind: ErrorKind::decode(input)?,
                detail: String::decode(input)?,
            },
            tag => {
                return Err(WireError::BadTag {
                    what: "ServeReply",
                    tag,
                })
            }
        })
    }
}

/// One mutation annotated by the frontend with the routing facts every
/// machine needs but only the frontend (which holds the atom-store
/// partition) computes: the owner machines of both endpoints. Workers
/// apply the broadcast batch filtered to what is locally relevant, so
/// they never need the global ownership map.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutedMutation {
    pub m: Mutation,
    /// Owner machine of endpoint `u` (== owner of `v` for TouchVertex).
    pub owner_u: u32,
    /// Owner machine of endpoint `v` (== `owner_u` for TouchVertex).
    pub owner_v: u32,
}

impl Wire for RoutedMutation {
    fn encode(&self, out: &mut Vec<u8>) {
        self.m.encode(out);
        self.owner_u.encode(out);
        self.owner_v.encode(out);
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(RoutedMutation {
            m: Mutation::decode(input)?,
            owner_u: u32::decode(input)?,
            owner_v: u32::decode(input)?,
        })
    }
}

/// The mesh protocol between serving machines (frontend = machine 0).
#[derive(Debug, Clone, PartialEq)]
pub enum PeerMsg {
    /// Frontend → all (including itself): start epoch `epoch` by
    /// applying `muts`. An empty batch with `epoch == 0` means "schedule
    /// every owned vertex" — the initial convergence.
    Apply { epoch: u64, muts: Vec<RoutedMutation> },
    /// Ghost coherence + remote task injection: `(vertex, version,
    /// rank)` triples for vertices the receiver ghosts, plus tasks for
    /// vertices the receiver owns (scheduled via the external-injection
    /// path, `Scheduler::inject`).
    Ghost {
        verts: Vec<(VertexId, u64, f32)>,
        tasks: Vec<Task>,
    },
    /// Superstep barrier marker: the sender has flushed everything it
    /// will send for barrier `step` (FIFO ordering makes this a fence).
    StepEnd { step: u64 },
    /// Worker → frontend at each barrier: local scheduler backlog and
    /// updates executed this superstep.
    Report {
        step: u64,
        pending: u64,
        updates: u64,
    },
    /// Frontend → all: continue (`cont`) or end the epoch (quiescent).
    Decision { step: u64, cont: bool },
    /// Frontend → owner: answer a client query for `vertex`.
    Query { id: u64, vertex: VertexId },
    /// Owner → frontend: the query answer.
    Answer {
        id: u64,
        vertex: VertexId,
        rank: f32,
        version: u64,
    },
    /// Frontend → all: drain and exit the serving loop.
    Stop,
}

impl Wire for PeerMsg {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            PeerMsg::Apply { epoch, muts } => {
                out.push(0);
                epoch.encode(out);
                muts.encode(out);
            }
            PeerMsg::Ghost { verts, tasks } => {
                out.push(1);
                verts.encode(out);
                tasks.encode(out);
            }
            PeerMsg::StepEnd { step } => {
                out.push(2);
                step.encode(out);
            }
            PeerMsg::Report {
                step,
                pending,
                updates,
            } => {
                out.push(3);
                step.encode(out);
                pending.encode(out);
                updates.encode(out);
            }
            PeerMsg::Decision { step, cont } => {
                out.push(4);
                step.encode(out);
                cont.encode(out);
            }
            PeerMsg::Query { id, vertex } => {
                out.push(5);
                id.encode(out);
                vertex.encode(out);
            }
            PeerMsg::Answer {
                id,
                vertex,
                rank,
                version,
            } => {
                out.push(6);
                id.encode(out);
                vertex.encode(out);
                rank.encode(out);
                version.encode(out);
            }
            PeerMsg::Stop => out.push(7),
        }
    }

    fn decode(input: &mut &[u8]) -> wire::Result<Self> {
        Ok(match u8::decode(input)? {
            0 => PeerMsg::Apply {
                epoch: u64::decode(input)?,
                muts: Vec::<RoutedMutation>::decode(input)?,
            },
            1 => PeerMsg::Ghost {
                verts: Vec::<(VertexId, u64, f32)>::decode(input)?,
                tasks: Vec::<Task>::decode(input)?,
            },
            2 => PeerMsg::StepEnd {
                step: u64::decode(input)?,
            },
            3 => PeerMsg::Report {
                step: u64::decode(input)?,
                pending: u64::decode(input)?,
                updates: u64::decode(input)?,
            },
            4 => PeerMsg::Decision {
                step: u64::decode(input)?,
                cont: bool::decode(input)?,
            },
            5 => PeerMsg::Query {
                id: u64::decode(input)?,
                vertex: VertexId::decode(input)?,
            },
            6 => PeerMsg::Answer {
                id: u64::decode(input)?,
                vertex: VertexId::decode(input)?,
                rank: f32::decode(input)?,
                version: u64::decode(input)?,
            },
            7 => PeerMsg::Stop,
            tag => {
                return Err(WireError::BadTag {
                    what: "PeerMsg",
                    tag,
                })
            }
        })
    }
}
