//! `bench-serve`: sustained mutation throughput + query latency against
//! a live in-proc serving cluster (ROADMAP's serving north star; the
//! lab's `serve` preset runs this via `configs/serve.json`).
//!
//! The driver converges a synthetic web graph, then alternates timed
//! mutation batches (each one full epoch of incremental re-convergence)
//! with timed point queries, and emits one `lab-metric` line carrying
//! `mutations_per_sec`, `query_p50_us`/`query_p99_us`, and the
//! incremental-vs-initial update counts (`incr_frac` is the fraction of
//! initial-convergence work an average epoch re-does — the paper's
//! dynamic-scheduling claim, §3.2, measured live).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::apps::pagerank;
use crate::distributed::TransportKind;
use crate::graph::VertexId;
use crate::partition;
use crate::util::Rng;

use super::engine::{ServeOpts, ServeSession};
use super::msg::{Mutation, ServeReply};

/// Bench shape. `mutrate` is mutations per batch (one batch = one
/// epoch); `batches` epochs and `queries` timed point reads follow the
/// initial convergence.
pub struct BenchOpts {
    pub n: usize,
    pub avg_degree: usize,
    pub machines: usize,
    pub transport: TransportKind,
    pub mutrate: usize,
    pub batches: usize,
    pub queries: usize,
    pub eps: f32,
    pub seed: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            n: 20_000,
            avg_degree: 8,
            machines: 2,
            transport: TransportKind::InProc,
            mutrate: 64,
            batches: 8,
            queries: 200,
            eps: 1e-7,
            seed: 1,
        }
    }
}

/// Deterministic mutation mix over a live edge list: reweights favored,
/// then adds, removes, touches. The list tracks adds/removes so later
/// picks stay mostly valid.
fn next_mutation(rng: &mut Rng, n: usize, edges: &mut Vec<(u32, u32)>, base_w: f32) -> Mutation {
    let roll = rng.gen_range(100);
    if roll < 40 && !edges.is_empty() {
        let (u, v) = edges[rng.gen_range(edges.len())];
        Mutation::SetEdgeWeight { u, v, w: base_w * rng.uniform(0.5, 1.5) }
    } else if roll < 65 {
        let u = rng.gen_range(n) as VertexId;
        let mut v = rng.gen_range(n) as VertexId;
        while v == u {
            v = rng.gen_range(n) as VertexId;
        }
        edges.push((u, v));
        Mutation::AddEdge { u, v, w: base_w * rng.uniform(0.5, 1.5) }
    } else if roll < 85 && !edges.is_empty() {
        let (u, v) = edges.swap_remove(rng.gen_range(edges.len()));
        Mutation::RemoveEdge { u, v }
    } else {
        Mutation::TouchVertex { v: rng.gen_range(n) as VertexId }
    }
}

/// Run the bench and return the `lab-metric` line (the caller prints
/// it — `graphlab bench-serve` to stdout, the lab's in-proc executor
/// into its synthesized child output).
pub fn run_bench(o: &BenchOpts) -> Result<String> {
    anyhow::ensure!(o.n >= 2, "bench-serve needs at least 2 vertices");
    anyhow::ensure!(
        o.eps > 0.0,
        "bench-serve needs eps > 0 (serving convergence is eps-driven; eps=0 never quiesces)"
    );
    let mut rng = Rng::new(o.seed ^ 0x5e7e);
    let mut edges = crate::datagen::web_graph(o.n, o.avg_degree, o.seed);
    let g = pagerank::build(o.n, &edges, 0.15);
    let part = partition::atoms::two_phase(&g, (o.machines * 8).max(16), o.machines, o.seed);
    let opts = ServeOpts {
        machines: o.machines,
        eps: o.eps,
        seed: o.seed,
        transport: o.transport,
        ..ServeOpts::default()
    };
    let session = ServeSession::start(g, &part, &opts)?;
    let initial = session.wait_converged()?;

    // Timed mutation batches: each is one epoch of incremental
    // re-convergence (the MutAck blocks until quiescence).
    let base_w = (1.0 - 0.15) / o.avg_degree.max(1) as f32;
    let mut incr_updates = 0u64;
    let mut epochs = 0u64;
    let total_muts = (o.batches * o.mutrate) as u64;
    let t0 = Instant::now();
    for _ in 0..o.batches {
        let muts: Vec<Mutation> = (0..o.mutrate)
            .map(|_| next_mutation(&mut rng, o.n, &mut edges, base_w))
            .collect();
        match session.mutate(muts)? {
            ServeReply::MutAck { updates, .. } => {
                incr_updates += updates;
                epochs += 1;
            }
            other => bail!("mutation batch answered with {other:?}"),
        }
    }
    let mut_secs = t0.elapsed().as_secs_f64();

    // Timed point queries against the quiescent cluster.
    let mut lat_us: Vec<f64> = Vec::with_capacity(o.queries);
    for _ in 0..o.queries {
        let v = rng.gen_range(o.n) as VertexId;
        let tq = Instant::now();
        match session.query(v)? {
            ServeReply::Value { .. } => {}
            other => bail!("query answered with {other:?}"),
        }
        lat_us.push(tq.elapsed().as_secs_f64() * 1e6);
    }
    session.shutdown()?;

    lat_us.sort_by(|a, b| a.total_cmp(b));
    let pick = |q: f64| -> f64 {
        if lat_us.is_empty() {
            return 0.0;
        }
        lat_us[((lat_us.len() - 1) as f64 * q).round() as usize]
    };
    let incr_per_epoch = incr_updates as f64 / epochs.max(1) as f64;
    let incr_frac = incr_per_epoch / initial.initial_updates.max(1) as f64;
    Ok(format!(
        "lab-metric app=serve machines={} transport={} n={} mutrate={} batches={} \
         mutations={} seconds={:.6} mutations_per_sec={:.1} \
         query_p50_us={:.1} query_p99_us={:.1} \
         initial_updates={} incr_updates={} incr_frac={:.4} updates={} sweeps={}",
        o.machines,
        o.transport.name(),
        o.n,
        o.mutrate,
        o.batches,
        total_muts,
        mut_secs,
        total_muts as f64 / mut_secs.max(1e-9),
        pick(0.50),
        pick(0.99),
        initial.initial_updates,
        incr_updates,
        incr_frac,
        initial.initial_updates + incr_updates,
        epochs + 1,
    ))
}
