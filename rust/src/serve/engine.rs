//! The resident serving engine: machine event loops, the frontend
//! coordinator, and the deterministic in-proc client harness.
//!
//! Topology (DESIGN.md §Serving): all machines form the usual full mesh
//! of [`Endpoint`]s carrying [`PeerMsg`]; machine 0 (the **frontend**)
//! additionally owns a client command channel fed by the in-proc harness
//! ([`ServeSession`]) and/or the TCP client listener
//! ([`super::client::spawn_listener`]). Because the frontend derives the
//! full vertex→machine map from the same partition every machine loaded,
//! it can route queries to owners and annotate mutations with owner ids
//! ([`RoutedMutation`]) before broadcasting them — workers never need
//! global state.
//!
//! Each epoch is one mutation batch re-converged by superstep rounds:
//!
//! 1. **Apply barrier** — every machine applies the locally-relevant
//!    mutations, exchanges ghost fills for newly cross-partition edges,
//!    and schedules exactly the dirtied endpoints it owns (the
//!    incremental-recomputation core: nothing else is queued).
//! 2. **Update supersteps** — drain the scheduler, recompute ranks
//!    (Jacobi: `R(v) = α/n + Σ w_in·R(u)`), push changed values to ghost
//!    mirrors, and reschedule neighbors whose inputs moved by more than
//!    `eps` (locally, or by remote task injection through
//!    [`crate::scheduler::Scheduler::inject`]).
//! 3. **Barriers** — after flushing, each machine fences the round with
//!    `StepEnd` to every peer (FIFO channels make the marker a fence),
//!    then reports its backlog to the frontend; the frontend ends the
//!    epoch when the cluster-wide backlog hits zero.
//!
//! Epoch 0 is the initial convergence (an empty batch that schedules
//! every owned vertex). Queries are answered at any time from the
//! owner's current value — the reply's `epoch`/`converged` pair is the
//! staleness tag.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use crate::apps::pagerank::{PrEdge, PrVertex};
use crate::distributed::transport::ClusterConfig;
use crate::distributed::{cluster_setup, Endpoint, LocalGraph, NetworkModel, TransportKind};
use crate::graph::{Graph, VertexId};
use crate::partition::atoms::AtomPlacement;
use crate::partition::{MachineId, Partition};
use crate::scheduler::{by_name, Scheduler, Task};

use super::msg::{
    ErrorKind, Mutation, PeerMsg, RoutedMutation, ServeReply, ServeReq, ServeStats,
};

/// The frontend machine's id (also the cluster leader for barriers).
pub const FRONTEND: MachineId = 0;

/// How long a harness request may wait for the cluster before the
/// harness declares it wedged.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(120);

/// Serving-cluster options.
#[derive(Clone)]
pub struct ServeOpts {
    /// Cluster size.
    pub machines: usize,
    /// PageRank damping (must match the weights the graph was built
    /// with — `pagerank::build` uses 0.15).
    pub alpha: f32,
    /// Reschedule threshold: a rank change ≤ eps stops propagating.
    pub eps: f32,
    /// Scheduler policy for the per-machine task queues.
    pub scheduler: String,
    /// Seed (scheduler tie-breaking).
    pub seed: u64,
    /// Byte substrate for the machine mesh.
    pub transport: TransportKind,
    /// In-proc latency injection.
    pub model: NetworkModel,
    /// Pin each machine loop to a CPU (`me % available_cpus`). Best-effort.
    pub pin_threads: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            machines: 2,
            alpha: 0.15,
            eps: 1e-8,
            scheduler: "fifo".to_string(),
            seed: 1,
            transport: TransportKind::InProc,
            model: NetworkModel::default(),
            pin_threads: false,
        }
    }
}

/// One queued client command: the request plus its reply channel. Both
/// the in-proc harness and the TCP listener feed these to the frontend.
pub struct ClientCmd {
    pub req: ServeReq,
    pub reply: mpsc::Sender<ServeReply>,
}

// ---------------------------------------------------------------------------
// per-machine state
// ---------------------------------------------------------------------------

/// Where a machine stands in the barrier protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// No epoch in flight.
    Idle,
    /// Flushed a round; waiting for every peer's `StepEnd` fence.
    WaitMarkers,
    /// Reported; waiting for the frontend's continue/stop decision.
    WaitDecision,
}

/// One serving machine's mutable graph + protocol state. Derived from a
/// [`LocalGraph`] at startup, then mutated in place for the rest of the
/// session (the batch engines' `LocalGraph` is CSR-immutable; serving
/// needs appendable adjacency).
struct ServeMachine {
    me: MachineId,
    machines: usize,
    n: usize,
    alpha: f32,
    eps: f32,
    /// Local→global vertex ids; `0..owned` are owned, the rest ghosts.
    l2g: Vec<VertexId>,
    g2l: HashMap<VertexId, u32>,
    owned: usize,
    /// Owning machine per local vertex.
    vowner: Vec<MachineId>,
    rank: Vec<f32>,
    /// Owned versions start at 1 so a fill always beats a ghost
    /// placeholder's version 0.
    version: Vec<u64>,
    /// Mutable adjacency, owned vertices only: `(local nbr, local edge)`.
    adj: Vec<Vec<(u32, u32)>>,
    /// `(to_lo, to_hi)` directed weights per local edge.
    edata: Vec<(f32, f32)>,
    /// Machines ghosting each owned vertex (grown on cross-partition
    /// `AddEdge` — the ghost-invalidation fan-out list).
    mirrors: Vec<Vec<MachineId>>,
    /// Task queue over *global* vertex ids (the vertex set is fixed, so
    /// the dedup bitmap sized `n` stays valid for the whole session).
    sched: Box<dyn Scheduler>,
    mode: Mode,
    /// Barriers completed since startup (cumulative across epochs, so
    /// marker accounting survives a peer racing one round ahead).
    barrier: u64,
    marker_total: u64,
    step_updates: u64,
    updates_total: u64,
    /// Ghost fills that arrived before the `Apply` that creates their
    /// ghost slot (cross-sender FIFO gives no ordering vs the frontend's
    /// broadcast); drained right after the next batch applies.
    stash: Vec<(VertexId, u64, f32)>,
}

impl ServeMachine {
    fn new(lg: LocalGraph<PrVertex, PrEdge>, n: usize, opts: &ServeOpts) -> Result<ServeMachine> {
        let nloc = lg.l2g.len();
        // Unpack the CSR adjacency into per-vertex Vecs (owned only).
        let mut adj = Vec::with_capacity(lg.owned);
        for lv in 0..lg.owned {
            let s = lg.adj_offsets[lv] as usize;
            let e = lg.adj_offsets[lv + 1] as usize;
            adj.push(lg.adj[s..e].to_vec());
        }
        let sched = by_name(&opts.scheduler, n, opts.seed)
            .with_context(|| format!("serve scheduler '{}'", opts.scheduler))?;
        Ok(ServeMachine {
            me: lg.machine,
            machines: opts.machines,
            n,
            alpha: opts.alpha,
            eps: opts.eps,
            l2g: lg.l2g,
            g2l: lg.g2l,
            owned: lg.owned,
            vowner: lg.owner,
            rank: lg.vdata.iter().map(|v| v.rank).collect(),
            version: vec![1; nloc],
            adj,
            edata: lg.edata.iter().map(|e| (e.to_lo, e.to_hi)).collect(),
            mirrors: lg.mirrors,
            sched,
            mode: Mode::Idle,
            barrier: 0,
            marker_total: 0,
            step_updates: 0,
            updates_total: 0,
            stash: Vec::new(),
        })
    }

    /// Local id of global `v`, creating a ghost slot if unknown.
    fn ensure_local(&mut self, v: VertexId, owner: MachineId) -> u32 {
        if let Some(&lv) = self.g2l.get(&v) {
            return lv;
        }
        let lv = self.l2g.len() as u32;
        self.l2g.push(v);
        self.g2l.insert(v, lv);
        self.vowner.push(owner);
        self.rank.push(0.0); // placeholder; the owner's fill overwrites it
        self.version.push(0);
        lv
    }

    fn push_owned(&mut self, v: VertexId, priority: f64) {
        self.sched.push(Task { vertex: v, priority });
    }

    /// First live edge between owned `la` and local `lb`, as
    /// `(position in adj[la], local edge id)`.
    fn find_edge(&self, la: u32, lb: u32) -> Option<(usize, u32)> {
        self.adj[la as usize]
            .iter()
            .position(|&(nbr, _)| nbr == lb)
            .map(|pos| (pos, self.adj[la as usize][pos].1))
    }

    /// Apply one routed mutation if it is locally relevant, scheduling
    /// the dirtied endpoints this machine owns and queuing ghost fills
    /// for newly cross-partition edges.
    fn apply_one(
        &mut self,
        rm: &RoutedMutation,
        fills: &mut HashMap<MachineId, Vec<(VertexId, u64, f32)>>,
    ) {
        let own_u = rm.owner_u as MachineId == self.me;
        let own_v = rm.owner_v as MachineId == self.me;
        match rm.m {
            Mutation::AddEdge { u, v, w } => {
                if !own_u && !own_v {
                    return; // edges live only at their endpoint owners
                }
                let lu = self.ensure_local(u, rm.owner_u as MachineId);
                let lv = self.ensure_local(v, rm.owner_v as MachineId);
                let le = self.edata.len() as u32;
                self.edata.push((w, w));
                if own_u {
                    self.adj[lu as usize].push((lv, le));
                }
                if own_v {
                    self.adj[lv as usize].push((lu, le));
                }
                // A cross-partition edge makes each owner a mirror of the
                // other's endpoint; seed the new ghost with a fill.
                if own_u && !own_v {
                    if !self.mirrors[lu as usize].contains(&(rm.owner_v as MachineId)) {
                        self.mirrors[lu as usize].push(rm.owner_v as MachineId);
                    }
                    fills.entry(rm.owner_v as MachineId).or_default().push((
                        u,
                        self.version[lu as usize],
                        self.rank[lu as usize],
                    ));
                }
                if own_v && !own_u {
                    if !self.mirrors[lv as usize].contains(&(rm.owner_u as MachineId)) {
                        self.mirrors[lv as usize].push(rm.owner_u as MachineId);
                    }
                    fills.entry(rm.owner_u as MachineId).or_default().push((
                        v,
                        self.version[lv as usize],
                        self.rank[lv as usize],
                    ));
                }
                if own_u {
                    self.push_owned(u, 1.0);
                }
                if own_v {
                    self.push_owned(v, 1.0);
                }
            }
            Mutation::RemoveEdge { u, v } => {
                if !own_u && !own_v {
                    return;
                }
                // Locate the edge from whichever endpoint is owned here
                // (adjacency exists for owned vertices only). Both owners
                // derived their lists from the same global edge order, so
                // "first match" removes the same edge everywhere.
                let (lu, lv) = match (self.g2l.get(&u), self.g2l.get(&v)) {
                    (Some(&a), Some(&b)) => (a, b),
                    _ => return, // edge was never here: a no-op remove
                };
                let le = if own_u {
                    self.find_edge(lu, lv)
                } else {
                    self.find_edge(lv, lu)
                };
                let Some((_, le)) = le else {
                    return; // no such edge: a no-op remove
                };
                if own_u {
                    if let Some(pos) =
                        self.adj[lu as usize].iter().position(|&(n, e)| n == lv && e == le)
                    {
                        self.adj[lu as usize].remove(pos);
                    }
                    self.push_owned(u, 1.0);
                }
                if own_v {
                    if let Some(pos) =
                        self.adj[lv as usize].iter().position(|&(n, e)| n == lu && e == le)
                    {
                        self.adj[lv as usize].remove(pos);
                    }
                    self.push_owned(v, 1.0);
                }
            }
            Mutation::SetEdgeWeight { u, v, w } => {
                if !own_u && !own_v {
                    return;
                }
                let (lu, lv) = match (self.g2l.get(&u), self.g2l.get(&v)) {
                    (Some(&a), Some(&b)) => (a, b),
                    _ => return,
                };
                let found = if own_u {
                    self.find_edge(lu, lv)
                } else {
                    self.find_edge(lv, lu)
                };
                let Some((_, le)) = found else {
                    return; // no such edge: a no-op reweight
                };
                self.edata[le as usize] = (w, w);
                if own_u {
                    self.push_owned(u, 1.0);
                }
                if own_v {
                    self.push_owned(v, 1.0);
                }
            }
            Mutation::TouchVertex { v } => {
                if own_u {
                    self.push_owned(v, 1.0);
                }
            }
        }
    }

    /// The epoch's apply barrier: apply the batch (or, for epoch 0's
    /// empty batch, schedule every owned vertex), flush ghost fills,
    /// then fence the round.
    fn apply_batch(&mut self, ep: &Endpoint<PeerMsg>, epoch: u64, muts: &[RoutedMutation]) {
        let mut fills: HashMap<MachineId, Vec<(VertexId, u64, f32)>> = HashMap::new();
        if epoch == 0 && muts.is_empty() {
            for lv in 0..self.owned {
                let v = self.l2g[lv];
                self.push_owned(v, 1.0);
            }
        }
        for rm in muts {
            self.apply_one(rm, &mut fills);
        }
        // Fills that raced ahead of this Apply can land now.
        let stash = std::mem::take(&mut self.stash);
        self.absorb_ghosts(stash);
        self.step_updates = 0;
        let ghosts = fills
            .into_iter()
            .map(|(m, verts)| (m, PeerMsg::Ghost { verts, tasks: Vec::new() }))
            .collect();
        self.fence_with(ep, ghosts);
    }

    /// One update superstep: drain the queue, recompute each drained
    /// vertex, propagate to mirrors, reschedule neighbors past `eps`.
    fn run_superstep(&mut self, ep: &Endpoint<PeerMsg>) {
        let mut batch: Vec<VertexId> = Vec::new();
        while let Some(t) = self.sched.pop() {
            batch.push(t.vertex);
        }
        type Out = (Vec<(VertexId, u64, f32)>, Vec<Task>);
        let mut out: HashMap<MachineId, Out> = HashMap::new();
        let inv_n = self.alpha / self.n as f32;
        for v in batch {
            let lv = *self.g2l.get(&v).expect("scheduled vertex is local") as usize;
            debug_assert!(lv < self.owned, "scheduled vertex must be owned");
            let mut sum = inv_n;
            for i in 0..self.adj[lv].len() {
                let (nbr, le) = self.adj[lv][i];
                let gn = self.l2g[nbr as usize];
                let (to_lo, to_hi) = self.edata[le as usize];
                let w = if v < gn { to_lo } else { to_hi };
                sum += w * self.rank[nbr as usize];
            }
            let delta = (sum - self.rank[lv]).abs();
            self.rank[lv] = sum;
            self.version[lv] += 1;
            self.step_updates += 1;
            self.updates_total += 1;
            for i in 0..self.mirrors[lv].len() {
                let m = self.mirrors[lv][i];
                out.entry(m).or_default().0.push((v, self.version[lv], sum));
            }
            if delta > self.eps {
                for i in 0..self.adj[lv].len() {
                    let (nbr, _) = self.adj[lv][i];
                    let gn = self.l2g[nbr as usize];
                    let owner = self.vowner[nbr as usize];
                    let t = Task { vertex: gn, priority: delta as f64 };
                    if owner == self.me {
                        self.sched.push(t);
                    } else {
                        out.entry(owner).or_default().1.push(t);
                    }
                }
            }
        }
        let ghosts = out
            .into_iter()
            .map(|(m, (verts, tasks))| (m, PeerMsg::Ghost { verts, tasks }))
            .collect();
        self.fence_with(ep, ghosts);
    }

    /// Flush-complete fence: each peer gets its ghost payload (if any)
    /// and the `StepEnd` marker in ONE batched send — a single pooled
    /// multi-frame buffer and one transport write per peer per round,
    /// with the marker's fence semantics intact (FIFO within the batch).
    fn fence_with(&mut self, ep: &Endpoint<PeerMsg>, mut ghosts: HashMap<MachineId, PeerMsg>) {
        for m in 0..self.machines {
            if m == self.me {
                continue;
            }
            let mut batch = Vec::with_capacity(2);
            if let Some(g) = ghosts.remove(&m) {
                batch.push(g);
            }
            batch.push(PeerMsg::StepEnd { step: self.barrier });
            ep.send_batch(m, batch);
        }
        self.mode = Mode::WaitMarkers;
    }

    /// Version-gated ghost writes; unknown vertices (fills racing their
    /// `Apply`) are stashed for the next batch.
    fn absorb_ghosts(&mut self, verts: Vec<(VertexId, u64, f32)>) {
        for (v, ver, r) in verts {
            match self.g2l.get(&v) {
                Some(&lv) => {
                    let lv = lv as usize;
                    if ver > self.version[lv] {
                        self.version[lv] = ver;
                        self.rank[lv] = r;
                    }
                }
                None => self.stash.push((v, ver, r)),
            }
        }
    }

    /// If every peer's fence for the current barrier has arrived, report
    /// the local backlog to the frontend and await its decision.
    fn maybe_report(&mut self, ep: &Endpoint<PeerMsg>) {
        if self.mode != Mode::WaitMarkers {
            return;
        }
        let need = (self.machines as u64 - 1) * (self.barrier + 1);
        if self.marker_total < need {
            return;
        }
        ep.send(
            FRONTEND,
            PeerMsg::Report {
                step: self.barrier,
                pending: self.sched.len() as u64,
                updates: self.step_updates,
            },
        );
        self.step_updates = 0;
        self.barrier += 1;
        self.mode = Mode::WaitDecision;
    }
}

// ---------------------------------------------------------------------------
// frontend coordinator
// ---------------------------------------------------------------------------

/// Machine 0's extra state: client channel, routing partition, epoch
/// bookkeeping, in-flight query table.
struct Frontend {
    part: Partition,
    client_rx: mpsc::Receiver<ClientCmd>,
    /// Queued mutation batches: (routed batch, dirtied-endpoint count,
    /// reply channel). Epoch 0 (initial convergence) has no reply.
    pending: VecDeque<(Vec<RoutedMutation>, u64, Option<mpsc::Sender<ServeReply>>)>,
    /// The in-flight epoch's (scheduled count, reply channel).
    cur: Option<(u64, mpsc::Sender<ServeReply>)>,
    queries: HashMap<u64, mpsc::Sender<ServeReply>>,
    next_query: u64,
    started: bool,
    in_epoch: bool,
    next_epoch: u64,
    rep_count: usize,
    rep_pending: u64,
    rep_updates: u64,
    epoch_updates: u64,
    epoch_steps: u64,
    stats: ServeStats,
}

impl Frontend {
    fn new(part: Partition, client_rx: mpsc::Receiver<ClientCmd>, n: usize, m_edges: usize, machines: usize) -> Frontend {
        Frontend {
            part,
            client_rx,
            pending: VecDeque::new(),
            cur: None,
            queries: HashMap::new(),
            next_query: 0,
            started: false,
            in_epoch: false,
            next_epoch: 0,
            rep_count: 0,
            rep_pending: 0,
            rep_updates: 0,
            epoch_updates: 0,
            epoch_steps: 0,
            stats: ServeStats {
                vertices: n as u64,
                edges: m_edges as u64,
                machines: machines as u32,
                ..ServeStats::default()
            },
        }
    }

    /// The staleness tag attached to query answers.
    fn tag(&self) -> (u64, bool) {
        (self.stats.epoch, self.stats.converged && !self.in_epoch)
    }

    /// Validate and owner-annotate a client mutation batch. Returns the
    /// routed batch plus the dirtied-endpoint count, or a typed refusal.
    fn route(&mut self, muts: Vec<Mutation>) -> std::result::Result<(Vec<RoutedMutation>, u64), ServeReply> {
        let n = self.part.num_vertices() as VertexId;
        let mut routed = Vec::with_capacity(muts.len());
        let mut scheduled = 0u64;
        for m in muts {
            let (u, v) = m.endpoints();
            if u >= n || v.is_some_and(|v| v >= n) {
                return Err(ServeReply::Error {
                    kind: ErrorKind::UnknownVertex,
                    detail: format!("vertex out of range in {m:?} (n = {n})"),
                });
            }
            if v == Some(u) {
                return Err(ServeReply::Error {
                    kind: ErrorKind::BadRequest,
                    detail: format!("self-loop mutation {m:?}"),
                });
            }
            if let Mutation::AddEdge { w, .. } | Mutation::SetEdgeWeight { w, .. } = m {
                if !w.is_finite() {
                    return Err(ServeReply::Error {
                        kind: ErrorKind::BadRequest,
                        detail: format!("non-finite weight in {m:?}"),
                    });
                }
            }
            // Live-edge tally (approximate for no-op removes: the
            // frontend does not track per-edge existence).
            match m {
                Mutation::AddEdge { .. } => self.stats.edges += 1,
                Mutation::RemoveEdge { .. } => {
                    self.stats.edges = self.stats.edges.saturating_sub(1)
                }
                _ => {}
            }
            scheduled += 1 + v.is_some() as u64;
            let owner_u = self.part.owner(u) as u32;
            let owner_v = v.map_or(owner_u, |v| self.part.owner(v) as u32);
            routed.push(RoutedMutation { m, owner_u, owner_v });
        }
        Ok((routed, scheduled))
    }
}

fn broadcast(ep: &Endpoint<PeerMsg>, machines: usize, msg: &PeerMsg) {
    for m in 0..machines {
        ep.send(m, msg.clone());
    }
}

/// Start the next queued epoch (or epoch 0, exactly once, at startup).
fn start_epochs(st: &ServeMachine, ep: &Endpoint<PeerMsg>, f: &mut Frontend) {
    if !f.started {
        f.started = true;
        f.in_epoch = true;
        f.stats.converged = false;
        broadcast(ep, st.machines, &PeerMsg::Apply { epoch: 0, muts: Vec::new() });
        return;
    }
    if f.in_epoch {
        return;
    }
    if let Some((muts, scheduled, reply)) = f.pending.pop_front() {
        f.in_epoch = true;
        f.stats.converged = false;
        f.epoch_updates = 0;
        f.epoch_steps = 0;
        f.cur = reply.map(|r| (scheduled, r));
        broadcast(ep, st.machines, &PeerMsg::Apply { epoch: f.next_epoch, muts });
    }
}

/// Handle one client command on the frontend.
fn handle_client(
    st: &mut ServeMachine,
    ep: &Endpoint<PeerMsg>,
    f: &mut Frontend,
    cmd: ClientCmd,
    running: &mut bool,
) {
    match cmd.req {
        ServeReq::Query { vertex } => {
            if vertex as usize >= st.n {
                let _ = cmd.reply.send(ServeReply::Error {
                    kind: ErrorKind::UnknownVertex,
                    detail: format!("vertex {vertex} out of range (n = {})", st.n),
                });
                return;
            }
            let owner = f.part.owner(vertex);
            if owner == st.me {
                let lv = st.g2l[&vertex] as usize;
                let (epoch, converged) = f.tag();
                let _ = cmd.reply.send(ServeReply::Value {
                    vertex,
                    rank: st.rank[lv],
                    epoch,
                    converged,
                });
            } else {
                let id = f.next_query;
                f.next_query += 1;
                f.queries.insert(id, cmd.reply);
                ep.send(owner, PeerMsg::Query { id, vertex });
            }
        }
        ServeReq::Mutate { muts } => match f.route(muts) {
            Ok((routed, scheduled)) => {
                f.pending.push_back((routed, scheduled, Some(cmd.reply)));
            }
            Err(refusal) => {
                let _ = cmd.reply.send(refusal);
            }
        },
        ServeReq::Stats => {
            let mut s = f.stats.clone();
            s.converged = s.converged && !f.in_epoch && f.started;
            let _ = cmd.reply.send(ServeReply::Stats(s));
        }
        ServeReq::Shutdown => {
            let _ = cmd.reply.send(ServeReply::Bye);
            for m in 0..st.machines {
                if m != st.me {
                    ep.send(m, PeerMsg::Stop);
                }
            }
            *running = false;
        }
    }
}

/// Handle one mesh message (frontend-only variants require `f`).
fn handle_peer(
    st: &mut ServeMachine,
    ep: &Endpoint<PeerMsg>,
    mut f: Option<&mut Frontend>,
    msg: PeerMsg,
    running: &mut bool,
) {
    match msg {
        PeerMsg::Apply { epoch, muts } => {
            st.apply_batch(ep, epoch, &muts);
            st.maybe_report(ep);
        }
        PeerMsg::Ghost { verts, tasks } => {
            st.absorb_ghosts(verts);
            st.sched.inject(&tasks);
        }
        PeerMsg::StepEnd { .. } => {
            st.marker_total += 1;
            st.maybe_report(ep);
        }
        PeerMsg::Report { step: _, pending, updates } => {
            let f = f.as_mut().expect("Report reaches only the frontend");
            f.rep_count += 1;
            f.rep_pending += pending;
            f.rep_updates += updates;
            if f.rep_count == st.machines {
                f.epoch_updates += f.rep_updates;
                let cont = f.rep_pending > 0;
                if cont {
                    f.epoch_steps += 1;
                }
                f.rep_count = 0;
                f.rep_pending = 0;
                f.rep_updates = 0;
                broadcast(ep, st.machines, &PeerMsg::Decision { step: st.barrier, cont });
                if !cont {
                    // Epoch over: book it and ack the waiting client.
                    f.in_epoch = false;
                    f.stats.epoch = f.next_epoch;
                    f.stats.epoch_updates = f.epoch_updates;
                    f.stats.total_updates += f.epoch_updates;
                    if f.next_epoch == 0 {
                        f.stats.initial_updates = f.epoch_updates;
                    }
                    f.stats.converged = true;
                    if let Some((scheduled, reply)) = f.cur.take() {
                        let _ = reply.send(ServeReply::MutAck {
                            epoch: f.next_epoch,
                            scheduled,
                            updates: f.epoch_updates,
                            steps: f.epoch_steps,
                        });
                    }
                    f.next_epoch += 1;
                }
            }
        }
        PeerMsg::Decision { step: _, cont } => {
            if cont {
                st.run_superstep(ep);
                st.maybe_report(ep);
            } else {
                st.mode = Mode::Idle;
            }
        }
        PeerMsg::Query { id, vertex } => {
            let (rank, version) = match st.g2l.get(&vertex) {
                Some(&lv) => (st.rank[lv as usize], st.version[lv as usize]),
                None => (0.0, 0),
            };
            ep.send(FRONTEND, PeerMsg::Answer { id, vertex, rank, version });
        }
        PeerMsg::Answer { id, vertex, rank, version: _ } => {
            let f = f.as_mut().expect("Answer reaches only the frontend");
            if let Some(reply) = f.queries.remove(&id) {
                let (epoch, converged) = f.tag();
                let _ = reply.send(ServeReply::Value { vertex, rank, epoch, converged });
            }
        }
        PeerMsg::Stop => *running = false,
    }
}

/// One machine's resident event loop. Machine 0 passes its frontend
/// state; workers pass `None`. Returns when a client shutdown (or the
/// frontend's `Stop`) drains the loop.
fn machine_loop(
    mut st: ServeMachine,
    mut ep: Endpoint<PeerMsg>,
    mut front: Option<Frontend>,
) -> Result<()> {
    let mut running = true;
    // The frontend polls tightly (it multiplexes the client channel);
    // workers park long — a mesh message wakes them instantly either way.
    let idle = if front.is_some() {
        Duration::from_micros(200)
    } else {
        Duration::from_millis(50)
    };
    while running {
        if let Some(f) = front.as_mut() {
            // Client commands never block: queries answer/forward
            // immediately, mutations queue for the next epoch.
            while let Ok(cmd) = f.client_rx.try_recv() {
                handle_client(&mut st, &ep, f, cmd, &mut running);
                if !running {
                    return Ok(());
                }
            }
            start_epochs(&st, &ep, f);
        }
        match ep.recv_timeout(idle) {
            Some(rx) => {
                handle_peer(&mut st, &ep, front.as_mut(), rx.msg, &mut running);
                // Drain whatever else is queued before the next poll.
                while running {
                    let Some(rx) = ep.try_recv() else { break };
                    handle_peer(&mut st, &ep, front.as_mut(), rx.msg, &mut running);
                }
            }
            None => {
                // A worker whose frontend died has nothing left to wait
                // for (the mesh records per-peer errors).
                if front.is_none() && !ep.peer_alive(FRONTEND) {
                    bail!("serve worker {}: frontend (machine 0) is gone", st.me);
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// entry points
// ---------------------------------------------------------------------------

/// A resident in-proc serving cluster: every machine is a thread in this
/// process, and this handle is the (deterministic, socket-free) client.
/// The TCP client listener can feed the same frontend — see
/// [`super::client::spawn_listener`].
pub struct ServeSession {
    client_tx: mpsc::Sender<ClientCmd>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl ServeSession {
    /// Build local graphs from `part`, form the mesh, and spawn one
    /// machine loop per thread. Returns once the cluster is resident
    /// (epoch 0 — the initial convergence — runs in the background;
    /// [`ServeSession::wait_converged`] blocks on it).
    pub fn start(
        g: Graph<PrVertex, PrEdge>,
        part: &Partition,
        opts: &ServeOpts,
    ) -> Result<ServeSession> {
        let n = g.num_vertices();
        let m_edges = g.num_edges();
        anyhow::ensure!(n > 0, "serve: empty graph");
        anyhow::ensure!(opts.machines >= 1, "serve: at least one machine");
        let setup = cluster_setup::<PrVertex, PrEdge, PeerMsg>(
            g,
            part,
            None,
            opts.machines,
            opts.model,
            opts.transport,
            None,
            None,
            None,
        )?;
        let (client_tx, client_rx) = mpsc::channel();
        let mut client_rx = Some(client_rx);
        let mut handles = Vec::with_capacity(opts.machines);
        for (lg, ep) in setup.locals.into_iter().zip(setup.endpoints) {
            let st = ServeMachine::new(lg, n, opts)?;
            let front = if st.me == FRONTEND {
                Some(Frontend::new(
                    part.clone(),
                    client_rx.take().expect("one frontend"),
                    n,
                    m_edges,
                    opts.machines,
                ))
            } else {
                None
            };
            let pin = opts.pin_threads;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-m{}", st.me))
                    .spawn(move || {
                        if pin {
                            crate::util::affinity::pin_current_thread(
                                st.me % crate::util::affinity::available_cpus(),
                            );
                        }
                        machine_loop(st, ep, front)
                    })?,
            );
        }
        Ok(ServeSession { client_tx, handles })
    }

    /// A sender feeding the frontend's client channel — hand it to the
    /// TCP listener so socket clients and this harness share one queue.
    pub fn feed(&self) -> mpsc::Sender<ClientCmd> {
        self.client_tx.clone()
    }

    /// Send one request and block for its reply.
    pub fn request(&self, req: ServeReq) -> Result<ServeReply> {
        let (tx, rx) = mpsc::channel();
        self.client_tx
            .send(ClientCmd { req, reply: tx })
            .map_err(|_| anyhow::anyhow!("serve cluster is down"))?;
        rx.recv_timeout(REQUEST_TIMEOUT)
            .map_err(|_| anyhow::anyhow!("serve cluster did not answer within {REQUEST_TIMEOUT:?}"))
    }

    /// Read one vertex's rank (with its staleness tag).
    pub fn query(&self, vertex: VertexId) -> Result<ServeReply> {
        self.request(ServeReq::Query { vertex })
    }

    /// Apply a mutation batch as one epoch; blocks until re-converged.
    pub fn mutate(&self, muts: Vec<Mutation>) -> Result<ServeReply> {
        self.request(ServeReq::Mutate { muts })
    }

    /// Serving counters.
    pub fn stats(&self) -> Result<ServeStats> {
        match self.request(ServeReq::Stats)? {
            ServeReply::Stats(s) => Ok(s),
            other => bail!("stats request answered with {other:?}"),
        }
    }

    /// Block until the cluster is quiescent (epoch 0 included).
    pub fn wait_converged(&self) -> Result<ServeStats> {
        let deadline = std::time::Instant::now() + REQUEST_TIMEOUT;
        loop {
            let s = self.stats()?;
            if s.converged {
                return Ok(s);
            }
            if std::time::Instant::now() > deadline {
                bail!("serve cluster did not converge within {REQUEST_TIMEOUT:?}");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Stop the cluster and join every machine thread.
    pub fn shutdown(self) -> Result<()> {
        let _ = self.request(ServeReq::Shutdown)?;
        self.wait()
    }

    /// Join every machine thread WITHOUT initiating shutdown — returns
    /// when some client's `Shutdown` (e.g. over the TCP listener) stops
    /// the cluster. This is `graphlab serve`'s resident blocking call.
    pub fn wait(self) -> Result<()> {
        for h in self.handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => bail!("a serve machine thread panicked"),
            }
        }
        Ok(())
    }
}

/// Run ONE machine of a (possibly multi-process) serving cluster on the
/// calling thread — the `graphlab serve` entry point. Machine 0 is the
/// frontend and requires a client feed: `client_rx` (from the TCP
/// listener, an in-proc harness, or both writing to its sender side).
/// Returns when a client `Shutdown` (or the frontend's `Stop`) lands.
#[allow(clippy::too_many_arguments)]
pub fn serve_machine(
    g: Graph<PrVertex, PrEdge>,
    part: &Partition,
    atoms: Option<&AtomPlacement>,
    opts: &ServeOpts,
    cluster: Option<&ClusterConfig>,
    client_rx: Option<mpsc::Receiver<ClientCmd>>,
) -> Result<()> {
    let n = g.num_vertices();
    let m_edges = g.num_edges();
    let me = cluster.map_or(FRONTEND, |c| c.me);
    let setup = cluster_setup::<PrVertex, PrEdge, PeerMsg>(
        g,
        part,
        atoms,
        opts.machines,
        opts.model,
        opts.transport,
        cluster,
        None,
        None,
    )?;
    anyhow::ensure!(
        setup.locals.len() == 1 && setup.endpoints.len() == 1,
        "serve_machine runs exactly one machine per process (use ServeSession in-proc)"
    );
    let lg = setup.locals.into_iter().next().unwrap();
    let ep = setup.endpoints.into_iter().next().unwrap();
    let st = ServeMachine::new(lg, n, opts)?;
    let front = if me == FRONTEND {
        let rx = client_rx.context("serve frontend (machine 0) needs a client channel")?;
        Some(Frontend::new(part.clone(), rx, n, m_edges, opts.machines))
    } else {
        None
    };
    if opts.pin_threads {
        crate::util::affinity::pin_current_thread(me % crate::util::affinity::available_cpus());
    }
    machine_loop(st, ep, front)
}
