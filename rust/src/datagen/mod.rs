//! Synthetic dataset generators (DESIGN.md §Substitutions).
//!
//! The paper's datasets (Netflix ratings, a web crawl for NER, 1,740
//! frames of high-resolution video) are not redistributable; these
//! generators produce planted-structure equivalents that exercise the same
//! code paths and preserve the behaviours the evaluation depends on:
//! bipartite low-rank structure for ALS, dense power-law bipartite
//! co-occurrence for CoEM, and a 3-D grid with smooth label regions for
//! CoSeg. All generators are deterministic in their seed.

use crate::util::Rng;

/// A synthetic Netflix-style ratings dataset with planted low-rank
/// structure: rating(u, m) = <p_u, q_m> + noise, clamped to [1, 5].
pub struct NetflixData {
    /// Number of users.
    pub users: usize,
    /// Number of movies.
    pub movies: usize,
    /// (user, movie, rating) triples (unique pairs).
    pub ratings: Vec<(u32, u32, f32)>,
    /// Planted rank.
    pub true_rank: usize,
}

/// Generate planted low-rank ratings. Movie popularity is power-law
/// distributed (like the real Netflix data); each user rates
/// `ratings_per_user` distinct movies.
pub fn netflix(
    users: usize,
    movies: usize,
    ratings_per_user: usize,
    true_rank: usize,
    noise: f32,
    seed: u64,
) -> NetflixData {
    let mut rng = Rng::new(seed);
    // Planted factors are zero-mean so the signal genuinely has rank
    // `true_rank` (all-positive factors would collapse to a near-rank-1
    // matrix dominated by the row/column means, making d irrelevant).
    // Var(<p, q>) = d * s^4, so s = (0.8^2 / d)^(1/4) gives the dot
    // product a ~0.8 standard deviation around the 3.0 mid-scale.
    let scale = (0.64f32 / true_rank as f32).powf(0.25);
    let p: Vec<Vec<f32>> = (0..users)
        .map(|_| (0..true_rank).map(|_| rng.normal() * scale).collect())
        .collect();
    let q: Vec<Vec<f32>> = (0..movies)
        .map(|_| (0..true_rank).map(|_| rng.normal() * scale).collect())
        .collect();
    let mut ratings = Vec::with_capacity(users * ratings_per_user);
    let mut seen = std::collections::HashSet::new();
    for u in 0..users {
        let mut tries = 0;
        let mut count = 0;
        while count < ratings_per_user && tries < ratings_per_user * 20 {
            tries += 1;
            let m = rng.powerlaw(movies, 1.5);
            if !seen.insert((u as u32, m as u32)) {
                continue;
            }
            let dot: f32 = p[u].iter().zip(&q[m]).map(|(a, b)| a * b).sum();
            let r = (3.0 + dot + rng.normal() * noise).clamp(1.0, 5.0);
            ratings.push((u as u32, m as u32, r));
            count += 1;
        }
    }
    NetflixData {
        users,
        movies,
        ratings,
        true_rank,
    }
}

/// A power-law undirected web-like graph for PageRank: edge list over `n`
/// vertices, preferential-attachment flavored.
pub fn web_graph(n: usize, avg_degree: usize, seed: u64) -> Vec<(u32, u32)> {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(n * avg_degree / 2);
    let mut seen = std::collections::HashSet::new();
    let target = n * avg_degree / 2;
    let mut tries = 0;
    while edges.len() < target && tries < target * 30 {
        tries += 1;
        let u = rng.gen_range(n) as u32;
        // Power-law target: low ids are hubs.
        let v = rng.powerlaw(n, 1.8) as u32;
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edges.push(key);
        }
    }
    edges
}

/// Synthetic 3-D video grid for CoSeg.
pub struct VideoData {
    /// Frames (time axis).
    pub frames: usize,
    /// Super-pixel grid width per frame.
    pub width: usize,
    /// Super-pixel grid height per frame.
    pub height: usize,
    /// Number of labels.
    pub labels: usize,
    /// Per-super-pixel appearance feature ([frames*width*height][labels]).
    pub appearance: Vec<Vec<f32>>,
    /// Ground-truth label per super-pixel.
    pub truth: Vec<u8>,
}

/// Vertex index of (frame, x, y) in the flattened grid.
pub fn grid_index(frames_dims: (usize, usize, usize), f: usize, x: usize, y: usize) -> usize {
    let (_, w, h) = frames_dims;
    f * w * h + x * h + y
}

/// Generate a video with `labels` smooth regions (horizontal bands that
/// drift over time) and noisy appearance features — the planted analogue
/// of sky/building/grass/... regions.
pub fn video(
    frames: usize,
    width: usize,
    height: usize,
    labels: usize,
    noise: f32,
    seed: u64,
) -> VideoData {
    let mut rng = Rng::new(seed);
    let n = frames * width * height;
    let mut appearance = Vec::with_capacity(n);
    let mut truth = Vec::with_capacity(n);
    for f in 0..frames {
        // Band boundaries drift slowly with time.
        let drift = (f as f32 * 0.07).sin() * 0.1;
        for _x in 0..width {
            for y in 0..height {
                let pos = y as f32 / height as f32 + drift;
                let lab = ((pos.clamp(0.0, 0.999)) * labels as f32) as usize % labels;
                let mut feat = vec![0.0f32; labels];
                for (l, fv) in feat.iter_mut().enumerate() {
                    *fv = if l == lab { 1.0 } else { 0.0 } + rng.normal() * noise;
                }
                appearance.push(feat);
                truth.push(lab as u8);
            }
        }
    }
    VideoData {
        frames,
        width,
        height,
        labels,
        appearance,
        truth,
    }
}

/// Edges of the 3-D grid (6-neighborhood: x±1, y±1, t±1).
pub fn video_edges(frames: usize, width: usize, height: usize) -> Vec<(u32, u32)> {
    let dims = (frames, width, height);
    let mut edges = Vec::new();
    for f in 0..frames {
        for x in 0..width {
            for y in 0..height {
                let v = grid_index(dims, f, x, y) as u32;
                if y + 1 < height {
                    edges.push((v, grid_index(dims, f, x, y + 1) as u32));
                }
                if x + 1 < width {
                    edges.push((v, grid_index(dims, f, x + 1, y) as u32));
                }
                if f + 1 < frames {
                    edges.push((v, grid_index(dims, f + 1, x, y) as u32));
                }
            }
        }
    }
    edges
}

/// Synthetic NER/CoEM bipartite co-occurrence data.
pub struct NerData {
    /// Noun-phrase count.
    pub nps: usize,
    /// Context count.
    pub contexts: usize,
    /// Entity type count.
    pub types: usize,
    /// (np, context, co-occurrence count) triples.
    pub cooccur: Vec<(u32, u32, f32)>,
    /// Ground-truth type per noun-phrase.
    pub np_truth: Vec<u8>,
    /// Seed labels: np index → type (the small pre-labeled set).
    pub seeds: Vec<(u32, u8)>,
}

/// Generate CoEM data: each noun-phrase and context has a latent type;
/// co-occurrence mass concentrates within-type (power-law context
/// popularity, like web contexts).
pub fn ner(
    nps: usize,
    contexts: usize,
    edges_per_np: usize,
    types: usize,
    seed_fraction: f64,
    seed: u64,
) -> NerData {
    let mut rng = Rng::new(seed);
    let np_truth: Vec<u8> = (0..nps).map(|_| rng.gen_range(types) as u8).collect();
    let ctx_truth: Vec<u8> = (0..contexts).map(|_| rng.gen_range(types) as u8).collect();
    // Within-type contexts per type for fast sampling.
    let mut by_type: Vec<Vec<u32>> = vec![Vec::new(); types];
    for (c, &t) in ctx_truth.iter().enumerate() {
        by_type[t as usize].push(c as u32);
    }
    let mut cooccur = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for np in 0..nps {
        let t = np_truth[np] as usize;
        for _ in 0..edges_per_np {
            // 80% within-type, 20% random (noise).
            let c = if rng.chance(0.8) && !by_type[t].is_empty() {
                by_type[t][rng.powerlaw(by_type[t].len(), 1.5)]
            } else {
                rng.gen_range(contexts) as u32
            };
            if seen.insert((np as u32, c)) {
                cooccur.push((np as u32, c, rng.gen_range(9) as f32 + 1.0));
            }
        }
    }
    let seeds: Vec<(u32, u8)> = (0..nps)
        .filter(|_| rng.chance(seed_fraction))
        .map(|np| (np as u32, np_truth[np]))
        .collect();
    NerData {
        nps,
        contexts,
        types,
        cooccur,
        np_truth,
        seeds,
    }
}

/// A 2-D Ising-like Markov Random Field for Gibbs sampling: grid with
/// per-vertex external field and uniform coupling.
pub struct MrfData {
    /// Grid side.
    pub side: usize,
    /// External field per vertex (+ favors 1, − favors 0).
    pub field: Vec<f32>,
    /// Coupling strength.
    pub coupling: f32,
}

/// Generate an Ising MRF with a smooth planted field.
pub fn mrf(side: usize, coupling: f32, seed: u64) -> MrfData {
    let mut rng = Rng::new(seed);
    let field = (0..side * side)
        .map(|i| {
            let (x, y) = (i / side, i % side);
            // Two planted blobs of opposite polarity + noise.
            let f1 = (-(((x as f32 - side as f32 * 0.3).powi(2)
                + (y as f32 - side as f32 * 0.3).powi(2))
                / (side as f32 * 2.0)))
                .exp();
            let f2 = (-(((x as f32 - side as f32 * 0.7).powi(2)
                + (y as f32 - side as f32 * 0.7).powi(2))
                / (side as f32 * 2.0)))
                .exp();
            (f1 - f2) * 2.0 + rng.normal() * 0.1
        })
        .collect();
    MrfData {
        side,
        field,
        coupling,
    }
}

/// Edges of a 2-D grid (4-neighborhood).
pub fn grid2d_edges(side: usize) -> Vec<(u32, u32)> {
    let mut edges = Vec::new();
    for x in 0..side {
        for y in 0..side {
            let v = (x * side + y) as u32;
            if y + 1 < side {
                edges.push((v, v + 1));
            }
            if x + 1 < side {
                edges.push((v, v + side as u32));
            }
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn netflix_is_deterministic_and_ranged() {
        let a = netflix(100, 50, 10, 5, 0.2, 7);
        let b = netflix(100, 50, 10, 5, 0.2, 7);
        assert_eq!(a.ratings, b.ratings);
        assert!(a.ratings.len() >= 900);
        assert!(a.ratings.iter().all(|&(_, _, r)| (1.0..=5.0).contains(&r)));
        // Unique (user, movie) pairs.
        let mut set = std::collections::HashSet::new();
        assert!(a.ratings.iter().all(|&(u, m, _)| set.insert((u, m))));
    }

    #[test]
    fn web_graph_is_powerlaw_ish() {
        let edges = web_graph(2000, 8, 3);
        assert!(edges.len() > 6000);
        let mut deg = vec![0usize; 2000];
        for &(u, v) in &edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let max = *deg.iter().max().unwrap();
        let mean = deg.iter().sum::<usize>() / 2000;
        assert!(max > mean * 5, "hubs expected: max={max} mean={mean}");
    }

    #[test]
    fn video_grid_shapes_and_smoothness() {
        // With L=5 bands over height 26, (25-4)/25 = 84% of vertical
        // neighbor pairs share a label.
        let v = video(4, 10, 26, 5, 0.1, 1);
        assert_eq!(v.appearance.len(), 4 * 10 * 26);
        assert_eq!(v.truth.len(), 1040);
        let dims = (4, 10, 26);
        let mut same = 0;
        let mut total = 0;
        for f in 0..4 {
            for x in 0..10 {
                for y in 0..25 {
                    total += 1;
                    if v.truth[grid_index(dims, f, x, y)]
                        == v.truth[grid_index(dims, f, x, y + 1)]
                    {
                        same += 1;
                    }
                }
            }
        }
        assert!(same * 10 > total * 7, "smooth bands: {same}/{total}");
        let edges = video_edges(4, 10, 26);
        // 6-neighborhood edge count check.
        let expected = 4 * 10 * 25 + 4 * 9 * 26 + 3 * 10 * 26;
        assert_eq!(edges.len(), expected);
    }

    #[test]
    fn ner_within_type_concentration() {
        let d = ner(200, 100, 20, 4, 0.1, 5);
        assert!(!d.seeds.is_empty());
        assert!(d.cooccur.len() > 2000);
        assert!(d.np_truth.len() == 200);
    }

    #[test]
    fn mrf_and_grid() {
        let m = mrf(16, 1.0, 2);
        assert_eq!(m.field.len(), 256);
        assert_eq!(grid2d_edges(16).len(), 2 * 16 * 15);
    }
}
