//! The **wire codec**: the one serialization story for everything that
//! crosses a machine boundary or touches disk.
//!
//! The paper's distributed layer rides on TCP frames (Sec. 4.1 versioned
//! ghost coherence, Sec. 4.2 lock/update protocols) and on atom files —
//! journals of graph-construction commands replayed at load time
//! (Distributed GraphLab, arXiv 1204.6078). Both need actual bytes, so
//! this module defines [`Wire`]: a little-endian, length-prefixed,
//! dependency-free codec implemented by hand for every primitive,
//! container, app vertex/edge type, and distributed message enum in the
//! tree. The in-process network ([`crate::distributed::network`]) encodes
//! every message into a frame and counts the *encoded* length, so wire
//! metrics (Fig. 6(b)) are measurements, not models; the atom store
//! ([`crate::partition::atoms`]) writes the same records to disk.
//!
//! # Encoding rules (version [`WIRE_VERSION`])
//!
//! * integers and floats: fixed-width little-endian (`usize`/`isize`
//!   travel as 8-byte `u64`/`i64` so files are portable across hosts);
//! * `bool` / `Option` tags: one byte, `0` or `1` — anything else is a
//!   decode error, not a silent coercion;
//! * `Vec<T>` / `String`: `u32` element count, then the elements
//!   (strings are UTF-8 validated on decode);
//! * tuples and structs: fields in declaration order, no padding;
//! * enums: one discriminant byte, then the variant's fields.
//!
//! Decoding is total: truncated input, bad tags, and invalid UTF-8 come
//! back as [`WireError`], never a panic (property-tested over random
//! values and all strict prefixes in `rust/tests/wire_props.rs`).

use std::fmt;

/// Codec version. Frames between in-process endpoints don't carry it
/// (both ends are the same build; a TCP deployment would negotiate it at
/// connection setup) but every atom file embeds it in its header.
pub const WIRE_VERSION: u32 = 1;

/// A decode failure. Encoding is infallible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the value did.
    Truncated {
        /// Bytes the decoder needed.
        needed: usize,
        /// Bytes it had.
        have: usize,
    },
    /// An enum discriminant / bool / Option tag held an invalid value.
    BadTag {
        /// The type being decoded.
        what: &'static str,
        /// The offending byte.
        tag: u8,
    },
    /// A `String` payload was not valid UTF-8.
    BadUtf8,
    /// [`from_bytes`] finished with unconsumed input.
    Trailing {
        /// Leftover byte count.
        extra: usize,
    },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "wire: truncated input (needed {needed} bytes, have {have})")
            }
            WireError::BadTag { what, tag } => {
                write!(f, "wire: invalid tag {tag} while decoding {what}")
            }
            WireError::BadUtf8 => write!(f, "wire: string payload is not valid UTF-8"),
            WireError::Trailing { extra } => {
                write!(f, "wire: {extra} trailing bytes after value")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// Codec result.
pub type Result<T> = std::result::Result<T, WireError>;

/// Consume exactly `n` bytes from the front of `input`.
#[inline]
pub fn take<'a>(input: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if input.len() < n {
        return Err(WireError::Truncated {
            needed: n,
            have: input.len(),
        });
    }
    let (head, tail) = input.split_at(n);
    *input = tail;
    Ok(head)
}

/// Anything that can be serialized onto the wire and back.
pub trait Wire: Sized {
    /// Append this value's encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode one value from the front of `input`, advancing it past the
    /// consumed bytes.
    fn decode(input: &mut &[u8]) -> Result<Self>;
}

/// Encode a value into a fresh buffer.
pub fn to_bytes<W: Wire>(value: &W) -> Vec<u8> {
    let mut out = Vec::new();
    value.encode(&mut out);
    out
}

/// Decode a value that must occupy the whole buffer (leftover bytes are a
/// [`WireError::Trailing`] error — the strict mode used for file records).
pub fn from_bytes<W: Wire>(mut input: &[u8]) -> Result<W> {
    let v = W::decode(&mut input)?;
    if !input.is_empty() {
        return Err(WireError::Trailing { extra: input.len() });
    }
    Ok(v)
}

/// Encoded size of a value (one throwaway encode; diagnostics/tests only —
/// hot paths encode once into the frame and read `frame.len()`).
pub fn encoded_len<W: Wire>(value: &W) -> usize {
    to_bytes(value).len()
}

// ---------------------------------------------------------------------------
// primitives
// ---------------------------------------------------------------------------

macro_rules! impl_wire_fixed {
    ($($t:ty),*) => {
        $(impl Wire for $t {
            #[inline]
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            #[inline]
            fn decode(input: &mut &[u8]) -> Result<Self> {
                let b = take(input, std::mem::size_of::<$t>())?;
                Ok(<$t>::from_le_bytes(b.try_into().unwrap()))
            }
        })*
    };
}

impl_wire_fixed!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Wire for usize {
    #[inline]
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }
    #[inline]
    fn decode(input: &mut &[u8]) -> Result<Self> {
        Ok(u64::decode(input)? as usize)
    }
}

impl Wire for isize {
    #[inline]
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as i64).encode(out);
    }
    #[inline]
    fn decode(input: &mut &[u8]) -> Result<Self> {
        Ok(i64::decode(input)? as isize)
    }
}

impl Wire for bool {
    #[inline]
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
    #[inline]
    fn decode(input: &mut &[u8]) -> Result<Self> {
        match u8::decode(input)? {
            0 => Ok(false),
            1 => Ok(true),
            tag => Err(WireError::BadTag { what: "bool", tag }),
        }
    }
}

impl Wire for () {
    #[inline]
    fn encode(&self, _out: &mut Vec<u8>) {}
    #[inline]
    fn decode(_input: &mut &[u8]) -> Result<Self> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// containers
// ---------------------------------------------------------------------------

impl<T: Wire> Wire for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self> {
        match u8::decode(input)? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(input)?)),
            tag => Err(WireError::BadTag { what: "Option", tag }),
        }
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u32).encode(out);
        for v in self {
            v.encode(out);
        }
    }
    fn decode(input: &mut &[u8]) -> Result<Self> {
        let len = u32::decode(input)? as usize;
        // Cap the preallocation by the remaining input so a corrupt length
        // prefix cannot force a huge allocation before the Truncated error.
        let mut v = Vec::with_capacity(len.min(input.len().max(1)));
        for _ in 0..len {
            v.push(T::decode(input)?);
        }
        Ok(v)
    }
}

impl Wire for String {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u32).encode(out);
        out.extend_from_slice(self.as_bytes());
    }
    fn decode(input: &mut &[u8]) -> Result<Self> {
        let len = u32::decode(input)? as usize;
        let b = take(input, len)?;
        String::from_utf8(b.to_vec()).map_err(|_| WireError::BadUtf8)
    }
}

impl<A: Wire, B: Wire> Wire for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self> {
        Ok((A::decode(input)?, B::decode(input)?))
    }
}

impl<A: Wire, B: Wire, C: Wire> Wire for (A, B, C) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
        self.2.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self> {
        Ok((A::decode(input)?, B::decode(input)?, C::decode(input)?))
    }
}

impl<A: Wire, B: Wire, C: Wire, D: Wire> Wire for (A, B, C, D) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
        self.2.encode(out);
        self.3.encode(out);
    }
    fn decode(input: &mut &[u8]) -> Result<Self> {
        Ok((
            A::decode(input)?,
            B::decode(input)?,
            C::decode(input)?,
            D::decode(input)?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip<W: Wire + PartialEq + std::fmt::Debug>(v: W) {
        let b = to_bytes(&v);
        assert_eq!(from_bytes::<W>(&b).unwrap(), v);
    }

    #[test]
    fn primitives_round_trip() {
        round_trip(0u8);
        round_trip(0xA5u8);
        round_trip(0xBEEFu16);
        round_trip(0xDEADBEEFu32);
        round_trip(u64::MAX);
        round_trip(-7i8);
        round_trip(i16::MIN);
        round_trip(-123456789i32);
        round_trip(i64::MIN);
        round_trip(3.5f32);
        round_trip(f64::NEG_INFINITY);
        round_trip(usize::MAX >> 1);
        round_trip(-42isize);
        round_trip(true);
        round_trip(false);
        round_trip(());
    }

    #[test]
    fn containers_round_trip() {
        round_trip(Option::<u32>::None);
        round_trip(Some(9u32));
        round_trip(vec![1u16, 2, 3]);
        round_trip(Vec::<f64>::new());
        round_trip("héllo wire".to_string());
        round_trip((1u8, 2.5f32));
        round_trip((1u32, 2u64, vec![3.0f32]));
        round_trip((1u32, true, "k".to_string(), vec![(7u32, 8u64)]));
        round_trip(vec![vec![1.0f64, 2.0], vec![]]);
    }

    #[test]
    fn layout_is_little_endian_and_length_prefixed() {
        assert_eq!(to_bytes(&0x0102_0304u32), [4, 3, 2, 1]);
        assert_eq!(to_bytes(&vec![1u8, 2]), [2, 0, 0, 0, 1, 2]);
        assert_eq!(to_bytes(&"ab".to_string()), [2, 0, 0, 0, b'a', b'b']);
        assert_eq!(to_bytes(&Some(7u8)), [1, 7]);
        assert_eq!(to_bytes(&5usize).len(), 8); // usize travels as u64
    }

    #[test]
    fn truncated_input_is_an_error_not_a_panic() {
        let b = to_bytes(&(1u64, vec![2u32, 3], "xyz".to_string()));
        for cut in 0..b.len() {
            let err = from_bytes::<(u64, Vec<u32>, String)>(&b[..cut]);
            assert!(err.is_err(), "prefix of {cut} bytes decoded");
        }
    }

    #[test]
    fn bad_tags_and_trailing_bytes_error() {
        assert_eq!(
            from_bytes::<bool>(&[2]),
            Err(WireError::BadTag { what: "bool", tag: 2 })
        );
        assert_eq!(
            from_bytes::<Option<u8>>(&[9, 0]),
            Err(WireError::BadTag { what: "Option", tag: 9 })
        );
        assert_eq!(from_bytes::<String>(&[1, 0, 0, 0, 0xFF]), Err(WireError::BadUtf8));
        assert_eq!(from_bytes::<u8>(&[1, 2]), Err(WireError::Trailing { extra: 1 }));
    }

    #[test]
    fn corrupt_vec_length_does_not_overallocate() {
        // Claims u32::MAX elements with 1 byte of payload: must error fast.
        let mut b = to_bytes(&u32::MAX);
        b.push(0);
        assert!(from_bytes::<Vec<u64>>(&b).is_err());
    }
}
