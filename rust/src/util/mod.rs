//! In-repo utility substrate.
//!
//! The offline vendor set only provides `xla`/`anyhow`/`thiserror`, so every
//! other building block a framework of this shape normally pulls from
//! crates.io is implemented here: a deterministic PRNG ([`rng`]), a thread
//! pool ([`threadpool`]), a CLI flag parser ([`cli`]), a key=value config
//! system ([`config`]), CSV emission ([`csv`]), summary statistics
//! ([`stats`]), and the small dense linear algebra used by the native
//! (non-PJRT) math paths ([`matrix`]).

pub mod affinity;
pub mod cli;
pub mod config;
pub mod csv;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use rng::Rng;
pub use threadpool::ThreadPool;
