//! Deterministic pseudo-random number generation (no `rand` crate offline).
//!
//! [`Rng`] is xoshiro256++ seeded through splitmix64 — the standard pairing
//! recommended by the xoshiro authors. Every randomized component in the
//! repository (data generators, partitioners, schedulers, simulators,
//! property tests) takes an explicit seed so that runs, tests, and figure
//! harnesses are exactly reproducible.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread / per-machine rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.gen_range(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-like power-law sample over `[0, n)` with exponent `alpha` via
    /// inverse-CDF approximation (used by the synthetic web/NER graphs).
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        // Inverse transform of p(x) ~ x^-alpha over [1, n].
        let u = self.f64();
        let one_m_a = 1.0 - alpha;
        let x = if (one_m_a).abs() < 1e-9 {
            (n as f64).powf(u)
        } else {
            ((((n as f64).powf(one_m_a) - 1.0) * u) + 1.0).powf(1.0 / one_m_a)
        };
        (x as usize).clamp(1, n) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn f64_in_unit_interval_with_reasonable_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn powerlaw_skews_low() {
        let mut r = Rng::new(5);
        let n = 10_000;
        let low = (0..n).filter(|_| r.powerlaw(1000, 2.0) < 10).count();
        assert!(low > n / 2, "power law should concentrate mass at small ids: {low}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
