//! CPU affinity for engine threads (opt-in via `--pin-threads`).
//!
//! The lab's executor already pins *whole runs* with `taskset`; this
//! module pins *individual engine threads* so a machine loop stops
//! migrating between cores (and across NUMA nodes) mid-run. The vendor
//! set has no `libc`, so pinning shells out to `taskset` with the
//! calling thread's kernel tid — best-effort by design: on platforms or
//! containers without `taskset` (or without `/proc`), it degrades to a
//! no-op and the engine runs exactly as before.

/// How many CPUs the scheduler offers this process (1 if unknown).
pub fn available_cpus() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Pin the *calling* thread to `cpu` (modulo nothing — pass a valid
/// index, e.g. `machine_id % available_cpus()`). Returns whether the
/// pin was applied. Never fails the run: engines treat `false` as
/// "scheduler's choice", the behavior before pinning existed.
pub fn pin_current_thread(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        // /proc/thread-self is a symlink to <pid>/task/<tid>; its last
        // component is this thread's kernel tid — the one handle taskset
        // accepts that std exposes without libc.
        let Ok(target) = std::fs::read_link("/proc/thread-self") else {
            return false;
        };
        let Some(tid) = target
            .file_name()
            .and_then(|s| s.to_str())
            .and_then(|s| s.parse::<u64>().ok())
        else {
            return false;
        };
        std::process::Command::new("taskset")
            .args(["-cp", &cpu.to_string(), &tid.to_string()])
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .status()
            .map(|s| s.success())
            .unwrap_or(false)
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_cpus_is_positive() {
        assert!(available_cpus() >= 1);
    }

    #[test]
    fn pin_is_best_effort_and_never_panics() {
        // Whether the pin lands depends on the platform/container; the
        // contract is only that the call returns (no panic, no abort)
        // and a second pin to another CPU also returns.
        let _ = pin_current_thread(0);
        let _ = pin_current_thread(available_cpus() - 1);
    }
}
