//! Tiny CSV emitter for figure/bench output (no `csv` crate offline).
//!
//! Every figure harness writes a `results/<fig>.csv` through [`CsvWriter`];
//! columns are declared once and row writes are checked against them.

use std::io::Write;
use std::path::Path;

use anyhow::{ensure, Context as _, Result};

/// Column-checked CSV writer.
pub struct CsvWriter {
    out: Box<dyn Write + Send>,
    columns: usize,
}

impl CsvWriter {
    /// Create a writer over an arbitrary sink with the given header.
    pub fn new(mut out: Box<dyn Write + Send>, header: &[&str]) -> Result<Self> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            columns: header.len(),
        })
    }

    /// Create a file-backed writer (parent directories are created).
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<Self> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Self::new(Box::new(f), header)
    }

    /// Write one row; must match the header width.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        ensure!(
            fields.len() == self.columns,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.columns
        );
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    /// Convenience: write a row of displayable values.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> Result<()> {
        let v: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&v)
    }

    /// Flush the sink.
    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Format a float with fixed precision for stable CSV diffs.
pub fn f(x: f64) -> String {
    format!("{x:.6}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[derive(Clone, Default)]
    struct Buf(Arc<Mutex<Vec<u8>>>);
    impl Write for Buf {
        fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(b);
            Ok(b.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn writes_header_and_rows() {
        let buf = Buf::default();
        let mut w = CsvWriter::new(Box::new(buf.clone()), &["nodes", "time"]).unwrap();
        w.rowd(&[&4, &1.5]).unwrap();
        w.rowd(&[&8, &0.9]).unwrap();
        let s = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        assert_eq!(s, "nodes,time\n4,1.5\n8,0.9\n");
    }

    #[test]
    fn width_mismatch_is_error() {
        let buf = Buf::default();
        let mut w = CsvWriter::new(Box::new(buf), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
    }
}
