//! Small dense f32 linear algebra (no BLAS/LAPACK offline).
//!
//! This is the *native* mirror of the Layer-1 Pallas math: the engines can
//! run every application without artifacts (`Runtime::native`), and the
//! integration tests cross-check the PJRT path against these routines. The
//! same Cholesky algorithm is implemented (unrolled) inside
//! `python/compile/kernels/als.py`.

/// Row-major dense matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Underlying row-major slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major slice.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Rank-1 update: `self += w * x x^T` (the ALS Gram accumulation).
    pub fn rank1_update(&mut self, x: &[f32], w: f32) {
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(x.len(), self.rows);
        for i in 0..self.rows {
            let wi = w * x[i];
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (j, r) in row.iter_mut().enumerate() {
                *r += wi * x[j];
            }
        }
    }

    /// Add `lam` to the diagonal (ridge regularization).
    pub fn add_diag(&mut self, lam: f32) {
        for i in 0..self.rows.min(self.cols) {
            self[(i, i)] += lam;
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

/// In-place Cholesky factorization of a symmetric PSD matrix; returns the
/// lower-triangular factor. Mirrors `_cholesky_solve` in `als.py`.
pub fn cholesky(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for j in 0..n {
        let mut s = a[(j, j)];
        for k in 0..j {
            s -= l[(j, k)] * l[(j, k)];
        }
        let ljj = s.max(1e-12).sqrt();
        l[(j, j)] = ljj;
        for i in (j + 1)..n {
            let mut s2 = a[(i, j)];
            for k in 0..j {
                s2 -= l[(i, k)] * l[(j, k)];
            }
            l[(i, j)] = s2 / ljj;
        }
    }
    l
}

/// Solve `(A + lam I) x = y` for symmetric PSD `A` via Cholesky.
pub fn solve_psd(a: &Mat, y: &[f32], lam: f32) -> Vec<f32> {
    let mut reg = a.clone();
    reg.add_diag(lam);
    let l = cholesky(&reg);
    let n = y.len();
    // forward: L t = y
    let mut t = vec![0.0f32; n];
    for i in 0..n {
        let mut s = y[i];
        for k in 0..i {
            s -= l[(i, k)] * t[k];
        }
        t[i] = s / l[(i, i)];
    }
    // backward: L^T x = t
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = t[i];
        for k in (i + 1)..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// `y += w * x`.
pub fn axpy(y: &mut [f32], x: &[f32], w: f32) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += w * xi;
    }
}

/// L1 distance between two slices.
pub fn l1_dist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// Normalize a slice to sum 1 (guarding empty mass).
pub fn normalize(x: &mut [f32]) {
    let s: f32 = x.iter().sum();
    if s > 1e-30 {
        for v in x.iter_mut() {
            *v /= s;
        }
    } else if !x.is_empty() {
        let u = 1.0 / x.len() as f32;
        for v in x.iter_mut() {
            *v = u;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_psd(n: usize, rng: &mut Rng) -> Mat {
        // A = G G^T + 0.1 I
        let mut g = Mat::zeros(n, n);
        for v in g.as_mut_slice() {
            *v = rng.normal();
        }
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += g[(i, k)] * g[(j, k)];
                }
                a[(i, j)] = s;
            }
        }
        a.add_diag(0.1);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        let a = random_psd(8, &mut rng);
        let l = cholesky(&a);
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0;
                for k in 0..8 {
                    s += l[(i, k)] * l[(j, k)];
                }
                assert!((s - a[(i, j)]).abs() < 1e-3, "({i},{j}): {s} vs {}", a[(i, j)]);
            }
        }
    }

    #[test]
    fn solve_recovers_planted() {
        let mut rng = Rng::new(2);
        for n in [1, 2, 5, 10, 20] {
            let a = random_psd(n, &mut rng);
            let x_true: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut y = vec![0.0f32; n];
            for i in 0..n {
                let mut s = 0.0;
                for j in 0..n {
                    s += a[(i, j)] * x_true[j];
                }
                y[i] = s;
            }
            let x = solve_psd(&a, &y, 0.0);
            for i in 0..n {
                assert!((x[i] - x_true[i]).abs() < 1e-2, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn rank1_matches_definition() {
        let x = [1.0f32, 2.0, 3.0];
        let mut a = Mat::zeros(3, 3);
        a.rank1_update(&x, 2.0);
        assert_eq!(a[(0, 0)], 2.0);
        assert_eq!(a[(1, 2)], 12.0);
        assert_eq!(a[(2, 1)], 12.0);
    }

    #[test]
    fn normalize_handles_zero_mass() {
        let mut x = [0.0f32; 4];
        normalize(&mut x);
        assert!(x.iter().all(|&v| (v - 0.25).abs() < 1e-7));
        let mut y = [1.0f32, 3.0];
        normalize(&mut y);
        assert!((y[0] - 0.25).abs() < 1e-7 && (y[1] - 0.75).abs() < 1e-7);
    }
}
