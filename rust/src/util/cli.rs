//! Minimal CLI argument parser (no `clap` offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` conventions used by the `graphlab` binary and the examples.

use std::collections::BTreeMap;

/// Parsed command line: positional args plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments in order (subcommand first).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process command line.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional argument `i`, if present.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// Raw flag value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed flag with default; a value that does not parse is an error
    /// (CLI misuse should fail loudly — but as a clean `bail!`-style
    /// error at the boundary, not a panic with a backtrace).
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Boolean flag (`--x`, `--x=true/false`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// All flags, for config merging.
    pub fn flags(&self) -> &BTreeMap<String, String> {
        &self.flags
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("run als --nodes 8 --d=20 --verbose");
        assert_eq!(a.pos(0), Some("run"));
        assert_eq!(a.pos(1), Some("als"));
        assert_eq!(a.num_or("nodes", 0usize).unwrap(), 8);
        assert_eq!(a.num_or("d", 0usize).unwrap(), 20);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.num_or("nodes", 4usize).unwrap(), 4);
        assert_eq!(a.str_or("engine", "chromatic"), "chromatic");
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse("x --offset -3");
        assert_eq!(a.num_or("offset", 0i64).unwrap(), -3);
    }

    #[test]
    fn bad_value_is_error_not_panic() {
        let a = parse("x --nodes abc");
        let err = a.num_or("nodes", 0usize).unwrap_err();
        assert!(err.to_string().contains("--nodes=abc"), "{err}");
    }
}
