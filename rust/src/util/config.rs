//! Layered key=value configuration (no `serde`/`toml` offline).
//!
//! A [`Config`] is a flat `section.key = value` map loaded from a file
//! (`#` comments, `[section]` headers) and overridable from CLI flags
//! (`--section.key value`). This is the config system behind `graphlab
//! run --config cluster.conf ...` and the figure harnesses.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context as _, Result};

/// Flat layered configuration store.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Empty config.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from file contents (INI-like: `[section]`, `key = value`).
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("config line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            cfg.values.insert(key, v.trim().to_string());
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Overlay (later wins): apply `other` on top of `self`.
    pub fn overlay(&mut self, other: &BTreeMap<String, String>) {
        for (k, v) in other {
            self.values.insert(k.clone(), v.clone());
        }
    }

    /// Set a single value.
    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Raw lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Typed lookup with default. A present-but-malformed value is an
    /// error (it used to fall back to the default silently, which turned
    /// typos like `--threads=fuor` into surprise single-thread runs).
    pub fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("config value {key}={v}: {e}")),
        }
    }

    /// String lookup with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean lookup with default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            _ => default,
        }
    }

    /// Iterate all entries (for dumping effective config into run logs).
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.values.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_comments_and_types() {
        let cfg = Config::parse(
            "# cluster config\n\
             [cluster]\n\
             machines = 8   # eight nodes\n\
             threads = 4\n\
             [engine]\n\
             kind = locking\n\
             maxpending = 100\n",
        )
        .unwrap();
        assert_eq!(cfg.num_or("cluster.machines", 0usize).unwrap(), 8);
        assert_eq!(cfg.str_or("engine.kind", ""), "locking");
        assert_eq!(cfg.num_or("engine.maxpending", 0u32).unwrap(), 100);
        assert_eq!(cfg.num_or("missing", 7i32).unwrap(), 7);
    }

    #[test]
    fn malformed_value_is_error_not_silent_default() {
        let cfg = Config::parse("threads = fuor\n").unwrap();
        assert!(cfg.num_or("threads", 4usize).is_err());
    }

    #[test]
    fn overlay_wins() {
        let mut cfg = Config::parse("a = 1\nb = 2\n").unwrap();
        let mut over = BTreeMap::new();
        over.insert("b".to_string(), "20".to_string());
        cfg.overlay(&over);
        assert_eq!(cfg.num_or("a", 0i32).unwrap(), 1);
        assert_eq!(cfg.num_or("b", 0i32).unwrap(), 20);
    }

    #[test]
    fn bad_line_is_error() {
        assert!(Config::parse("not a kv line\n").is_err());
    }

    #[test]
    fn bools() {
        let cfg = Config::parse("x = true\ny = 0\n").unwrap();
        assert!(cfg.bool_or("x", false));
        assert!(!cfg.bool_or("y", true));
        assert!(cfg.bool_or("z", true));
    }
}
