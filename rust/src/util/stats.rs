//! Summary statistics for benchmarks and figures.

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Minimum observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another summary (parallel reduction).
    pub fn merge(&mut self, o: &Summary) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = o.clone();
            return;
        }
        let n = self.n + o.n;
        let d = o.mean - self.mean;
        let mean = self.mean + d * o.n as f64 / n as f64;
        self.m2 += o.m2 + d * d * self.n as f64 * o.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(o.min);
        self.max = self.max.max(o.max);
    }
}

/// Median of a slice (copies + sorts; fine at bench sizes).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

/// Percentile (nearest-rank) of a slice, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.13809).abs() < 1e-4);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.stddev() - all.stddev()).abs() < 1e-9);
    }

    #[test]
    fn median_and_percentile() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }
}
