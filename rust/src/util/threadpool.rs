//! A small persistent scoped thread pool (no `rayon` offline).
//!
//! Provides the three primitives the engines need:
//!
//! * [`ThreadPool::scope_execute`] — run a closure on every worker
//!   simultaneously (the engines' "spawn N workers over shared state"
//!   pattern, mirroring the paper's pthread worker loops);
//! * [`ThreadPool::parallel_for`] — a chunked dynamic parallel for used by
//!   data generators and the chromatic engine's per-color vertex sweeps;
//! * [`DispatchQueue`] — an asynchronous job queue for the locking
//!   engine's per-machine executor pools: the pump thread pushes granted
//!   transaction batches without waiting, workers park on a condvar
//!   between jobs, and completions travel back over whatever channel the
//!   caller pairs with the jobs.
//!
//! Workers are spawned **once** at construction and parked on a condvar
//! between jobs, so callers that issue many small phases (the chromatic
//! engine runs one `parallel_for` per color per sweep) pay a notify/park
//! handshake per phase instead of an OS thread spawn + join. Borrowed
//! (non-`'static`) closures remain allowed: `scope_execute` erases the
//! closure's lifetime and is careful never to return — not even on panic —
//! until every worker has finished running it, which keeps the borrow live
//! for exactly as long as it is used.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A borrowed job with its lifetime erased. Soundness: [`CompletionGuard`]
/// pins the real borrow until `remaining == 0`, i.e. until no worker can
/// still observe the reference.
type Job = &'static (dyn Fn(usize) + Sync);

struct State {
    /// Monotonically increasing job id; workers run one job per bump.
    epoch: u64,
    /// The current job (valid while `remaining > 0` or until reset).
    job: Option<Job>,
    /// Helper threads still executing the current job.
    remaining: usize,
    /// Pool is shutting down (set by `Drop`).
    shutdown: bool,
}

struct Inner {
    state: Mutex<State>,
    /// Signals helpers: new job available (or shutdown).
    work: Condvar,
    /// Signals the submitter: a helper finished the current job.
    done: Condvar,
    /// A helper panicked while running the current job.
    panicked: AtomicBool,
}

/// Persistent worker pool: `workers - 1` helper threads are spawned at
/// construction and parked between jobs; the submitting thread itself acts
/// as worker 0. With `workers == 1` no threads exist and every primitive
/// runs inline (the deterministic single-worker path).
pub struct ThreadPool {
    workers: usize,
    inner: Arc<Inner>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes `scope_execute` submitters (the pool runs one job at a
    /// time; engines only submit from one thread, but `&self` submission
    /// must stay sound under sharing).
    submit: Mutex<()>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("workers", &self.workers).finish()
    }
}

/// Waits (in `drop`) until every helper has finished the current job, then
/// clears it. Runs on both the normal path and the unwind path, so a panic
/// in the submitter's own shard cannot free the job closure while helpers
/// still execute it.
struct CompletionGuard<'a> {
    inner: &'a Inner,
}

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.remaining > 0 {
            st = self.inner.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
    }
}

fn helper_loop(inner: Arc<Inner>, id: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    break st.job.expect("epoch bumped without a job");
                }
                st = inner.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(id)));
        if result.is_err() {
            inner.panicked.store(true, Ordering::Release);
        }
        let mut st = inner.state.lock().unwrap_or_else(|e| e.into_inner());
        st.remaining -= 1;
        if st.remaining == 0 {
            inner.done.notify_all();
        }
    }
}

impl ThreadPool {
    /// A pool with `workers` worker threads (minimum 1); `workers - 1` OS
    /// threads are spawned here and live until the pool is dropped.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                remaining: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let handles = (1..workers)
            .map(|id| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("graphlab-worker-{id}"))
                    .spawn(move || helper_loop(inner, id))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool {
            workers,
            inner,
            handles,
            submit: Mutex::new(()),
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(worker_id)` on every worker concurrently and wait for all.
    /// The submitting thread participates as worker 0.
    pub fn scope_execute<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.workers == 1 {
            f(0);
            return;
        }
        let _submit = self.submit.lock().unwrap_or_else(|e| e.into_inner());
        self.inner.panicked.store(false, Ordering::Release);
        {
            let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
            // SAFETY: the erased borrow of `f` is cleared by
            // `CompletionGuard` before this function returns (normally or
            // by unwind), and the guard waits for every helper first.
            let borrowed: &(dyn Fn(usize) + Sync) = &f;
            let job: Job = unsafe { std::mem::transmute(borrowed) };
            st.job = Some(job);
            st.remaining = self.workers - 1;
            st.epoch += 1;
            self.inner.work.notify_all();
        }
        let guard = CompletionGuard { inner: &self.inner };
        f(0);
        drop(guard); // blocks until all helpers finished this job
        if self.inner.panicked.load(Ordering::Acquire) {
            panic!("a ThreadPool worker panicked during scope_execute");
        }
    }

    /// Dynamic parallel for over `0..n` with an atomic chunk cursor:
    /// `f(i)` for every index, chunked to amortize the atomic.
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let cursor = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        self.scope_execute(|_w| loop {
            let start = cursor.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            for i in start..end {
                f(i);
            }
        });
    }

    /// Parallel fold: each worker folds a private accumulator over the
    /// indices it claims, then the accumulators are merged sequentially.
    pub fn parallel_fold<A, F, M>(&self, n: usize, chunk: usize, init: A, fold: F, merge: M) -> A
    where
        A: Clone + Send + Sync,
        F: Fn(&mut A, usize) + Sync,
        M: Fn(&mut A, A),
    {
        let cursor = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        let accs = Mutex::new(Vec::new());
        self.scope_execute(|_w| {
            let mut acc = init.clone();
            loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    fold(&mut acc, i);
                }
            }
            accs.lock().unwrap_or_else(|e| e.into_inner()).push(acc);
        });
        let mut out = init;
        for a in accs.into_inner().unwrap() {
            merge(&mut out, a);
        }
        out
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
            self.inner.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// An asynchronous multi-producer multi-consumer job queue for executor
/// pools that outlive individual jobs (the locking engine's per-machine
/// update workers).
///
/// Unlike [`ThreadPool::scope_execute`], which is a fork-join barrier
/// (the submitter blocks until every worker finishes), `push` returns
/// immediately: the pump thread keeps servicing the network while workers
/// chew through granted transaction batches. Workers call the blocking
/// [`DispatchQueue::pop`] in a loop and exit when it returns `None`,
/// which happens once the queue has been [closed](DispatchQueue::close)
/// and drained. Results flow back over whatever channel the caller pairs
/// with the jobs — the queue itself is one-directional.
pub struct DispatchQueue<J> {
    state: Mutex<QueueState<J>>,
    avail: Condvar,
}

struct QueueState<J> {
    jobs: std::collections::VecDeque<J>,
    closed: bool,
}

impl<J> Default for DispatchQueue<J> {
    fn default() -> Self {
        Self::new()
    }
}

impl<J> DispatchQueue<J> {
    pub fn new() -> Self {
        DispatchQueue {
            state: Mutex::new(QueueState {
                jobs: std::collections::VecDeque::new(),
                closed: false,
            }),
            avail: Condvar::new(),
        }
    }

    /// Enqueue a job and wake one parked worker. Pushing to a closed
    /// queue silently drops the job (only reachable during unwinds —
    /// the pump closes the queue strictly after its last push on the
    /// normal path).
    pub fn push(&self, job: J) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if st.closed {
            return;
        }
        st.jobs.push_back(job);
        self.avail.notify_one();
    }

    /// Blocking dequeue: parks until a job arrives or the queue is
    /// closed *and* empty (then returns `None` — the worker's exit
    /// signal). Remaining jobs are still handed out after `close`, so
    /// closing never loses queued work.
    pub fn pop(&self) -> Option<J> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if st.closed {
                return None;
            }
            st = self.avail.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Close the queue and wake every parked worker so it can drain the
    /// remainder and exit. Idempotent.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.closed = true;
        self.avail.notify_all();
    }

    /// RAII closer: guarantees `close` runs even if the owning scope
    /// unwinds, so workers blocked in `pop` can never deadlock a
    /// `std::thread::scope` join.
    pub fn close_guard(&self) -> CloseGuard<'_, J> {
        CloseGuard { queue: self }
    }
}

/// See [`DispatchQueue::close_guard`].
pub struct CloseGuard<'a, J> {
    queue: &'a DispatchQueue<J>,
}

impl<J> Drop for CloseGuard<'_, J> {
    fn drop(&mut self) {
        self.queue.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(8).parallel_for(n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_fold_sums_correctly() {
        let n = 100_000usize;
        let total = ThreadPool::new(4).parallel_fold(
            n,
            1000,
            0u64,
            |acc, i| *acc += i as u64,
            |a, b| *a += b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn scope_execute_runs_every_worker() {
        let flags: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(6).scope_execute(|w| {
            flags[w].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_worker_is_inline() {
        let mut hit = false;
        let hit_ref = Mutex::new(&mut hit);
        ThreadPool::new(1).scope_execute(|_| {
            **hit_ref.lock().unwrap_or_else(|e| e.into_inner()) = true;
        });
        assert!(hit);
    }

    #[test]
    fn pool_is_reusable_across_many_phases() {
        // The persistent pool's reason to exist: many cheap phases on the
        // same threads. Also exercises the park/notify handshake heavily.
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.parallel_for(64, 8, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 200 * 64);
    }

    #[test]
    fn dispatch_queue_delivers_every_job_once() {
        let q = DispatchQueue::new();
        let hits: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    while let Some(i) = q.pop() {
                        hits[i as usize].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            for i in 0..500u64 {
                q.push(i);
            }
            q.close();
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dispatch_queue_close_drains_remaining_jobs() {
        // Jobs queued before close must still be handed out.
        let q = DispatchQueue::new();
        for i in 0..10u64 {
            q.push(i);
        }
        q.close();
        let mut seen = Vec::new();
        while let Some(i) = q.pop() {
            seen.push(i);
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert_eq!(q.pop(), None); // stays closed
    }

    #[test]
    fn dispatch_queue_close_guard_unblocks_workers_on_unwind() {
        let q = DispatchQueue::<u64>::new();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            std::thread::scope(|s| {
                let _close = q.close_guard();
                s.spawn(|| while q.pop().is_some() {});
                panic!("pump died");
            });
        }));
        // Without the guard the scope join would hang forever on the
        // worker parked in pop(); with it, the panic propagates out.
        assert!(caught.is_err());
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let pool = ThreadPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_execute(|w| {
                if w == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // Pool must still be usable after a worker panic.
        let n = AtomicU64::new(0);
        pool.scope_execute(|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 4);
    }
}
