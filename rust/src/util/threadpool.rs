//! A small scoped thread pool (no `rayon` offline).
//!
//! Provides the two primitives the engines need:
//!
//! * [`ThreadPool::scope_execute`] — run a closure on every worker
//!   simultaneously (the engines' "spawn N workers over shared state"
//!   pattern, mirroring the paper's pthread worker loops);
//! * [`ThreadPool::parallel_for`] — a chunked dynamic parallel for used by
//!   data generators and the chromatic engine's per-color vertex sweeps.
//!
//! Scoped execution is built on `std::thread::scope`, so borrows of stack
//! data are allowed without `Arc` gymnastics.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread-count container; threads are spawned per scoped call rather than
/// persisted, which keeps lifetimes simple and is cheap at the granularity
/// the engines use (one spawn per engine phase, not per task).
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// A pool with `workers` worker threads (minimum 1).
    pub fn new(workers: usize) -> Self {
        ThreadPool {
            workers: workers.max(1),
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f(worker_id)` on every worker concurrently and wait for all.
    pub fn scope_execute<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.workers == 1 {
            f(0);
            return;
        }
        std::thread::scope(|s| {
            for w in 0..self.workers {
                let f = &f;
                s.spawn(move || f(w));
            }
        });
    }

    /// Dynamic parallel for over `0..n` with an atomic chunk cursor:
    /// `f(i)` for every index, chunked to amortize the atomic.
    pub fn parallel_for<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let cursor = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        self.scope_execute(|_w| loop {
            let start = cursor.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            for i in start..end {
                f(i);
            }
        });
    }

    /// Parallel fold: each worker folds a private accumulator over the
    /// indices it claims, then the accumulators are merged sequentially.
    pub fn parallel_fold<A, F, M>(&self, n: usize, chunk: usize, init: A, fold: F, merge: M) -> A
    where
        A: Clone + Send + Sync,
        F: Fn(&mut A, usize) + Sync,
        M: Fn(&mut A, A),
    {
        let cursor = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        let accs = std::sync::Mutex::new(Vec::new());
        self.scope_execute(|_w| {
            let mut acc = init.clone();
            loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    fold(&mut acc, i);
                }
            }
            accs.lock().unwrap().push(acc);
        });
        let mut out = init;
        for a in accs.into_inner().unwrap() {
            merge(&mut out, a);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(8).parallel_for(n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_fold_sums_correctly() {
        let n = 100_000usize;
        let total = ThreadPool::new(4).parallel_fold(
            n,
            1000,
            0u64,
            |acc, i| *acc += i as u64,
            |a, b| *a += b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn scope_execute_runs_every_worker() {
        let flags: Vec<AtomicU64> = (0..6).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(6).scope_execute(|w| {
            flags[w].fetch_add(1, Ordering::Relaxed);
        });
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_worker_is_inline() {
        let mut hit = false;
        let hit_ref = std::sync::Mutex::new(&mut hit);
        ThreadPool::new(1).scope_execute(|_| {
            **hit_ref.lock().unwrap() = true;
        });
        assert!(hit);
    }
}
